"""llama4-maverick-400b-a17b — MoE top-1, 128 experts, MoE every 2nd layer,
early-fusion multimodal [hf:meta-llama/Llama-4-Maverick-17B-128E].

Dense layers use d_ff 16384; MoE layers route top-1 over 128 experts
(d_ff 8192) plus one always-on shared expert.  Early fusion is modeled with
the VLM patch-embedding stub (precomputed patch embeddings prepended).
128 experts / EP=16 = 8 per device.
"""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=16384, vocab_size=202048,
    mlp_type="swiglu",
    num_experts=128, num_shared_experts=1, top_k=1, moe_d_ff=8192,
    moe_every=2, moe_capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=256,
    mlp_type="swiglu",
    num_experts=8, num_shared_experts=1, top_k=1, moe_d_ff=64,
    moe_every=2, dtype="float32",
)

register(FULL, SMOKE)
