"""internlm2-1.8b — dense GQA [arXiv:2403.17297].  head_dim = 128 (aligned)."""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="internlm2-1.8b", family="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92544,
    mlp_type="swiglu",
)

SMOKE = ModelConfig(
    name="internlm2-smoke", family="dense",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256,
    mlp_type="swiglu", dtype="float32",
)

register(FULL, SMOKE)
