"""mamba2-780m — attention-free SSD (state-space duality) [arXiv:2405.21060].

d_inner = 2*1536 = 3072, 48 SSD heads of P=64, state N=128 (lane-aligned ✓),
chunk Q=256 (lane-aligned ✓).  No attention and no MLP: each layer is one
Mamba2 block (d_ff=0).  The paper's BMM rules apply to the SSD chunk BMMs
with (Q, P, N) as the shape knobs (DESIGN.md §Arch-applicability).
Runs long_500k.
"""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    attn_type="none", mlp_type="gelu",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=3, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256,
    attn_type="none", mlp_type="gelu",
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=32,
    tie_embeddings=True, dtype="float32",
)

register(FULL, SMOKE)
