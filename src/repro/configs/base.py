"""Model / run configuration schema.

Every assigned architecture is expressed as a `ModelConfig`; input-shape cells
as `ShapeConfig`; parallelism as `MeshConfig`.  Configs are frozen dataclasses
(hashable — usable as jit static args) and carry enough structure for the
co-design engine (core/) to enumerate their GEMMs without instantiating
parameters.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int  # query heads; 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # block variants ------------------------------------------------------
    mlp_type: str = "swiglu"  # swiglu | gelu | relu2
    qkv_bias: bool = False
    parallel_layers: bool = False  # Wang&Komatsuzaki parallel attn+MLP (§VI-C1)
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    pos_emb: str = "rotary"  # rotary | learned | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # attention variant ----------------------------------------------------
    attn_type: str = "gqa"  # gqa | mla | none
    # "naive" = paper Table II score/AOV BMM decomposition (faithful baseline)
    # "blocked" = streaming online-softmax (§VI-C3 FlashAttention; XLA twin
    #             of kernels/flash_attention, used by the §Perf hillclimb)
    # "flash" = Pallas flash kernel with fused custom-VJP backward
    #           (kernels/flash_attention) — the differentiable TPU training
    #           path; consults the autotuning cache (tuned=True) and honors
    #           $REPRO_KERNEL_INTERPRET
    # "paged" = Pallas paged decode kernel over the serving slot pool
    attn_impl: str = "naive"
    attn_block_kv: int = 1024
    # linear-execution dispatch for every dense projection GEMM (qkv/output,
    # MLP, lm_head, MoE experts) — all routed through repro.models.linear:
    # "jnp"    = XLA x @ w (CPU/dry-run default)
    # "pallas" = tile-aligned Pallas matmul kernel at its 128^3 defaults
    # "tuned"  = Pallas + per-(m, k, n, dtype, hw) autotuning-cache blocks
    # "fused"  = tuned dispatch + the fused SwiGLU/MLP Pallas kernel for the
    #            MLP gate/up pair (kernels/fused_mlp; the §VII-B hot path)
    # "quantized" = int8 weight path (kernels/quantized): per-channel weight
    #            scales, dynamic per-row activation quantization, i32
    #            accumulate, f32 de-scale — inference-first; gradients fall
    #            back to the high-precision tuned matmul route
    linear_impl: str = "jnp"
    # KV-cache storage dtype for serving pools and decode caches:
    # "auto" = the compute dtype; "int8" = quantized KV (int8 payload plus
    # per-(token, kv_head) f32 scale leaves — see models/blocks and the
    # dequantizing paged-decode kernels).  Engine(kv_dtype=...) sets this.
    kv_dtype: str = "auto"
    # Megatron-style sequence parallelism: residual-stream activations are
    # sequence-sharded on the model axis between TP blocks (norms/adds run
    # 1/t-sharded; XLA converts the TP all-reduce into all-gather +
    # reduce-scatter of the same volume).
    seq_parallel: bool = False
    # MLA (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE -------------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1  # MoE layer every k-th layer (llama4: 2)
    first_dense_layers: int = 0  # deepseek-v3: first 3 layers dense
    moe_capacity_factor: float = 1.25
    # "auto" = XLA-chosen collectives (models/moe.py);
    # "shard_map" = explicit EP schedule: local dispatch + one psum combine
    moe_dispatch: str = "auto"

    # SSM / Mamba2 ----------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    conv_width: int = 4

    # hybrid (zamba2): shared attention block applied every k SSM blocks ----
    hybrid_attn_every: int = 0

    # encoder-decoder (whisper) ----------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed frame embeddings length (conv stub)

    # vlm (internvl / llama4 early fusion): patch-embedding stub -------------
    num_patches: int = 0

    # multi-token prediction (deepseek-v3) -----------------------------------
    mtp_depth: int = 0

    dtype: str = "bfloat16"

    # Deployment intent, consumed by the static shape audit
    # (repro.analysis.shape_audit): error-severity shape findings gate CI
    # only for production configs.  Pedagogical / deliberately-misaligned
    # configs (the GPT-3 2.7B case-study variants, the smoke configs) set
    # False so they stay usable in tests and examples while still being
    # *flagged* (at warn severity).
    production: bool = True

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def attention_free(self) -> bool:
        return self.attn_type == "none"

    @property
    def padded_vocab_size(self) -> int:
        """Embedding/logit rows padded to a multiple of 128 (paper §VI-B:
        'vocab divisible by 64' — 128 on TPU lanes, and it also satisfies
        v % tp == 0 for any power-of-two TP).  E.g. 50257 -> 50304, the
        nanoGPT +25% trick.  Logits over padded ids are masked to -inf."""
        v = self.vocab_size
        return -(-v // 128) * 128

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    def is_moe_layer(self, layer: int) -> bool:
        if self.num_experts == 0:
            return False
        if layer < self.first_dense_layers:
            return False
        return (layer - self.first_dense_layers) % self.moe_every == 0

    @property
    def num_moe_layers(self) -> int:
        return sum(1 for i in range(self.num_layers) if self.is_moe_layer(i))

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing => long_500k cell is runnable."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive stack

    def param_count(self) -> int:
        """Exact-ish parameter count (embeddings + per-layer weights).

        Mirrors the paper's P = 12h^2 L + 13hL + (v+s)h for the vanilla
        architecture, generalized to GQA/MLA/MoE/SSM variants.
        """
        h = self.d_model
        n = 0
        # embeddings (+ untied output head)
        n += self.vocab_size * h
        if not self.tie_embeddings:
            n += self.vocab_size * h
        if self.pos_emb == "learned":
            n += 8192 * h  # nominal max positions
        for layer in range(self.num_layers):
            n += self._layer_params(layer)
        if self.family == "hybrid":
            # zamba2 shared attention+MLP block (weights tied across uses)
            n += self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model
        if self.is_encoder_decoder:
            for _ in range(self.num_encoder_layers):
                # encoder: self-attn + mlp
                n += self._attn_params() + self._mlp_params(self.d_ff) + 2 * h
            # decoder cross-attention blocks
            n += self.num_layers * (self._attn_params() + h)
        n += self.num_layers * 2 * h  # norms (approx 2 per layer)
        n += h  # final norm
        if self.mtp_depth:
            n += self.mtp_depth * (self._layer_params(self.num_layers - 1) + 2 * h * h)
        return n

    def _attn_params(self) -> int:
        h = self.d_model
        if self.attn_type == "mla":
            qdim = self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
            p = h * self.q_lora_rank + self.q_lora_rank * qdim
            p += h * (self.kv_lora_rank + self.qk_rope_dim)
            p += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
            p += self.num_heads * self.v_head_dim * h
            return p
        hd = self.head_dim
        p = h * (self.num_heads * hd) + h * (2 * self.num_kv_heads * hd)
        p += (self.num_heads * hd) * h
        if self.qkv_bias:
            p += (self.num_heads + 2 * self.num_kv_heads) * hd
        return p

    def _mlp_params(self, d_ff: int) -> int:
        h = self.d_model
        mats = 3 if self.mlp_type == "swiglu" else 2
        return mats * h * d_ff

    def _ssm_params(self) -> int:
        h, di, ds = self.d_model, self.ssm_d_inner, self.ssm_state
        ng, nh = self.ssm_ngroups, self.ssm_nheads
        p = h * (2 * di + 2 * ng * ds + nh)  # in_proj (z,x,B,C,dt)
        p += self.conv_width * (di + 2 * ng * ds)  # conv1d
        p += nh * 2  # A_log, D
        p += di * h  # out_proj
        return p

    def _layer_params(self, layer: int) -> int:
        h = self.d_model
        fam_attn = 0
        fam_mix = 0
        if self.family in ("ssm", "hybrid"):
            # hybrid (zamba2): layers are pure Mamba2 blocks; the shared
            # attention+MLP block's params are counted once in param_count().
            fam_mix = self._ssm_params()
            if self.family == "ssm" and self.d_ff:
                fam_mix += self._mlp_params(self.d_ff)
            return fam_mix
        fam_attn = self._attn_params()
        if self.is_moe_layer(layer):
            e = self.num_experts * self._mlp_params(self.moe_d_ff)
            e += self.num_shared_experts * self._mlp_params(self.moe_d_ff)
            e += h * self.num_experts  # router
            return fam_attn + e
        return fam_attn + self._mlp_params(self.d_ff)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: only routed top_k experts)."""
        if self.num_experts == 0:
            return self.param_count()
        n = self.param_count()
        per_expert = self._mlp_params(self.moe_d_ff)
        inactive = self.num_moe_layers * (self.num_experts - self.top_k) * per_expert
        return n - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


# The four assigned input-shape cells -------------------------------------------------
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Parallelism plan over the physical mesh."""

    data: int = 1
    model: int = 1
    pod: int = 1
    pod_role: str = "data"  # data | pipeline
    fsdp: bool = True  # shard params/optimizer over the data axis (ZeRO-3)

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pod

    @property
    def tp(self) -> int:
        return self.model

    @property
    def dp(self) -> int:
        return self.data * (self.pod if self.pod_role == "data" else 1)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatch_per_device: int = 1
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    optimizer: str = "adamw"  # adamw | adamw8bit
    remat: str = "full"  # none | full | dots
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
