"""zamba2-2.7b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

54 Mamba2 layers with a single *shared-weight* attention block applied every
6th layer (9 applications).  head_dim = 2560/32 = 80 — the same misalignment
the paper's GPT-3 2.7B case study targets (pow2 factor 16 < 128 lane width);
the advisor flags it.  Runs long_500k (sub-quadratic backbone).
"""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    # Published Zamba2 shape: head_dim 80 is the misalignment under study
    # (see module docstring) — reproduce it, don't "fix" it.
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,  # repro: noqa[SHP102]
    d_ff=10240, vocab_size=32000,
    mlp_type="gelu", attn_type="gqa",
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    hybrid_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    mlp_type="gelu", attn_type="gqa",
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=32,
    hybrid_attn_every=2, dtype="float32",
)

register(FULL, SMOKE)
