"""deepseek-v3-671b — MLA + MoE (1 shared + 256 routed, top-8) + MTP
[arXiv:2412.19437].

MLA: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v_head 128.
First 3 layers dense (d_ff 18432); layers 4..61 MoE with expert d_ff 2048.
256 experts / EP=16 = 16 experts per device on the production mesh (the
advisor's experts_div_ep rule).  Expert GEMM n-dim 2048 is lane-aligned.
"""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    mlp_type="swiglu",
    attn_type="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128, head_dim=192,
    num_experts=256, num_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=3, moe_capacity_factor=1.25,
    mtp_depth=1,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    mlp_type="swiglu",
    attn_type="mla", q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, head_dim=24,
    num_experts=8, num_shared_experts=1, top_k=2, moe_d_ff=32,
    first_dense_layers=1, mtp_depth=1, dtype="float32",
)

register(FULL, SMOKE)
