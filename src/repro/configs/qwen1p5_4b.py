"""qwen1.5-4b — dense MHA with QKV bias [hf:Qwen/Qwen1.5-4B].

head_dim = 2560/20 = 128 — fully lane-aligned on TPU v5e.  The QKV bias only
changes the GEMM epilogue (β-term), not its shape (paper §III-A).
"""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    d_ff=6912, vocab_size=151936,
    mlp_type="swiglu", qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke", family="dense",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=160, vocab_size=256,
    mlp_type="swiglu", qkv_bias=True, dtype="float32",
)

register(FULL, SMOKE)
