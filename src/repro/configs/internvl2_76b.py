"""internvl2-76b — VLM: InternViT frontend (STUB) + LLaMA-3-70B-class backbone
[arXiv:2404.16821].

Per the task spec the modality frontend is a stub: `input_specs()` provides
precomputed patch embeddings (b, n_patches, d_model) prepended to the token
stream; the backbone is a dense GQA transformer (head_dim 128, aligned).
"""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    mlp_type="swiglu",
    num_patches=1024,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=256,
    mlp_type="swiglu", num_patches=8, dtype="float32",
)

register(FULL, SMOKE)
