"""Architecture registry: full configs + reduced smoke configs per arch.

Full configs are exercised ONLY via the dry-run (ShapeDtypeStruct lowering);
smoke configs instantiate real parameters on CPU in tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from .base import ModelConfig

_ARCHS = [
    "zamba2_2p7b", "qwen1p5_4b", "nemotron4_340b", "internlm2_1p8b",
    "command_r_plus_104b", "deepseek_v3_671b", "llama4_maverick",
    "internvl2_76b", "whisper_small", "mamba2_780m",
    # paper case-study configs (not part of the 40-cell table)
    "gpt3_2p7b",
]

_REGISTRY: Dict[str, ModelConfig] = {}
_SMOKE: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig):
    _REGISTRY[cfg.name] = cfg
    # Smoke configs are deliberately tiny (and sometimes deliberately
    # misaligned); never let their shape findings gate CI.
    if smoke.production:
        smoke = dataclasses.replace(smoke, production=False)
    _SMOKE[cfg.name] = smoke
    return cfg


def _load_all():
    if _REGISTRY:
        return
    for m in _ARCHS:
        importlib.import_module(f"repro.configs.{m}")


def get_config(name: str) -> ModelConfig:
    _load_all()
    try:
        return _REGISTRY[name]
    except KeyError as e:
        raise ValueError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}") from e


def get_smoke_config(name: str) -> ModelConfig:
    _load_all()
    return _SMOKE[name]


def list_archs(assigned_only: bool = False):
    _load_all()
    names = sorted(_REGISTRY)
    if assigned_only:
        names = [n for n in names if not n.startswith("gpt3") and not n.startswith("pythia")]
    return names
