"""nemotron-4-340b — dense GQA with squared-ReLU MLP [arXiv:2402.16819].

head_dim = 18432/96 = 192 (1.5 MXU lanes — the advisor notes the half-tile);
d_ff = 4h = 73728 is fully aligned.  Squared ReLU is pointwise (no GEMM-shape
impact, paper §VI-C).
"""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256000,
    mlp_type="relu2",
)

SMOKE = ModelConfig(
    name="nemotron-smoke", family="dense",
    num_layers=3, d_model=96, num_heads=4, num_kv_heads=2,
    d_ff=384, vocab_size=512,
    mlp_type="relu2", dtype="float32",
)

register(FULL, SMOKE)
