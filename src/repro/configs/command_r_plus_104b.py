"""command-r-plus-104b — dense GQA, no biases, PARALLEL attn+MLP blocks
[hf:CohereForAI/c4ai-command-r-plus].

Parallel layers are the paper's §VI-C1 architectural modification — the
residual form y = x + Attn(N(x)) + MLP(N(x)) with a single input norm.
head_dim = 12288/96 = 128 (aligned).
"""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    mlp_type="swiglu", parallel_layers=True, norm_type="layernorm",
)

SMOKE = ModelConfig(
    name="command-r-smoke", family="dense",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=256,
    mlp_type="swiglu", parallel_layers=True, norm_type="layernorm",
    dtype="float32",
)

register(FULL, SMOKE)
