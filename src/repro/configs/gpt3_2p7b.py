"""GPT-3 2.7B shape case study (paper Fig. 1).

C0 is Brown et al.'s original shape (a=32, head_dim 80 — misaligned, copied
by GPT-Neo/OPT/RedPajama/Pythia).  C1/C2 are the paper's variants; C3 (a=20,
head_dim 128) is the paper's recommended fix and the TPU-optimal one.
"""

from .base import ModelConfig
from .registry import register


def _variant(tag: str, heads: int) -> ModelConfig:
    return ModelConfig(
        name=f"gpt3-2.7b-{tag}", family="dense",
        num_layers=32, d_model=2560, num_heads=heads, num_kv_heads=heads,
        d_ff=10240, vocab_size=50257,
        mlp_type="gelu", norm_type="layernorm",
        # Paper case-study shapes: C0's head_dim 80 / vocab 50257 are the
        # misalignments under study, not a deployment target — keep the
        # static shape audit (SHP1xx) from gating CI on them.
        production=False,
    )


C0 = _variant("c0", 32)  # original: head_dim 80
C1 = _variant("c1", 64)  # paper Fig.1 C1: head_dim 40
C2 = _variant("c2", 40)  # paper Fig.1 C2: head_dim 64
C3 = _variant("c3", 20)  # paper text fix: head_dim 128

SMOKE = ModelConfig(
    name="gpt3-smoke", family="dense",
    num_layers=2, d_model=80, num_heads=4, num_kv_heads=4,  # head_dim 20: misaligned on purpose
    d_ff=320, vocab_size=251,  # vocab not divisible by 64/128 on purpose
    mlp_type="gelu", norm_type="layernorm", dtype="float32",
)

register(C0, SMOKE)
VARIANTS = {"c0": C0, "c1": C1, "c2": C2, "c3": C3}
