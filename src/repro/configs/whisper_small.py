"""whisper-small — encoder-decoder with conv audio frontend (STUB)
[arXiv:2212.04356].

The conv frontend is stubbed per the task spec: `input_specs()` provides
precomputed frame embeddings (b, 1500, 768).  12 encoder + 12 decoder layers,
LayerNorm, GELU, learned positions.  The paper notes its analysis "largely
does not apply to encoder-decoder models" (§III-C) — we apply it per-stack
(see DESIGN.md §Arch-applicability).  decode_32k is lowered structurally
(whisper's real max target length is 448).
"""
from .base import ModelConfig
from .registry import register

FULL = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    mlp_type="gelu", norm_type="layernorm", pos_emb="learned",
    is_encoder_decoder=True, num_encoder_layers=12, encoder_seq=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    mlp_type="gelu", norm_type="layernorm", pos_emb="learned",
    is_encoder_decoder=True, num_encoder_layers=2, encoder_seq=32,
    dtype="float32",
)

register(FULL, SMOKE)
