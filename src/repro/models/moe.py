"""Mixture-of-Experts layer: top-k routing, capacity-bounded sort-based
dispatch, EP-shardable batched-expert GEMMs, shared experts.

The expert FFN computation is `num_experts` batched GEMMs of size
(capacity, h) x (h, moe_d_ff) — exactly the `moe_expert_*` entries that
core/transformer_gemms.py enumerates, so the paper's alignment rules apply to
(capacity, h, moe_d_ff) and the advisor checks experts % EP == 0.

Dispatch is sort-based (GShard-style but without the T×E×C one-hot): tokens
are sorted by assigned expert, positioned within their expert's capacity
window, and scattered into an (E, C, h) buffer.  Under EP sharding of the
leading expert axis XLA lowers the scatter/gather to all-to-all traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init
from .linear import expert_fused_hidden, expert_linear, linear, resolve_impl
from .mlp import apply_mlp, init_mlp


def init_moe(key, cfg: ModelConfig):
    h, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    out_scale = 1.0 / (2 * cfg.num_layers) ** 0.5
    p = {
        "router": dense_init(ks[0], h, e, scale=0.5),
        "w_up": jax.vmap(lambda k: dense_init(k, h, f))(jax.random.split(ks[1], e)),
        "w_down": jax.vmap(lambda k: dense_init(k, f, h, scale=out_scale))(
            jax.random.split(ks[2], e)),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = jax.vmap(lambda k: dense_init(k, h, f))(jax.random.split(ks[3], e))
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.moe_capacity_factor / cfg.num_experts)
    return max(cap - cap % -8, 8)  # round up to 8 (sublane alignment)


def apply_moe(p, x, cfg: ModelConfig):
    """x: (b, s, h) -> (y, aux_loss)."""
    b, s, h = x.shape
    e, k = cfg.num_experts, cfg.top_k
    xt = x.reshape(b * s, h)
    t = b * s
    cap = _capacity(t, cfg)
    impl = resolve_impl(cfg)

    logits = linear(xt, p["router"], impl=impl).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (t, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)  # renormalize

    # load-balance auxiliary loss (Switch/GShard form)
    frac_tokens = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)

    # ---- sort-based dispatch -------------------------------------------------
    flat_expert = idx.reshape(-1)  # (t*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # position of each entry within its expert's token run
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = jnp.arange(t * k) - seg_start[se]
    keep = pos < cap
    # scatter into (e, cap, h); dropped tokens go to a trash row
    from ..parallel.sharding import constrain
    buf = jnp.zeros((e * cap, h), x.dtype)
    dst = jnp.where(keep, se * cap + pos, e * cap - 1)
    buf = buf.at[dst].add(jnp.where(keep[:, None], xt[st], 0))
    buf = constrain(buf, "eh").reshape(e, cap, h)

    # ---- batched expert GEMMs (E x (cap,h)x(h,f)) ----------------------------
    # dispatched through repro.models.linear: jnp keeps the einsum, Pallas
    # impls run one tuned kernel per expert, and "fused" runs the gate/up
    # pair + combine as the fused MLP kernel per expert
    if impl == "fused":
        hdn = expert_fused_hidden(
            buf, p.get("w_gate"), p["w_up"],
            mlp_type="swiglu" if cfg.mlp_type == "swiglu" else "gelu")
    elif cfg.mlp_type == "swiglu":
        g = jax.nn.silu(expert_linear(buf, p["w_gate"], impl=impl))
        u = expert_linear(buf, p["w_up"], impl=impl)
        hdn = g * u
    else:
        hdn = jax.nn.gelu(expert_linear(buf, p["w_up"], impl=impl))
    out_buf = expert_linear(hdn, p["w_down"], impl=impl)
    out_buf = out_buf.reshape(e * cap, h)

    # ---- gather back + combine ----------------------------------------------
    # anchor the token-major layout: without it the SPMD partitioner
    # replicates the (t*k, h) gather output on every chip (measured 60 GB x
    # 58 layers x n_micro on deepseek-v3 — EXPERIMENTS.md §Perf)
    out_buf = constrain(out_buf, "eh")
    picked = jnp.where(keep[:, None], out_buf[dst], 0)
    picked = constrain(picked, "td")
    y = jnp.zeros((t, h), x.dtype).at[st].add(picked * sg[:, None].astype(x.dtype))
    y = constrain(y, "td")

    if cfg.num_shared_experts:
        y = y + apply_mlp(p["shared"], xt, cfg)
    return y.reshape(b, s, h), aux
