"""Transformer block assembly and scanned layer stacks.

A model is a list of *segments*; each segment is `n` structurally identical
layers whose parameters are stacked on a leading axis and executed with
`jax.lax.scan` (fast compiles + small HLO even for 96-layer models, and the
natural form for per-layer FSDP gathering under SPMD).

Segment kinds:
  dense        — attn + MLP                       (qwen, nemotron, internlm2, ...)
  moe          — attn + MoE                       (deepseek-v3 layers 3..61)
  pair         — [moe, dense] superblock          (llama4: MoE every 2nd layer)
  ssm          — Mamba2 block only                (mamba2-780m)
  hybrid_super — `k` (ssm+MLP) layers + one SHARED attention block
                 (zamba2: shared weights live outside the scan)
Supports sequential and parallel-layers (§VI-C1) residual forms.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import apply_attention, init_attention
from .layers import norm_apply, norm_init
from .mlp import apply_mlp, init_mlp
from .moe import apply_moe, init_moe
from .ssm import apply_ssm, decode_ssm, init_ssm, init_ssm_cache


# --- plan ---------------------------------------------------------------------------

def stack_plan(cfg: ModelConfig) -> List[Tuple[str, int]]:
    L = cfg.num_layers
    if cfg.family == "ssm":
        return [("ssm", L)]
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every or L
        assert L % k == 0, "hybrid: L must divide hybrid_attn_every"
        return [("hybrid_super", L // k)]
    if cfg.num_experts:
        if cfg.moe_every == 1:
            fd = cfg.first_dense_layers
            plan: List[Tuple[str, int]] = []
            if fd:
                plan.append(("dense", fd))
            plan.append(("moe", L - fd))
            return plan
        assert cfg.moe_every == 2 and cfg.first_dense_layers == 0
        return [("pair", L // 2)]
    return [("dense", L)]


# --- per-kind init -------------------------------------------------------------------

def _init_one(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 8)
    if kind == "dense":
        return {"norm1": norm_init(cfg.d_model, cfg.norm_type),
                "attn": init_attention(ks[0], cfg),
                "norm2": norm_init(cfg.d_model, cfg.norm_type),
                "mlp": init_mlp(ks[1], cfg)}
    if kind == "moe":
        return {"norm1": norm_init(cfg.d_model, cfg.norm_type),
                "attn": init_attention(ks[0], cfg),
                "norm2": norm_init(cfg.d_model, cfg.norm_type),
                "moe": init_moe(ks[1], cfg)}
    if kind == "pair":
        return {"moe_blk": _init_one(ks[0], cfg, "moe"),
                "dense_blk": _init_one(ks[1], cfg, "dense")}
    if kind == "ssm":
        return {"norm1": norm_init(cfg.d_model, cfg.norm_type),
                "ssm": init_ssm(ks[0], cfg)}
    if kind == "hybrid_super":
        k = cfg.hybrid_attn_every
        sub = jax.vmap(lambda kk: {
            "norm1": norm_init(cfg.d_model, cfg.norm_type),
            "ssm": init_ssm(kk, cfg),
        })(jax.random.split(ks[0], k))
        return {"layers": sub}
    raise ValueError(kind)


def init_segment(key, cfg: ModelConfig, kind: str, n: int):
    return jax.vmap(lambda k: _init_one(k, cfg, kind))(jax.random.split(key, n))


def init_shared(key, cfg: ModelConfig):
    """Zamba2 shared attention+MLP block (weights tied across applications)."""
    if cfg.family != "hybrid":
        return None
    k1, k2 = jax.random.split(key)
    return {"norm": norm_init(cfg.d_model, cfg.norm_type),
            "attn": init_attention(k1, cfg),
            "norm2": norm_init(cfg.d_model, cfg.norm_type),
            "mlp": init_mlp(k2, cfg)}


# --- caches --------------------------------------------------------------------------

KV_DTYPES = ("auto", "int8")


def kv_cache_dtype(cfg: ModelConfig, dtype):
    """Storage dtype for k/v cache leaves: `cfg.kv_dtype` ("auto" resolves to
    the compute dtype; "int8" stores quantized k/v plus f32 scale leaves)."""
    if cfg.kv_dtype == "auto":
        return dtype
    if cfg.kv_dtype == "int8":
        if cfg.attn_type == "mla":
            raise ValueError(
                "kv_dtype='int8' is not supported with attn_type='mla' "
                "(the latent cache feeds back through projections, not raw k/v)")
        return jnp.int8
    raise ValueError(
        f"unknown kv_dtype {cfg.kv_dtype!r}; valid: {list(KV_DTYPES)}")


def _kv_cache_shape(cfg: ModelConfig, batch: int, s_max: int):
    if cfg.attn_type == "mla":
        return {"latent": (batch, s_max, cfg.kv_lora_rank + cfg.qk_rope_dim)}
    return {"k": (batch, s_max, cfg.num_kv_heads, cfg.head_dim),
            "v": (batch, s_max, cfg.num_kv_heads, cfg.head_dim)}


def init_cache_segment(cfg: ModelConfig, kind: str, n: int, batch: int,
                       s_max: int, dtype=jnp.bfloat16):
    """Cache pytree for one segment (leading dim n, scanned with the layers)."""
    def kv():
        store = kv_cache_dtype(cfg, dtype)
        leaves = {k: jnp.zeros((n,) + shp, store)
                  for k, shp in _kv_cache_shape(cfg, batch, s_max).items()}
        if store == jnp.int8:
            # absmax scale per (token, kv_head): head_dim is the reduce axis
            for name in ("k", "v"):
                leaves[f"{name}_scale"] = jnp.zeros(
                    (n, batch, s_max, cfg.num_kv_heads), jnp.float32)
        return leaves

    if kind in ("dense", "moe"):
        return kv()
    if kind == "pair":
        return {"moe_blk": kv(), "dense_blk": kv()}
    if kind == "ssm":
        c = init_ssm_cache(cfg, batch, dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), c)
    if kind == "hybrid_super":
        c = init_ssm_cache(cfg, batch, dtype)
        ssm = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n, cfg.hybrid_attn_every) + x.shape), c)
        return {"ssm": ssm, "shared_attn": kv()}
    raise ValueError(kind)


# --- per-kind apply ------------------------------------------------------------------

def _apply_attn_block(p, x, cfg, positions, cache, cache_index,
                      block_tables=None):
    h, new_cache = apply_attention(
        p["attn"], norm_apply(p["norm1"], x, cfg.norm_type), cfg,
        positions=positions, cache=cache, cache_index=cache_index,
        block_tables=block_tables)
    return h, new_cache


def _apply_core(p, x, cfg: ModelConfig, kind: str, *, positions,
                cache=None, cache_index=None, shared=None, decode=False,
                block_tables=None):
    """One layer.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe"):
        if cfg.seq_parallel and x.shape[1] > 1:
            from ..parallel.sharding import constrain
            x = constrain(x, "btd_sp")
        attn_out, new_cache = _apply_attn_block(p, x, cfg, positions, cache,
                                                cache_index, block_tables)
        if cfg.parallel_layers:
            # y = x + Attn(N(x)) + MLP(N(x))   (§VI-C1; same first norm)
            mix_in = norm_apply(p["norm1"], x, cfg.norm_type)
        else:
            x = x + attn_out
            mix_in = norm_apply(p["norm2"], x, cfg.norm_type)
        if kind == "moe":
            if cfg.moe_dispatch == "shard_map":
                from .moe_shardmap import apply_moe_shardmap
                mix_out, aux = apply_moe_shardmap(p["moe"], mix_in, cfg)
            else:
                mix_out, aux = apply_moe(p["moe"], mix_in, cfg)
        else:
            mix_out = apply_mlp(p["mlp"], mix_in, cfg)
        x = x + mix_out + (attn_out if cfg.parallel_layers else 0)
        return x, new_cache, aux

    if kind == "pair":
        x, c1, a1 = _apply_core(p["moe_blk"], x, cfg, "moe", positions=positions,
                                cache=None if cache is None else cache["moe_blk"],
                                cache_index=cache_index, decode=decode,
                                block_tables=block_tables)
        x, c2, a2 = _apply_core(p["dense_blk"], x, cfg, "dense", positions=positions,
                                cache=None if cache is None else cache["dense_blk"],
                                cache_index=cache_index, decode=decode,
                                block_tables=block_tables)
        nc = None if cache is None else {"moe_blk": c1, "dense_blk": c2}
        return x, nc, a1 + a2

    if kind == "ssm":
        xin = norm_apply(p["norm1"], x, cfg.norm_type)
        if decode:
            y, new_c = decode_ssm(p["ssm"], xin, cfg, cache)
        else:
            y, (st, tails) = apply_ssm(p["ssm"], xin, cfg,
                                       state=None if cache is None else cache["state"])
            new_c = None if cache is None else jax.tree.map(
                lambda old, new: new.astype(old.dtype),
                cache, {"state": st, **tails})
        return x + y, new_c, aux

    if kind == "hybrid_super":
        assert block_tables is None, \
            "block-table KV paging does not support ssm/hybrid caches"
        k = cfg.hybrid_attn_every
        new_ssm = [] if cache is not None else None
        for i in range(k):
            pi = jax.tree.map(lambda t: t[i], p["layers"])
            ci = None if cache is None else jax.tree.map(lambda t: t[i], cache["ssm"])
            xin = norm_apply(pi["norm1"], x, cfg.norm_type)
            if decode:
                y, nc = decode_ssm(pi["ssm"], xin, cfg, ci)
            else:
                y, (st, tails) = apply_ssm(pi["ssm"], xin, cfg,
                                           state=None if ci is None else ci["state"])
                nc = None if ci is None else jax.tree.map(
                    lambda old, new: new.astype(old.dtype),
                    ci, {"state": st, **tails})
            x = x + y
            if cache is not None:
                new_ssm.append(nc)
        # shared attention+MLP block (weights tied across all applications)
        sc = None if cache is None else cache["shared_attn"]
        attn_out, new_kv = apply_attention(
            shared["attn"], norm_apply(shared["norm"], x, cfg.norm_type), cfg,
            positions=positions, cache=sc, cache_index=cache_index)
        x = x + attn_out
        x = x + apply_mlp(shared["mlp"],
                          norm_apply(shared["norm2"], x, cfg.norm_type), cfg)
        if cache is None:
            return x, None, aux
        stacked_ssm = jax.tree.map(lambda *ts: jnp.stack(ts), *new_ssm)
        return x, {"ssm": stacked_ssm, "shared_attn": new_kv}, aux

    raise ValueError(kind)


# --- scanned stack -------------------------------------------------------------------

def apply_stack(segments_params, cfg: ModelConfig, x, *, positions,
                caches=None, cache_index=None, decode=False, shared=None,
                remat: str = "none", block_tables=None):
    """Run all segments.  segments_params: list of (kind, stacked_params).

    caches: list aligned with segments (or None).
    block_tables: (b, max_blocks) block-pool indirection, shared by every
    layer (scan-closure captured — all layers' kv leaves use one table).
    Returns (x, new_caches, total_aux).
    """
    total_aux = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None

    for si, (kind, sp) in enumerate(segments_params):
        seg_cache = None if caches is None else caches[si]

        def body(carry, xs, _kind=kind):
            h, aux = carry
            p_l, c_l = xs
            h, nc, a = _apply_core(p_l, h, cfg, _kind, positions=positions,
                                   cache=c_l, cache_index=cache_index,
                                   shared=shared, decode=decode,
                                   block_tables=block_tables)
            return (h, aux + a), nc

        if remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif remat == "dots":
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

        xs = (sp, seg_cache)
        (x, total_aux), seg_new_cache = jax.lax.scan(body, (x, total_aux), xs)
        if new_caches is not None:
            new_caches.append(seg_new_cache)
    return x, new_caches, total_aux
