"""Full models: decoder-only LM, VLM (patch-embed stub), encoder-decoder
(whisper, conv-frontend stub), with train/prefill/decode entry points.

Parameter pytree layout:
  embed        (v, h)            token embedding (TP: vocab-sharded)
  pos_embed    (max_pos, h)      only for pos_emb == "learned"
  seg{i}       stacked params    one entry per stack_plan segment
  shared       zamba2 shared attention block (hybrid only)
  final_norm
  lm_head      (h, v)            untied output head (TP: vocab-sharded)
  encoder      whisper encoder stack (+ cross-attn lives in decoder blocks)
  mtp          deepseek multi-token-prediction head
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import apply_attention, init_attention
from .blocks import (apply_stack, init_cache_segment, init_segment,
                     init_shared, stack_plan, _init_one, _apply_core)
from .layers import compute_dtype, dense_init, embed_init, norm_apply, norm_init
from .linear import linear, resolve_impl


def init_lm(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 16)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.padded_vocab_size, cfg.d_model)}
    if cfg.pos_emb == "learned":
        max_pos = max(cfg.encoder_seq, 8192)
        params["pos_embed"] = embed_init(ks[1], max_pos, cfg.d_model)
    for i, (kind, n) in enumerate(stack_plan(cfg)):
        params[f"seg{i}"] = init_segment(ks[2 + i], cfg, kind, n)
    sh = init_shared(ks[10], cfg)
    if sh is not None:
        params["shared"] = sh
    params["final_norm"] = norm_init(cfg.d_model, cfg.norm_type)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[11], cfg.d_model, cfg.padded_vocab_size)
    if cfg.is_encoder_decoder:
        params["encoder"] = _init_encoder(ks[12], cfg)
        params["xattn"] = jax.vmap(
            lambda k: {"norm": norm_init(cfg.d_model, cfg.norm_type),
                       "attn": init_attention(k, cfg)}
        )(jax.random.split(ks[13], cfg.num_layers))
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": dense_init(ks[14], 2 * cfg.d_model, cfg.d_model),
            "block": _init_one(ks[15], cfg, "dense"),
            "norm": norm_init(cfg.d_model, cfg.norm_type),
        }
    return params


def _init_encoder(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {"seg0": init_segment(ks[0], cfg, "dense", cfg.num_encoder_layers),
            "final_norm": norm_init(cfg.d_model, cfg.norm_type)}


# --- caches --------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    return [init_cache_segment(cfg, kind, n, batch, s_max, dtype)
            for kind, n in stack_plan(cfg)]


# --- encoder (whisper; conv frontend is a stub: frames are precomputed embeddings) ---

def apply_encoder(params, frames, cfg: ModelConfig, remat: str = "none"):
    """frames: (b, enc_seq, h) precomputed log-mel conv embeddings (stub)."""
    dt = compute_dtype(cfg.dtype)
    x = frames.astype(dt)
    if cfg.pos_emb == "learned":
        pos = jnp.arange(x.shape[1])
        x = x + params["pos_embed"][pos].astype(dt)[None]
    enc = params["encoder"]
    positions = jnp.arange(x.shape[1])

    def body(carry, p_l):
        h, _ = carry
        hn = norm_apply(p_l["norm1"], h, cfg.norm_type)
        a, _ = apply_attention(p_l["attn"], hn, cfg, positions=positions, causal=False)
        h = h + a
        from .mlp import apply_mlp
        h = h + apply_mlp(p_l["mlp"], norm_apply(p_l["norm2"], h, cfg.norm_type), cfg)
        return (h, jnp.zeros(())), None

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros(())), enc["seg0"])
    return norm_apply(enc["final_norm"], x, cfg.norm_type)


# --- main forward --------------------------------------------------------------------

def apply_lm(params, tokens, cfg: ModelConfig, *,
             positions=None, caches=None, cache_index=None, decode=False,
             remat: str = "none", patch_embeds=None, encoder_frames=None,
             enc_out=None, return_hidden: bool = False, block_tables=None):
    """tokens: (b, s) int32.  Returns (logits, new_caches, aux, [hidden]).

    patch_embeds: (b, n_patches, h) VLM stub — prepended to the token stream.
    encoder_frames: (b, enc_seq, h) whisper stub — runs the encoder.
    enc_out: precomputed encoder output (decode steps reuse it).
    block_tables: (b, max_blocks) — caches are a physical KV *block pool*
    (kv leaves (n, num_blocks, block_size, kv, hd)) and row b's logical
    block j lives at block_tables[b, j]; single-token decode only.
    """
    from ..parallel.sharding import constrain
    dt = compute_dtype(cfg.dtype)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dt) * jnp.sqrt(float(cfg.d_model)).astype(dt)

    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(dt), x], axis=1)
        s = x.shape[1]
    # anchor the activation layout: batch over DP axes, seq/dim unsharded.
    # without this single constraint the SPMD partitioner is free to
    # replicate the whole forward pass dp-fold (observed; EXPERIMENTS.md §Perf)
    x = constrain(x, "btd")

    if positions is None:
        start = jnp.asarray(0 if cache_index is None else cache_index)
        # vector cache_index (serving engine): per-row (b, s) positions
        positions = (start[:, None] + jnp.arange(s)[None] if start.ndim
                     else start + jnp.arange(s))
    if cfg.pos_emb == "learned":
        pe = params["pos_embed"][positions].astype(dt)
        x = x + (pe if positions.ndim > 1 else pe[None])

    if cfg.is_encoder_decoder and enc_out is None:
        assert encoder_frames is not None, "whisper needs encoder frames"
        enc_out = apply_encoder(params, encoder_frames, cfg, remat)

    segs = [(kind, params[f"seg{i}"]) for i, (kind, n) in enumerate(stack_plan(cfg))]
    x, new_caches, aux = apply_stack(
        segs, cfg, x, positions=positions, caches=caches,
        cache_index=cache_index, decode=decode,
        shared=params.get("shared"), remat=remat, block_tables=block_tables)

    # whisper cross-attention: applied as a post-pass per decoder layer would
    # interleave; for the stub we apply the stacked cross-attn blocks after the
    # self-attn stack (documented simplification — same GEMM inventory).
    if cfg.is_encoder_decoder:
        def xbody(h, p_l):
            hn = norm_apply(p_l["norm"], h, cfg.norm_type)
            a, _ = apply_attention(p_l["attn"], hn, cfg,
                                   positions=positions, causal=False,
                                   kv_input=enc_out)
            return h + a, None
        x, _ = jax.lax.scan(xbody, x, params["xattn"])

    hidden = x
    x = constrain(norm_apply(params["final_norm"], x, cfg.norm_type), "btd")
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = constrain(linear(x, head, impl=resolve_impl(cfg)), "btv")
    if cfg.padded_vocab_size != cfg.vocab_size:
        # mask the padded vocabulary tail (paper §VI-B vocab padding)
        pad_mask = jnp.arange(cfg.padded_vocab_size) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    if return_hidden:
        return logits, new_caches, aux, hidden
    return logits, new_caches, aux


# --- loss ----------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """Mean next-token cross entropy.  logits: (b, s, v); labels: (b, s)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.clip(jnp.sum(mask), 1.0)


def lm_loss(params, batch, cfg: ModelConfig, remat: str = "none"):
    """batch: dict(tokens, labels[, loss_mask, patch_embeds, encoder_frames]).

    Returns (loss, metrics).  MTP (deepseek) adds a depth-1 future-token loss.
    """
    logits, _, aux, hidden = apply_lm(
        params, batch["tokens"], cfg, remat=remat,
        patch_embeds=batch.get("patch_embeds"),
        encoder_frames=batch.get("encoder_frames"),
        return_hidden=True)
    mask = batch.get("loss_mask")
    npatch = 0 if batch.get("patch_embeds") is None else batch["patch_embeds"].shape[1]
    if npatch:
        logits = logits[:, npatch:]
        hidden = hidden[:, npatch:]
    loss = softmax_xent(logits[:, :-1], batch["labels"][:, 1:],
                        None if mask is None else mask[:, 1:])
    metrics = {"lm_loss": loss}
    if cfg.num_experts:
        metrics["aux_loss"] = aux
        loss = loss + 0.001 * aux
    if cfg.mtp_depth and "mtp" in params:
        dt = compute_dtype(cfg.dtype)
        emb_next = params["embed"][batch["labels"]].astype(dt)
        mtp_in = jnp.concatenate([hidden.astype(dt), emb_next], axis=-1)
        mtp_in = linear(mtp_in, params["mtp"]["proj"], impl=resolve_impl(cfg))
        pos = jnp.arange(mtp_in.shape[1])
        h2, _, _ = _apply_core(params["mtp"]["block"], mtp_in, cfg, "dense",
                               positions=pos)
        h2 = norm_apply(params["mtp"]["norm"], h2, cfg.norm_type)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        mtp_logits = linear(h2, head, impl=resolve_impl(cfg))
        mtp = softmax_xent(mtp_logits[:, :-2], batch["labels"][:, 2:])
        metrics["mtp_loss"] = mtp
        loss = loss + 0.3 * mtp
    metrics["loss"] = loss
    return loss, metrics
