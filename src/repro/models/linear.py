"""Unified linear-execution layer: every model GEMM routes through here.

The paper's thesis is that transformer throughput is decided by the shapes
of a handful of GEMMs; this module is the single chokepoint where those
GEMMs actually execute, so tile-quantization waste is paid (and measured) in
one place.  `linear` flattens (b, s, h) activations to 2-D — producing the
exact (m, k, n) key the autotuner writes — and selects the execution path
from `ModelConfig.linear_impl` (mirroring `attn_impl`):

  "jnp"    — XLA `x @ w` (CPU/dry-run default; identical to the pre-refactor
             inline GEMMs, including gradients)
  "pallas" — the tile-aligned Pallas matmul kernel at its 128^3 defaults
  "tuned"  — Pallas + per-(m, k, n, dtype, hw) autotuning-cache blocks
  "fused"  — tuned dispatch everywhere, plus the fused SwiGLU/MLP Pallas
             kernel (kernels/fused_mlp) for the MLP gate/up pair

The Pallas paths carry a `jax.custom_vjp` whose backward routes the dgrad
and wgrad GEMMs back through the same dispatch — transposed shapes make
their own cache lookups, so forward and backward tile geometries tune
independently (as with flash attention's split fwd/bwd entries).

Weight casting to the activation dtype happens here (params are f32 master
copies), so call sites pass raw param leaves.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ops import default_interpret
from ..kernels.fused_mlp.ops import fused_mlp_hidden
from ..kernels.matmul.ops import matmul

LINEAR_IMPLS = ("jnp", "pallas", "tuned", "fused")


def resolve_impl(cfg) -> str:
    """ModelConfig -> linear_impl, tolerating configs predating the field."""
    return getattr(cfg, "linear_impl", "jnp")


def _check_impl(impl: str) -> None:
    if impl not in LINEAR_IMPLS:
        raise ValueError(
            f"unknown linear_impl {impl!r}; valid: {list(LINEAR_IMPLS)}")


class _LinearConfig(NamedTuple):
    """Static dispatch config threaded through the custom_vjp (hashable)."""
    tuned: bool
    interpret: bool
    hw_name: Optional[str]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pallas_linear(cfg: _LinearConfig, x2, w):
    return matmul(x2, w, tuned=cfg.tuned, interpret=cfg.interpret,
                  hw_name=cfg.hw_name)


def _pallas_linear_fwd(cfg, x2, w):
    return _pallas_linear(cfg, x2, w), (x2, w)


def _pallas_linear_bwd(cfg, res, g):
    x2, w = res
    # both transposed GEMMs stay on the Pallas path and key the cache with
    # their own (m, k, n): dgrad (m, n, k) and wgrad (k, m, n) tune
    # independently of the forward
    dx = matmul(g, w.T, tuned=cfg.tuned, interpret=cfg.interpret,
                hw_name=cfg.hw_name)
    dw = matmul(x2.T, g, tuned=cfg.tuned, interpret=cfg.interpret,
                hw_name=cfg.hw_name)
    return dx.astype(x2.dtype), dw.astype(w.dtype)


_pallas_linear.defvjp(_pallas_linear_fwd, _pallas_linear_bwd)


def linear(x, w, *, impl: str = "jnp", hw_name: Optional[str] = None):
    """y = x @ w with dispatched execution.  x: (..., k); w: (k, n).

    Flattens the leading dims to one m axis before dispatch, so a (b, s, h)
    activation keys the tuning cache as (b*s, h, n) — exactly the shape
    `tuning.search.autotune_matmul` writes (the >2-D cache-miss fix).
    """
    _check_impl(impl)
    # named_scope is trace-time HLO metadata only (no runtime cost and no
    # program divergence when obs toggles), so it is applied unconditionally:
    # XLA profiles attribute every GEMM to its dispatch impl
    with jax.named_scope(f"linear_{impl}"):
        w = w.astype(x.dtype)
        if impl == "jnp":
            return x @ w
        lead, k = x.shape[:-1], x.shape[-1]
        cfg = _LinearConfig(tuned=impl in ("tuned", "fused"),
                            interpret=default_interpret(), hw_name=hw_name)
        out = _pallas_linear(cfg, x.reshape(-1, k), w)
        return out.reshape(*lead, w.shape[-1])


def expert_linear(x, w, *, impl: str = "jnp", hw_name: Optional[str] = None):
    """Batched per-expert GEMM: x (e, m, k) @ w (e, k, n) -> (e, m, n).

    The jnp path keeps the einsum (XLA lowers it to one batched GEMM, the
    `moe_expert_*` entry core/transformer_gemms enumerates).  Pallas paths
    run one kernel per expert under `lax.map` — the TPU grid is sequential
    per core anyway, and every expert shares one (m, k, n) cache key.
    """
    _check_impl(impl)
    with jax.named_scope(f"expert_linear_{impl}"):
        w = w.astype(x.dtype)
        if impl == "jnp":
            return jnp.einsum("emk,ekn->emn", x, w)
        cfg = _LinearConfig(tuned=impl in ("tuned", "fused"),
                            interpret=default_interpret(), hw_name=hw_name)
        return jax.lax.map(lambda xw: _pallas_linear(cfg, xw[0], xw[1]),
                           (x, w))


def fused_mlp(x, p, cfg, *, impl: Optional[str] = None,
              hw_name: Optional[str] = None):
    """Full MLP block through the fused Pallas hidden kernel + dispatched
    down projection.  p: {w_gate (swiglu), w_up, w_down}; x: (..., h).

    The gate/up GEMM pair and the elementwise combine run as ONE Pallas
    kernel (kernels/fused_mlp) with its recompute-based custom-VJP backward;
    both the hidden kernel and the down GEMM consult the tuning cache.
    """
    impl = impl or resolve_impl(cfg)
    dt = x.dtype
    with jax.named_scope("fused_mlp"):
        w_gate = p["w_gate"].astype(dt) if cfg.mlp_type == "swiglu" else None
        hidden = fused_mlp_hidden(
            x, w_gate, p["w_up"].astype(dt), mlp_type=cfg.mlp_type,
            tuned=True, interpret=default_interpret(), hw_name=hw_name)
        return linear(hidden, p["w_down"], impl="tuned", hw_name=hw_name)


def expert_fused_hidden(x, w_gate, w_up, *, mlp_type: str,
                        hw_name: Optional[str] = None):
    """Per-expert fused hidden: x (e, m, h) with (e, h, f) expert weights ->
    (e, m, f), one fused kernel per expert under `lax.map` (the MoE
    counterpart of `fused_mlp`'s hidden half)."""
    dt = x.dtype
    interp = default_interpret()
    wu = w_up.astype(dt)
    if mlp_type == "swiglu":
        return jax.lax.map(
            lambda t: fused_mlp_hidden(t[0], t[1], t[2], mlp_type=mlp_type,
                                       tuned=True, interpret=interp,
                                       hw_name=hw_name),
            (x, w_gate.astype(dt), wu))
    return jax.lax.map(
        lambda t: fused_mlp_hidden(t[0], None, t[1], mlp_type=mlp_type,
                                   tuned=True, interpret=interp,
                                   hw_name=hw_name),
        (x, wu))
