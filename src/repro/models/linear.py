"""Unified linear-execution layer: every model GEMM routes through here.

The paper's thesis is that transformer throughput is decided by the shapes
of a handful of GEMMs; this module is the single chokepoint where those
GEMMs actually execute, so tile-quantization waste is paid (and measured) in
one place.  `linear` flattens (b, s, h) activations to 2-D — producing the
exact (m, k, n) key the autotuner writes — and selects the execution path
from `ModelConfig.linear_impl` (mirroring `attn_impl`):

  "jnp"    — XLA `x @ w` (CPU/dry-run default; identical to the pre-refactor
             inline GEMMs, including gradients)
  "pallas" — the tile-aligned Pallas matmul kernel at its 128^3 defaults
  "tuned"  — Pallas + per-(m, k, n, dtype, hw) autotuning-cache blocks
  "fused"  — tuned dispatch everywhere, plus the fused SwiGLU/MLP Pallas
             kernel (kernels/fused_mlp) for the MLP gate/up pair
  "quantized" — the int8 weight path (kernels/quantized): per-channel
             weight scales, dynamic per-row activation quantization, i32
             accumulate, f32 de-scale.  Weights may be raw float leaves
             (quantized on the fly — the train-step fallback) or
             `QuantizedLinear` containers from `quantize_linear_params`
             (quantize-once at load; scales ride alongside the payload)

The Pallas paths carry a `jax.custom_vjp` whose backward routes the dgrad
and wgrad GEMMs back through the same dispatch — transposed shapes make
their own cache lookups, so forward and backward tile geometries tune
independently (as with flash attention's split fwd/bwd entries).  The
quantized path is inference-first: its backward falls back to the
high-precision tuned matmul route (a straight-through estimator — the int8
rounding is treated as identity for gradient purposes).

Weight casting to the activation dtype happens here (params are f32 master
copies), so call sites pass raw param leaves.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.flash_attention.ops import default_interpret
from ..kernels.fused_mlp.ops import fused_mlp_hidden
from ..kernels.fused_mlp.ref import fused_mlp_hidden_ref
from ..kernels.matmul.ops import matmul
from ..kernels.quantized.ops import int8_fused_mlp_hidden, int8_matmul
from ..quant import QuantizedTensor, quantize_weight

LINEAR_IMPLS = ("jnp", "pallas", "tuned", "fused", "quantized")

# The QuantizedLinear weight container IS repro.quant's QuantizedTensor —
# re-exported under the dispatch-layer name model code uses.
QuantizedLinear = QuantizedTensor


def resolve_impl(cfg) -> str:
    """ModelConfig -> linear_impl, tolerating configs predating the field."""
    return getattr(cfg, "linear_impl", "jnp")


def _check_impl(impl: str) -> None:
    if impl not in LINEAR_IMPLS:
        raise ValueError(
            f"unknown linear_impl {impl!r}; valid: {list(LINEAR_IMPLS)}")


class _LinearConfig(NamedTuple):
    """Static dispatch config threaded through the custom_vjp (hashable)."""
    tuned: bool
    interpret: bool
    hw_name: Optional[str]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pallas_linear(cfg: _LinearConfig, x2, w):
    return matmul(x2, w, tuned=cfg.tuned, interpret=cfg.interpret,
                  hw_name=cfg.hw_name)


def _pallas_linear_fwd(cfg, x2, w):
    return _pallas_linear(cfg, x2, w), (x2, w)


def _pallas_linear_bwd(cfg, res, g):
    x2, w = res
    # both transposed GEMMs stay on the Pallas path and key the cache with
    # their own (m, k, n): dgrad (m, n, k) and wgrad (k, m, n) tune
    # independently of the forward
    dx = matmul(g, w.T, tuned=cfg.tuned, interpret=cfg.interpret,
                hw_name=cfg.hw_name)
    dw = matmul(x2.T, g, tuned=cfg.tuned, interpret=cfg.interpret,
                hw_name=cfg.hw_name)
    return dx.astype(x2.dtype), dw.astype(w.dtype)


_pallas_linear.defvjp(_pallas_linear_fwd, _pallas_linear_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _quantized_linear(cfg: _LinearConfig, x2, w):
    """Float-weight quantized linear: weight quantizes per output channel on
    the fly, activation per row inside the kernel wrapper."""
    return int8_matmul(x2, w, tuned=cfg.tuned, interpret=cfg.interpret,
                       hw_name=cfg.hw_name)


def _quantized_linear_fwd(cfg, x2, w):
    return _quantized_linear(cfg, x2, w), (x2, w)


def _quantized_linear_bwd(cfg, res, g):
    x2, w = res
    # straight-through: int8 rounding treated as identity, both grad GEMMs
    # take the high-precision tuned route (their own cache keys)
    dx = matmul(g, w.T, tuned=cfg.tuned, interpret=cfg.interpret,
                hw_name=cfg.hw_name)
    dw = matmul(x2.T, g, tuned=cfg.tuned, interpret=cfg.interpret,
                hw_name=cfg.hw_name)
    return dx.astype(x2.dtype), dw.astype(w.dtype)


_quantized_linear.defvjp(_quantized_linear_fwd, _quantized_linear_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _quantized_linear_frozen(cfg: _LinearConfig, x2, wq, wscale):
    """Prequantized-weight linear (QuantizedLinear container): the int8
    payload and scales pass straight to the kernel."""
    return int8_matmul(x2, QuantizedTensor(wq, wscale, -2), tuned=cfg.tuned,
                       interpret=cfg.interpret, hw_name=cfg.hw_name)


def _quantized_frozen_fwd(cfg, x2, wq, wscale):
    return _quantized_linear_frozen(cfg, x2, wq, wscale), (x2, wq, wscale)


def _quantized_frozen_bwd(cfg, res, g):
    x2, wq, wscale = res
    w = (wq.astype(jnp.float32) * wscale).astype(x2.dtype)
    dx = matmul(g, w.T, tuned=cfg.tuned, interpret=cfg.interpret,
                hw_name=cfg.hw_name)
    # int8 payloads carry float0 tangents (non-differentiable by
    # construction); the scales get symbolic zeros
    return (dx.astype(x2.dtype), np.zeros(wq.shape, jax.dtypes.float0),
            jnp.zeros_like(wscale))


_quantized_linear_frozen.defvjp(_quantized_frozen_fwd, _quantized_frozen_bwd)


# Param-leaf names that are (k, n) GEMM weights consumed through `linear()`.
# Embeddings (indexed, and transposed for tied lm_heads), conv kernels, norm
# gains, and 3-D expert stacks (quantized on the fly per expert) are NOT
# here — quantizing them would break their non-GEMM consumers.
QUANT_WEIGHT_KEYS = frozenset({
    "wq", "wk", "wv", "wo",                       # attention projections
    "wq_down", "wq_up", "wkv_down", "wk_up", "wv_up",  # MLA projections
    "w_gate", "w_up", "w_down",                   # MLP
    "in_z", "in_x", "in_B", "in_C", "in_dt", "out_proj",  # SSM projections
    "lm_head",                                    # untied output head
})


def quantize_linear_params(params, dtype: str = "int8"):
    """Quantize-once-at-load: replace every 2-D float GEMM weight leaf
    (matched by name, see `QUANT_WEIGHT_KEYS`) with a `QuantizedLinear`
    container — int8 payload + per-output-channel f32 scales.
    `linear(impl="quantized")` consumes the containers directly, skipping
    the per-call weight quantization; all other leaves pass through."""
    def one(path, leaf):
        name = next((p.key for p in reversed(path)
                     if isinstance(p, jax.tree_util.DictKey)), None)
        if (name in QUANT_WEIGHT_KEYS and getattr(leaf, "ndim", 0) == 2
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return quantize_weight(leaf, dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(one, params)


def linear(x, w, *, impl: str = "jnp", hw_name: Optional[str] = None):
    """y = x @ w with dispatched execution.  x: (..., k); w: (k, n).

    Flattens the leading dims to one m axis before dispatch, so a (b, s, h)
    activation keys the tuning cache as (b*s, h, n) — exactly the shape
    `tuning.search.autotune_matmul` writes (the >2-D cache-miss fix).
    """
    _check_impl(impl)
    # named_scope is trace-time HLO metadata only (no runtime cost and no
    # program divergence when obs toggles), so it is applied unconditionally:
    # XLA profiles attribute every GEMM to its dispatch impl
    with jax.named_scope(f"linear_{impl}"):
        lead, k = x.shape[:-1], x.shape[-1]
        if impl == "quantized":
            cfg = _LinearConfig(tuned=True, interpret=default_interpret(),
                                hw_name=hw_name)
            if isinstance(w, QuantizedTensor):
                out = _quantized_linear_frozen(
                    cfg, x.reshape(-1, k), w.q, w.scale.reshape(1, -1))
                return out.reshape(*lead, w.q.shape[-1])
            out = _quantized_linear(cfg, x.reshape(-1, k), w.astype(x.dtype))
            return out.reshape(*lead, w.shape[-1])
        w = w.astype(x.dtype)
        if impl == "jnp":
            return x @ w
        cfg = _LinearConfig(tuned=impl in ("tuned", "fused"),
                            interpret=default_interpret(), hw_name=hw_name)
        out = _pallas_linear(cfg, x.reshape(-1, k), w)
        return out.reshape(*lead, w.shape[-1])


def expert_linear(x, w, *, impl: str = "jnp", hw_name: Optional[str] = None):
    """Batched per-expert GEMM: x (e, m, k) @ w (e, k, n) -> (e, m, n).

    The jnp path keeps the einsum (XLA lowers it to one batched GEMM, the
    `moe_expert_*` entry core/transformer_gemms enumerates).  Pallas paths
    run one kernel per expert under `lax.map` — the TPU grid is sequential
    per core anyway, and every expert shares one (m, k, n) cache key.
    """
    _check_impl(impl)
    with jax.named_scope(f"expert_linear_{impl}"):
        w = w.astype(x.dtype)
        if impl == "jnp":
            return jnp.einsum("emk,ekn->emn", x, w)
        if impl == "quantized":
            qcfg = _LinearConfig(tuned=True, interpret=default_interpret(),
                                 hw_name=hw_name)
            # per-expert dynamic quantization: every expert shares one
            # (m, k, n) cache key, like the float Pallas path below
            return jax.lax.map(
                lambda xw: _quantized_linear(qcfg, xw[0], xw[1]), (x, w))
        cfg = _LinearConfig(tuned=impl in ("tuned", "fused"),
                            interpret=default_interpret(), hw_name=hw_name)
        return jax.lax.map(lambda xw: _pallas_linear(cfg, xw[0], xw[1]),
                           (x, w))


def fused_mlp(x, p, cfg, *, impl: Optional[str] = None,
              hw_name: Optional[str] = None):
    """Full MLP block through the fused Pallas hidden kernel + dispatched
    down projection.  p: {w_gate (swiglu), w_up, w_down}; x: (..., h).

    The gate/up GEMM pair and the elementwise combine run as ONE Pallas
    kernel (kernels/fused_mlp) with its recompute-based custom-VJP backward;
    both the hidden kernel and the down GEMM consult the tuning cache.
    """
    impl = impl or resolve_impl(cfg)
    dt = x.dtype
    with jax.named_scope("fused_mlp"):
        w_gate = p["w_gate"].astype(dt) if cfg.mlp_type == "swiglu" else None
        hidden = fused_mlp_hidden(
            x, w_gate, p["w_up"].astype(dt), mlp_type=cfg.mlp_type,
            tuned=True, interpret=default_interpret(), hw_name=hw_name)
        return linear(hidden, p["w_down"], impl="tuned", hw_name=hw_name)


class _QuantMLPConfig(NamedTuple):
    """Static dispatch config for the quantized fused-MLP custom_vjp."""
    mlp_type: str
    interpret: bool
    hw_name: Optional[str]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _quantized_hidden(cfg: _QuantMLPConfig, x2, w_gate, w_up):
    return int8_fused_mlp_hidden(x2, w_gate, w_up, mlp_type=cfg.mlp_type,
                                 tuned=True, interpret=cfg.interpret,
                                 hw_name=cfg.hw_name)


def _quantized_hidden_fwd(cfg, x2, w_gate, w_up):
    return _quantized_hidden(cfg, x2, w_gate, w_up), (x2, w_gate, w_up)


def _quantized_hidden_bwd(cfg, res, g):
    # straight-through fallback: recompute the hidden in high precision and
    # differentiate the reference (the int8 forward only affects the primal)
    x2, w_gate, w_up = res
    if w_gate is None:
        _, vjp = jax.vjp(
            lambda x, wu: fused_mlp_hidden_ref(x, None, wu, cfg.mlp_type),
            x2, w_up)
        dx, dwu = vjp(g)
        return dx.astype(x2.dtype), None, dwu.astype(w_up.dtype)
    _, vjp = jax.vjp(
        lambda x, wg, wu: fused_mlp_hidden_ref(x, wg, wu, cfg.mlp_type),
        x2, w_gate, w_up)
    dx, dwg, dwu = vjp(g)
    return (dx.astype(x2.dtype), dwg.astype(w_gate.dtype),
            dwu.astype(w_up.dtype))


_quantized_hidden.defvjp(_quantized_hidden_fwd, _quantized_hidden_bwd)


def quantized_mlp(x, p, cfg, *, hw_name: Optional[str] = None):
    """Full MLP block on the int8 path: the gate/up pair runs the int8
    fused-MLP kernel (one i32-accumulating pass), the down projection the
    quantized linear.  Float weight leaves quantize on the fly and keep the
    high-precision gradient fallback; `QuantizedLinear` containers (from
    `quantize_linear_params`) skip re-quantization — the inference path."""
    lead, h = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, h)
    w_gate = p.get("w_gate") if cfg.mlp_type == "swiglu" else None
    w_up = p["w_up"]
    with jax.named_scope("quantized_mlp"):
        if isinstance(w_up, QuantizedTensor):
            hidden = int8_fused_mlp_hidden(
                x2, w_gate, w_up, mlp_type=cfg.mlp_type, tuned=True,
                interpret=default_interpret(), hw_name=hw_name)
        else:
            qcfg = _QuantMLPConfig(cfg.mlp_type, default_interpret(), hw_name)
            hidden = _quantized_hidden(
                qcfg, x2,
                None if w_gate is None else w_gate.astype(x.dtype),
                w_up.astype(x.dtype))
        f = hidden.shape[-1]
        out = linear(hidden.reshape(*lead, f), p["w_down"], impl="quantized",
                     hw_name=hw_name)
        return out


def expert_fused_hidden(x, w_gate, w_up, *, mlp_type: str,
                        hw_name: Optional[str] = None):
    """Per-expert fused hidden: x (e, m, h) with (e, h, f) expert weights ->
    (e, m, f), one fused kernel per expert under `lax.map` (the MoE
    counterpart of `fused_mlp`'s hidden half)."""
    dt = x.dtype
    interp = default_interpret()
    wu = w_up.astype(dt)
    if mlp_type == "swiglu":
        return jax.lax.map(
            lambda t: fused_mlp_hidden(t[0], t[1], t[2], mlp_type=mlp_type,
                                       tuned=True, interpret=interp,
                                       hw_name=hw_name),
            (x, w_gate.astype(dt), wu))
    return jax.lax.map(
        lambda t: fused_mlp_hidden(t[0], None, t[1], mlp_type=mlp_type,
                                   tuned=True, interpret=interp,
                                   hw_name=hw_name),
        (x, wu))
