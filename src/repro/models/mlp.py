"""MLP variants: standard 2-matrix (gelu / squared-ReLU) and SwiGLU (3-matrix).

The SwiGLU d_ff choice is the subject of paper §VII-B: the 8h/3 heuristic
breaks GEMM alignment; configs should pick an aligned nearby d_ff (the
advisor's `_candidate_dff` search).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import activation, dense_init


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    h = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / (2 * cfg.num_layers) ** 0.5
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], h, f),
            "w_up": dense_init(ks[1], h, f),
            "w_down": dense_init(ks[2], f, h, scale=out_scale),
        }
    return {
        "w_up": dense_init(ks[0], h, f),
        "w_down": dense_init(ks[1], f, h, scale=out_scale),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
        u = x @ p["w_up"].astype(x.dtype)
        return (g * u) @ p["w_down"].astype(x.dtype)
    act = activation("relu2" if cfg.mlp_type == "relu2" else "gelu")
    u = act(x @ p["w_up"].astype(x.dtype))
    return u @ p["w_down"].astype(x.dtype)
