"""MLP variants: standard 2-matrix (gelu / squared-ReLU) and SwiGLU (3-matrix).

The SwiGLU d_ff choice is the subject of paper §VII-B: the 8h/3 heuristic
breaks GEMM alignment; configs should pick an aligned nearby d_ff (the
advisor's `_candidate_dff` search).
"""
from __future__ import annotations

import jax

from ..configs.base import ModelConfig
from .layers import activation, dense_init
from .linear import fused_mlp, linear, quantized_mlp, resolve_impl


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    h = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / (2 * cfg.num_layers) ** 0.5
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], h, f),
            "w_up": dense_init(ks[1], h, f),
            "w_down": dense_init(ks[2], f, h, scale=out_scale),
        }
    return {
        "w_up": dense_init(ks[0], h, f),
        "w_down": dense_init(ks[1], f, h, scale=out_scale),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    impl = resolve_impl(cfg)
    if impl == "fused":
        # gate+up GEMM pair and the silu*mul combine run as ONE Pallas
        # kernel (kernels/fused_mlp); the down GEMM dispatches tuned
        return fused_mlp(x, p, cfg)
    if impl == "quantized":
        # int8-weight fused hidden + quantized down projection
        return quantized_mlp(x, p, cfg)
    if cfg.mlp_type == "swiglu":
        g = jax.nn.silu(linear(x, p["w_gate"], impl=impl))
        u = linear(x, p["w_up"], impl=impl)
        return linear(g * u, p["w_down"], impl=impl)
    act = activation("relu2" if cfg.mlp_type == "relu2" else "gelu")
    u = act(linear(x, p["w_up"], impl=impl))
    return linear(u, p["w_down"], impl=impl)
