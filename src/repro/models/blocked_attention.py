"""Blocked online-softmax attention in pure XLA (lax.scan over KV blocks).

This is the lowering twin of kernels/flash_attention: identical algorithm
(FlashAttention-2 streaming softmax), expressed as jnp + lax.scan so it
lowers on any backend and differentiates.  On a TPU deployment the Pallas
kernel replaces it 1:1; for the dry-run roofline it is what converts naive
attention's O(s^2) HBM traffic into O(s·block) — the §VI-C3 hillclimb.

Selected via ModelConfig.attn_impl == "blocked" ("naive" = the paper's
Table II score/AOV BMM decomposition, the faithful baseline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def blocked_sdpa(q, k, v, *, causal: bool, q_pos=None, kv_len=None,
                 block_kv: int = 1024):
    """q: (b, sq, a, hd); k, v: (b, skv, kv, hd); GQA a % kv == 0.

    Returns (b, sq, a, v_hd).  Same contract as models.attention._sdpa.
    """
    b, sq, a, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    g = a // nkv
    blk = min(block_kv, skv)
    if skv % blk:
        pad = blk - skv % blk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        skv_p = skv + pad
    else:
        skv_p = skv
    nblk = skv_p // blk

    if q_pos is None:
        q_pos = jnp.arange(sq)
    limit = jnp.asarray(skv if kv_len is None else kv_len, jnp.int32)

    qg = (q.reshape(b, sq, nkv, g, hd) / jnp.sqrt(hd).astype(q.dtype))
    kb = k.reshape(b, nblk, blk, nkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, blk, nkv, vd).transpose(1, 0, 2, 3, 4)

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, start = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kc).astype(jnp.float32)
        pos = start + jnp.arange(blk)
        valid = pos[None, :] < limit
        if causal:
            valid = valid & (pos[None, :] <= q_pos[:, None])
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = alpha * l + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vc.dtype), vc)
        acc_new = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, nkv, g, sq, vd), q.dtype)
    starts = jnp.arange(nblk) * blk
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, starts))
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, a, vd)
