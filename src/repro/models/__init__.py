"""Pure-JAX model zoo: dense GQA, MLA, MoE, SSD/Mamba2, hybrid, enc-dec, VLM."""
from .lm import apply_lm, init_lm, init_caches, lm_loss, softmax_xent, apply_encoder
from .blocks import stack_plan
from .linear import (LINEAR_IMPLS, expert_linear, fused_mlp, linear,
                     resolve_impl)

__all__ = ["apply_lm", "init_lm", "init_caches", "lm_loss", "softmax_xent",
           "apply_encoder", "stack_plan", "LINEAR_IMPLS", "expert_linear",
           "fused_mlp", "linear", "resolve_impl"]
