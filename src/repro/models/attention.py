"""Attention variants: GQA/MHA (+QKV bias) and DeepSeek-V3 MLA.

Shapes follow the paper's Table II GEMM decomposition exactly:
  qkv:    (b*s, h) x (h, (a+2kv)*hd)
  score:  b*a BMMs of (s, hd) x (hd, s_kv)
  aov:    b*a BMMs of (s, s_kv) x (s_kv, hd)
  out:    (b*s, a*hd) x (a*hd, h)

Both a fused-reference path (jnp einsum, used on CPU and in the dry-run) and
the Pallas flash-attention path (TPU target) are provided; dispatch is by
`use_flash`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rotary, dense_init
from .linear import linear, resolve_impl

NEG_INF = -1e30


def init_gqa(key, cfg: ModelConfig):
    h, hd = cfg.d_model, cfg.head_dim
    a, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], h, a * hd),
        "wk": dense_init(ks[1], h, kv * hd),
        "wv": dense_init(ks[2], h, kv * hd),
        "wo": dense_init(ks[3], a * hd, h, scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((a * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    return p


def _sdpa(q, k, v, causal: bool, q_pos=None, kv_len=None,
          seq_sharded: bool = False):
    """Reference scaled-dot-product attention.

    q: (b, sq, a, hd); k, v: (b, skv, kv, hd).  GQA: a % kv == 0.
    q_pos: (sq,) absolute positions of the queries (for causal masking
    against a cache), or (b, sq) per-row positions (serving-engine slots at
    heterogeneous depths); kv_len: number of valid cache entries (scalar, or
    (b,) per-row).

    seq_sharded (decode): anchors K/V and the score matrix sequence-sharded
    on the model axis — the softmax then reduces over a sharded dim, which
    XLA lowers to partial max/sum + tiny all-reduces (distributed
    flash-decode) instead of gathering the 32k-deep cache per layer.
    """
    from ..parallel.sharding import constrain
    b, sq, a, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = a // nkv
    if seq_sharded:
        k = constrain(k, "bskh")
        v = constrain(v, "bskh")
    q = q.reshape(b, sq, nkv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    if seq_sharded:
        scores = constrain(scores, "bkgqs")
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    kv_pos = jnp.arange(skv)
    mask = None  # (B, sq, skv) with B in {1, b}, broadcast over head dims
    if causal:
        if q_pos is None:
            q_pos = jnp.arange(sq)
        if q_pos.ndim == 1:
            mask = (kv_pos[None, :] <= q_pos[:, None])[None]
        else:  # per-row query positions
            mask = kv_pos[None, None, :] <= q_pos[:, :, None]
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        live = (kv_pos[None, :] < kvl[:, None])[:, None, :] if kvl.ndim \
            else (kv_pos < kvl)[None, None, :]
        mask = live if mask is None else mask & live
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(b, sq, a, v.shape[-1])  # v head dim may differ (MLA)


def apply_gqa(p, x, cfg: ModelConfig, *, positions, causal=True,
              cache=None, cache_index=None, kv_input=None,
              block_tables=None):
    """x: (b, s, h).  Returns (out, new_cache).

    cache: dict(k=(b, s_max, kv, hd), v=...) or None.
    cache_index: write offset for decode — a scalar, or a (b,) vector of
    per-row offsets (serving engine: each cache slot at its own depth; the
    write is then a per-row one-hot scatter and requires s == 1, and
    `positions` should be the matching (b, s) per-row positions).
    kv_input: if set, keys/values come from this tensor (cross-attention).
    block_tables: (b, max_blocks) int32 — switches the cache to the
    *block-pool* layout (k/v: (num_blocks, block_size, kv, hd)): row b's
    logical kv block j lives in physical block `block_tables[b, j]`.
    Requires single-token decode with a (b,) vector cache_index; the new
    token is scattered into (table[b, ci//bs], ci % bs) — each live row's
    tail block is private by the pool's copy-on-write discipline, so rows
    never collide (dead rows all write the pool's garbage block, which is
    never read).
    """
    b, s, h = x.shape
    a, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    impl = resolve_impl(cfg)
    src = x if kv_input is None else kv_input
    q = linear(x, p["wq"], impl=impl)
    k = linear(src, p["wk"], impl=impl)
    v = linear(src, p["wv"], impl=impl)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, a, hd)
    k = k.reshape(b, src.shape[1], nkv, hd)
    v = v.reshape(b, src.shape[1], nkv, hd)
    if cfg.pos_emb == "rotary" and kv_input is None:
        q = apply_rotary(q, positions, cfg.rope_theta)
        k = apply_rotary(k, positions, cfg.rope_theta)
    new_cache = None
    kv_len = None
    # int8 KV cache (cfg.kv_dtype="int8"): quantize per (token, kv_head) on
    # write; dequantize on read (jnp paths) or in-kernel (paged kernels)
    quant = cache is not None and "k_scale" in cache
    k_scale = v_scale = None
    if block_tables is not None:
        assert cache is not None and kv_input is None
        ci = jnp.asarray(cache_index)
        assert s == 1 and ci.ndim == 1, \
            "block_tables requires single-token decode with vector cache_index"
        blk = cache["k"].shape[1]  # physical block size (tokens)
        rows = jnp.arange(b)
        phys = block_tables[rows, ci // blk]
        off = ci % blk
        if quant:
            from ..quant import dequantize_kv, quantize_kv
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            kc = cache["k"].at[phys, off].set(kq[:, 0])
            vc = cache["v"].at[phys, off].set(vq[:, 0])
            k_scale = cache["k_scale"].at[phys, off].set(ks[:, 0])
            v_scale = cache["v_scale"].at[phys, off].set(vs[:, 0])
            new_cache = {"k": kc, "v": vc,
                         "k_scale": k_scale, "v_scale": v_scale}
        else:
            kc = cache["k"].at[phys, off].set(k[:, 0].astype(cache["k"].dtype))
            vc = cache["v"].at[phys, off].set(v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": kc, "v": vc}
        lengths = (ci + 1).astype(jnp.int32)
        if cfg.attn_impl == "paged":
            from ..kernels.flash_attention.ops import (default_interpret,
                                                       paged_decode_blocktable)
            out = paged_decode_blocktable(
                q[:, 0], kc if quant else kc.astype(q.dtype),
                vc if quant else vc.astype(q.dtype),
                block_tables, lengths, k_scale=k_scale, v_scale=v_scale,
                tuned=True, interpret=default_interpret())[:, None]
        else:
            from ..kernels.flash_attention.ref import gather_block_kv
            kg = gather_block_kv(kc, block_tables)
            vg = gather_block_kv(vc, block_tables)
            if quant:
                kg = dequantize_kv(kg, gather_block_kv(k_scale, block_tables),
                                   q.dtype)
                vg = dequantize_kv(vg, gather_block_kv(v_scale, block_tables),
                                   q.dtype)
            out = _sdpa(q, kg.astype(q.dtype), vg.astype(q.dtype),
                        causal=causal, q_pos=positions, kv_len=lengths)
        out = linear(out.reshape(b, s, a * hd), p["wo"], impl=impl)
        return out, new_cache
    if cache is not None and kv_input is None:
        ci = jnp.asarray(cache_index)
        if quant:
            from ..quant import dequantize_kv, quantize_kv
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            if ci.ndim:  # per-row write positions (serving-engine slot pool)
                assert s == 1, "vector cache_index requires single-token decode"
                write = jnp.arange(cache["k"].shape[1]) == ci[:, None]
                sel = write[:, :, None, None]
                kq = jnp.where(sel, kq, cache["k"])
                vq = jnp.where(sel, vq, cache["v"])
                k_scale = jnp.where(write[:, :, None], ks, cache["k_scale"])
                v_scale = jnp.where(write[:, :, None], vs, cache["v_scale"])
            else:
                upd = jax.lax.dynamic_update_slice_in_dim
                kq = upd(cache["k"], kq, cache_index, axis=1)
                vq = upd(cache["v"], vq, cache_index, axis=1)
                k_scale = upd(cache["k_scale"], ks, cache_index, axis=1)
                v_scale = upd(cache["v_scale"], vs, cache_index, axis=1)
            new_cache = {"k": kq, "v": vq,
                         "k_scale": k_scale, "v_scale": v_scale}
            kv_len = ci + s
            if cfg.attn_impl == "paged" and s == 1:
                k, v = kq, vq  # paged kernel dequantizes per kv tile
            else:
                k = dequantize_kv(kq, k_scale, q.dtype)
                v = dequantize_kv(vq, v_scale, q.dtype)
        else:
            if ci.ndim:  # per-row write positions (serving-engine slot pool)
                assert s == 1, "vector cache_index requires single-token decode"
                write = jnp.arange(cache["k"].shape[1]) == ci[:, None]  # (b, s_max)
                sel = write[:, :, None, None]
                k = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
                v = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
            else:
                k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
                v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
            new_cache = {"k": k, "v": v}
            kv_len = ci + s
    # 2-D positions are per-row query positions; _sdpa masks them row-wise
    q_pos = positions
    is_decode = cache is not None and s == 1
    if cfg.attn_impl == "paged" and is_decode:
        # Pallas paged decode over the slot pool (identity slot map here;
        # the kernel's gather-by-slot path is exercised by the engine tests)
        from ..kernels.flash_attention.ops import (default_interpret,
                                                   paged_decode)
        lengths = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
        out = paged_decode(q[:, 0], k if quant else k.astype(q.dtype),
                           v if quant else v.astype(q.dtype),
                           jnp.arange(b, dtype=jnp.int32), lengths,
                           k_scale=k_scale, v_scale=v_scale, tuned=True,
                           interpret=default_interpret())[:, None]
    elif cfg.attn_impl == "flash" and not is_decode and cache is None:
        # Pallas flash kernel with its custom-VJP fused backward: the
        # training/prefill fast path.  Cache-backed prefill (dynamic kv_len)
        # and decode stay on the jnp paths below; MLA never routes here.
        from ..kernels.flash_attention.ops import (default_interpret,
                                                   flash_attention)
        out = flash_attention(q, k.astype(q.dtype), v.astype(q.dtype),
                              causal=causal and kv_input is None,
                              tuned=True, interpret=default_interpret())
    elif cfg.attn_impl == "blocked" and not is_decode:
        from .blocked_attention import blocked_sdpa
        out = blocked_sdpa(q, k.astype(q.dtype), v.astype(q.dtype),
                           causal=causal and kv_input is None,
                           q_pos=q_pos if q_pos.ndim == 1 else q_pos[0],
                           kv_len=kv_len, block_kv=cfg.attn_block_kv)
    else:
        out = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype),
                    causal=causal and kv_input is None,
                    q_pos=q_pos, kv_len=kv_len, seq_sharded=is_decode)
    out = linear(out.reshape(b, s, a * hd), p["wo"], impl=impl)
    return out, new_cache


# --- DeepSeek-V3 Multi-head Latent Attention ------------------------------------------

def init_mla(key, cfg: ModelConfig):
    h = cfg.d_model
    a = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_down": dense_init(ks[0], h, qr),
        "wq_up": dense_init(ks[1], qr, a * (nope + rope)),
        "wkv_down": dense_init(ks[2], h, kvr + rope),
        "wk_up": dense_init(ks[3], kvr, a * nope),
        "wv_up": dense_init(ks[4], kvr, a * vd),
        "wo": dense_init(ks[5], a * vd, h, scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }


def apply_mla(p, x, cfg: ModelConfig, *, positions, cache=None, cache_index=None):
    """MLA with a latent-KV cache.  cache: dict(latent=(b, s_max, kvr+rope)).

    Train/prefill: decompressed path (naive).  The latent (c_kv ++ k_rope) is
    what gets cached; decode recomputes k/v from the cached latent (the
    weight-absorbed schedule is an optimization we model in core/, the
    computation here is mathematically identical).
    """
    b, s, h = x.shape
    a = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    impl = resolve_impl(cfg)
    q = linear(linear(x, p["wq_down"], impl=impl), p["wq_up"], impl=impl)
    q = q.reshape(b, s, a, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rotary(q_rope, positions, cfg.rope_theta)

    latent = linear(x, p["wkv_down"], impl=impl)  # (b, s, kvr+rope)
    c_kv, k_rope_flat = latent[..., :kvr], latent[..., kvr:]
    k_rope = apply_rotary(k_rope_flat[..., None, :], positions, cfg.rope_theta)

    kv_len = None
    new_cache = None
    if cache is not None:
        lat_all = jnp.concatenate([c_kv, k_rope[..., 0, :]], axis=-1)
        stored = jax.lax.dynamic_update_slice_in_dim(
            cache["latent"], lat_all.astype(cache["latent"].dtype), cache_index, axis=1)
        new_cache = {"latent": stored}
        c_kv = stored[..., :kvr].astype(x.dtype)
        k_rope = stored[..., None, kvr:].astype(x.dtype)
        kv_len = cache_index + s

    skv = c_kv.shape[1]
    k_nope = linear(c_kv, p["wk_up"], impl=impl).reshape(b, skv, a, nope)
    v = linear(c_kv, p["wv_up"], impl=impl).reshape(b, skv, a, vd)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, skv, a, rope))], axis=-1)
    out = _sdpa(q_full, k_full, v, causal=True,
                q_pos=positions[0] if positions.ndim > 1 else positions,
                kv_len=kv_len,
                seq_sharded=(cache is not None and s == 1))
    out = linear(out.reshape(b, s, a * vd), p["wo"], impl=impl)
    return out, new_cache


def init_attention(key, cfg: ModelConfig):
    return init_mla(key, cfg) if cfg.attn_type == "mla" else init_gqa(key, cfg)


def apply_attention(p, x, cfg: ModelConfig, **kw):
    if cfg.attn_type == "mla":
        kw.pop("kv_input", None)
        kw.pop("causal", None)
        assert kw.pop("block_tables", None) is None, \
            "block-table KV paging is not supported for MLA"
        return apply_mla(p, x, cfg, **kw)
    return apply_gqa(p, x, cfg, **kw)
