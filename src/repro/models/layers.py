"""Basic layers: norms, embeddings, rotary, initializers.

Pure JAX (no flax): every layer is an `init(key, ...) -> params` function plus
an `apply(params, x, ...) -> y` function over dict pytrees.  Parameters are
stored in float32 (master copy); compute runs in the config dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def compute_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --- initializers -------------------------------------------------------------------

def dense_init(key, fan_in: int, fan_out: int, scale: float = 1.0):
    std = scale / np.sqrt(fan_in)
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * std


def embed_init(key, vocab: int, dim: int):
    return jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02


# --- norms --------------------------------------------------------------------------

def norm_init(dim: int, kind: str = "rmsnorm"):
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def norm_apply(p, x, kind: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# --- rotary embeddings (§VI-C2: recommended best practice) ---------------------------

def rotary_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    return jnp.asarray(inv)


def apply_rotary(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    inv = rotary_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., s, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]  # broadcast over heads
    cos = cos[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- activations ---------------------------------------------------------------------

_ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron-4 squared ReLU
}


def activation(name: str):
    try:
        return _ACTIVATIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown activation {name!r}; valid: {sorted(_ACTIVATIONS)}"
        ) from None
