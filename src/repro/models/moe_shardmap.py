"""Expert-parallel MoE with EXPLICIT collective scheduling (shard_map).

The auto-SPMD dispatch (models/moe.py) lets XLA choose the collectives for
the token->expert scatter and the expert->token combine; even with output
sharding anchors it emits multi-pass f32 gathers/all-reduces (measured
11 TB/chip on deepseek-v3 train_4k — EXPERIMENTS.md §Perf).

This path exploits the framework's activation layout directly: tokens are
sharded over `data` and REPLICATED over `model` (= the EP axis), so

  * expert selection, capacity packing, and the expert FFN are fully LOCAL
    to each (data, model) shard: each chip packs only the tokens routed to
    ITS E/t resident experts — no dispatch communication at all;
  * the combine is exactly ONE bf16 `psum` of the (t_local, h) partial
    outputs over `model` per layer — each chip contributes the share of
    every token's top-k that its experts produced.

Per layer per microbatch the communication is t_loc x h x 2 bytes
(deepseek-v3/mb4: 235 MB/chip vs the ~3 GB x multiple passes XLA chose).
Selected via ModelConfig.moe_dispatch == "shard_map"; requires the
activation context to carry the mesh (launchers set it), otherwise falls
back to the auto-SPMD path (CPU unit tests, single device).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .linear import expert_fused_hidden, expert_linear, linear, resolve_impl
from .mlp import apply_mlp


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """jax.shard_map moved out of jax.experimental in newer jax; the
    replication-check kwarg was renamed check_rep -> check_vma with it."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _local_block(cfg: ModelConfig, tp_axis: str):
    e, k = cfg.num_experts, cfg.top_k

    def block(router, w_gate, w_up, w_down, xt):
        """Per-shard block.  router: (h, E) replicated; w_*: (E_loc, h, f)
        this shard's experts; xt: (t_loc, h) this data shard's tokens
        (replicated over the model axis)."""
        t_loc, h = xt.shape
        e_loc = w_up.shape[0]
        m = jax.lax.axis_index(tp_axis)
        lo = m * e_loc
        impl = resolve_impl(cfg)

        logits = linear(xt, router, impl=impl).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, k)                  # (t_loc, k)
        gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

        # load-balance aux (identical on every model shard: same tokens)
        frac_tokens = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32),
                               axis=(0, 1))
        aux = e * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))

        # ---- local packing: only assignments landing on OUR experts ------
        cap = max(int(t_loc * k * cfg.moe_capacity_factor / e) // -8 * -8, 8)
        flat_idx = idx.reshape(-1)                           # (t_loc*k,)
        flat_tok = jnp.repeat(jnp.arange(t_loc), k)
        flat_gate = gate.reshape(-1).astype(xt.dtype)
        local = (flat_idx >= lo) & (flat_idx < lo + e_loc)
        le = jnp.where(local, flat_idx - lo, e_loc)          # e_loc = trash
        order = jnp.argsort(le, stable=True)
        se, st, sg = le[order], flat_tok[order], flat_gate[order]
        seg_start = jnp.searchsorted(se, jnp.arange(e_loc), side="left")
        pos = jnp.arange(t_loc * k) - seg_start[jnp.clip(se, 0, e_loc - 1)]
        keep = (se < e_loc) & (pos < cap)
        dst = jnp.where(keep, se * cap + pos, e_loc * cap - 1)
        buf = jnp.zeros((e_loc * cap, h), xt.dtype)
        buf = buf.at[dst].add(jnp.where(keep[:, None], xt[st], 0))
        buf = buf.reshape(e_loc, cap, h)

        # ---- local expert FFN (dispatched through models.linear) ---------
        if impl == "fused":
            hdn = expert_fused_hidden(
                buf, w_gate, w_up,
                mlp_type="swiglu" if cfg.mlp_type == "swiglu" else "gelu")
        elif cfg.mlp_type == "swiglu":
            g = jax.nn.silu(expert_linear(buf, w_gate, impl=impl))
            u = expert_linear(buf, w_up, impl=impl)
            hdn = g * u
        else:
            hdn = jax.nn.gelu(expert_linear(buf, w_up, impl=impl))
        out_buf = expert_linear(hdn, w_down, impl=impl).reshape(e_loc * cap, h)

        # ---- local combine + ONE psum over the EP axis -------------------
        picked = jnp.where(keep[:, None], out_buf[dst], 0)
        y = jnp.zeros((t_loc, h), xt.dtype).at[st].add(picked * sg[:, None])
        y = jax.lax.psum(y, tp_axis)
        return y, aux

    return block


def apply_moe_shardmap(p, x, cfg: ModelConfig):
    """x: (b, s, h) -> (y, aux).  Falls back to auto-SPMD when no mesh."""
    from ..parallel.sharding import activation_context
    ctx = activation_context()
    mesh = ctx.get("mesh")
    if mesh is None or ctx.get("tp") is None:
        from .moe import apply_moe
        return apply_moe(p, x, cfg)
    tp_axis = ctx["tp"]
    dp = ctx["dp"] or ()
    b, s, h = x.shape
    xt = x.reshape(b * s, h)

    block = _local_block(cfg, tp_axis)
    spec_tok = P(dp, None)
    spec_exp = P(tp_axis, None, None)
    y, aux = _shard_map(
        block, mesh=mesh,
        in_specs=(P(None, None), spec_exp, spec_exp, spec_exp, spec_tok),
        out_specs=(spec_tok, P()),
    )(p["router"], p.get("w_gate", p["w_up"]), p["w_up"], p["w_down"], xt)

    if cfg.num_shared_experts:
        y = y + apply_mlp(p["shared"], xt, cfg)
    return y.reshape(b, s, h), aux
