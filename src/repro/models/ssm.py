"""Mamba2 SSD (state-space duality) block — chunked dual form + decode step.

The chunked dual form computes, per chunk of length Q:
  intra-chunk:  Y_diag = ((C Bᵀ) ∘ L) · (x·dt)          — attention-like BMMs
  chunk states: S_c    = (B·decay)ᵀ (x·dt)               — (N,Q)x(Q,P) BMMs
  inter-chunk:  recurrence over chunk states (associative scan, O(nc log nc))
  state read:   Y_off  = C · S_prev · decay              — (Q,N)x(N,P) BMMs

These are exactly the `ssd_*` GEMMs enumerated in core/transformer_gemms.py;
the paper's BMM sizing rules apply with (Q, P, N) in place of (s, h/a): Q and
N should be multiples of the 128 lane width, P of the sublane tile.

TP note: the z/x/B/C/dt projections are stored as SEPARATE matrices (not the
fused in_proj of the reference CUDA implementation) so each shards cleanly on
the `model` axis — the fused layout's split points fall mid-shard and would
force XLA to reshard (DESIGN.md §Hardware-adaptation).  Same math, same total
GEMM volume (XLA fuses the small projections back together per tile).

Decode runs the constant-memory recurrent step on an (b, nh, P, N) state —
this is what makes the long_500k cell runnable for mamba2/zamba2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, norm_apply, norm_init
from .linear import linear, resolve_impl


def _dims(cfg: ModelConfig):
    di = cfg.ssm_d_inner
    N = cfg.ssm_state
    P = cfg.ssm_head_dim
    nh = di // P
    g = cfg.ssm_ngroups
    return di, N, P, nh, g


def init_ssm(key, cfg: ModelConfig):
    h = cfg.d_model
    di, N, P, nh, g = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "in_z": dense_init(ks[0], h, di),
        "in_x": dense_init(ks[1], h, di),
        "in_B": dense_init(ks[2], h, g * N),
        "in_C": dense_init(ks[3], h, g * N),
        "in_dt": dense_init(ks[4], h, nh),
        "conv_x": jax.random.normal(ks[5], (cfg.conv_width, di), jnp.float32) * 0.1,
        "conv_B": jax.random.normal(ks[6], (cfg.conv_width, g * N), jnp.float32) * 0.1,
        "conv_C": jax.random.normal(ks[7], (cfg.conv_width, g * N), jnp.float32) * 0.1,
        "conv_bx": jnp.zeros((di,), jnp.float32),
        "conv_bB": jnp.zeros((g * N,), jnp.float32),
        "conv_bC": jnp.zeros((g * N,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": norm_init(di),
        "out_proj": dense_init(jax.random.fold_in(key, 99), di, h,
                               scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv + SiLU.  x: (b, s, c); w: (k, c)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b.astype(out.dtype))


def apply_ssm(p, x, cfg: ModelConfig, *, state=None):
    """Chunked SSD forward.  x: (b, s, h), s % ssm_chunk == 0 (or s <= chunk).

    Returns (y, (final_state, None)) so prefill can hand off to decode.
    """
    b, s, h = x.shape
    di, N, P, nh, g = _dims(cfg)
    Q = min(cfg.ssm_chunk, s)
    dtype = x.dtype

    impl = resolve_impl(cfg)
    z = linear(x, p["in_z"], impl=impl)
    u_x = linear(x, p["in_x"], impl=impl)
    u_B = linear(x, p["in_B"], impl=impl)
    u_C = linear(x, p["in_C"], impl=impl)
    xr = _causal_conv(u_x, p["conv_x"].astype(dtype), p["conv_bx"])
    B = _causal_conv(u_B, p["conv_B"].astype(dtype), p["conv_bB"])
    C = _causal_conv(u_C, p["conv_C"].astype(dtype), p["conv_bC"])
    dt = linear(x, p["in_dt"], impl=impl)

    # conv-state tails for prefill -> decode handoff: the last (width-1)
    # pre-activation rows of each conv branch
    w1 = cfg.conv_width - 1
    def _tail(u):
        if s >= w1:
            return u[:, s - w1:s]
        return jnp.pad(u, ((0, 0), (w1 - s, 0), (0, 0)))
    conv_tails = {"conv_x": _tail(u_x), "conv_B": _tail(u_B),
                  "conv_C": _tail(u_C)}

    xin = xr.reshape(b, s, nh, P)
    Bh = jnp.repeat(B.reshape(b, s, g, N), nh // g, axis=2)  # (b,s,nh,N)
    Ch = jnp.repeat(C.reshape(b, s, g, N), nh // g, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,s,nh)

    # pad the sequence up to a chunk multiple; padded steps get dt = 0, i.e.
    # unit decay and zero input — they cannot perturb y or the final state.
    s_orig = s
    if s % Q:
        pad = Q - s % Q
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        s = s + pad

    A = -jnp.exp(p["A_log"])  # (nh,)
    dA = dt * A
    x_dt = xin * dt.astype(dtype)[..., None]

    # ---- chunk ---------------------------------------------------------------
    nc = s // Q
    def ck(t):
        return t.reshape((b, nc, Q) + t.shape[2:])
    dA_c = ck(dA)                                   # (b,nc,Q,nh) f32
    seg = jnp.cumsum(dA_c, axis=2)
    x_c, B_c, C_c = ck(x_dt), ck(Bh), ck(Ch)

    # intra-chunk: ((C Bᵀ) ∘ L) x.  The mask goes INSIDE the exponent:
    # masked (k > q) entries have positive exponents that overflow to inf,
    # and 0*inf in the backward pass poisons gradients with NaN.
    CB = jnp.einsum("bcqhn,bckhn->bcqkh", C_c, B_c)
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    att = (CB.astype(jnp.float32) * L).astype(dtype)
    Y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", att, x_c)

    # chunk states
    decay_states = jnp.exp(seg[:, :, -1:, :] - seg)
    S = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", B_c, decay_states.astype(dtype), x_c)

    # inter-chunk recurrence (associative scan over chunks)
    chunk_decay = jnp.exp(jnp.sum(dA_c, axis=2))    # (b,nc,nh)
    if state is None:
        state = jnp.zeros((b, nh, N, P), dtype)
    d_all = jnp.concatenate([jnp.ones((b, 1, nh), jnp.float32), chunk_decay], 1)
    S_all = jnp.concatenate([state[:, None].astype(dtype), S], 1)

    def combine(a_, b_):
        d1, s1 = a_
        d2, s2 = b_
        return d1 * d2, s2 + d2[..., None, None].astype(s2.dtype) * s1

    d_sc, S_sc = jax.lax.associative_scan(combine, (d_all, S_all), axis=1)
    S_prev = S_sc[:, :-1]
    new_state = S_sc[:, -1]

    Y_off = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp",
                       C_c, S_prev, jnp.exp(seg).astype(dtype))

    y = (Y_diag + Y_off).reshape(b, s, nh, P)
    y = y + xin * p["D"].astype(dtype)[None, None, :, None]
    y = y.reshape(b, s, di)[:, :s_orig]
    y = norm_apply(p["norm"], y * jax.nn.silu(z))
    out = linear(y, p["out_proj"], impl=impl)
    return out, (new_state, conv_tails)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, N, P, nh, g = _dims(cfg)
    w = cfg.conv_width - 1
    return {
        "state": jnp.zeros((batch, nh, N, P), dtype),
        "conv_x": jnp.zeros((batch, w, di), dtype),
        "conv_B": jnp.zeros((batch, w, g * N), dtype),
        "conv_C": jnp.zeros((batch, w, g * N), dtype),
    }


def _conv_step(buf, new, w, b):
    """One causal-conv step.  buf: (b, k-1, c); new: (b, c)."""
    full = jnp.concatenate([buf, new[:, None]], 1)
    out = jax.nn.silu(jnp.einsum("bkc,kc->bc", full, w) + b.astype(new.dtype))
    return out, full[:, 1:]


def decode_ssm(p, x, cfg: ModelConfig, cache):
    """Single-token recurrent step.  x: (b, 1, h)."""
    b = x.shape[0]
    di, N, P, nh, g = _dims(cfg)
    dtype = x.dtype
    xt = x[:, 0]
    impl = resolve_impl(cfg)
    z = linear(xt, p["in_z"], impl=impl)
    xr, ncx = _conv_step(cache["conv_x"].astype(dtype), linear(xt, p["in_x"], impl=impl),
                         p["conv_x"].astype(dtype), p["conv_bx"])
    B, ncB = _conv_step(cache["conv_B"].astype(dtype), linear(xt, p["in_B"], impl=impl),
                        p["conv_B"].astype(dtype), p["conv_bB"])
    C, ncC = _conv_step(cache["conv_C"].astype(dtype), linear(xt, p["in_C"], impl=impl),
                        p["conv_C"].astype(dtype), p["conv_bC"])
    dt = linear(xt, p["in_dt"], impl=impl)

    xin = xr.reshape(b, nh, P)
    Bh = jnp.repeat(B.reshape(b, g, N), nh // g, axis=1)
    Ch = jnp.repeat(C.reshape(b, g, N), nh // g, axis=1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b, nh)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A).astype(dtype)
    x_dt = xin * dt.astype(dtype)[..., None]

    state = cache["state"].astype(dtype)
    state = state * dA[..., None, None] + jnp.einsum("bhn,bhp->bhnp", Bh, x_dt)
    y = jnp.einsum("bhnp,bhn->bhp", state, Ch) + xin * p["D"].astype(dtype)[None, :, None]
    y = y.reshape(b, di)
    y = norm_apply(p["norm"], y * jax.nn.silu(z))
    out = linear(y, p["out_proj"], impl=impl)[:, None]
    return out, {"state": state, "conv_x": ncx, "conv_B": ncB, "conv_C": ncC}
