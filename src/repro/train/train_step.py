"""Training step: value_and_grad + microbatched gradient accumulation +
AdamW update, built for pjit with parameter donation.

Gradient accumulation runs as a `lax.scan` over microbatches (constant HLO
size), with grads accumulated in f32.  Remat policy is applied inside the
model's layer scan (models/blocks.py), so activation memory per microbatch is
O(layers x carry) instead of O(layers x activations).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import MeshConfig, ModelConfig, ShapeConfig, TrainConfig
from ..models import lm_loss
from ..optim.adamw import OptState, apply_updates


def num_microbatches(shape: ShapeConfig, mesh_cfg: MeshConfig,
                     tc: TrainConfig) -> int:
    per_step = mesh_cfg.dp * tc.microbatch_per_device
    if shape.global_batch % per_step:
        raise ValueError(
            f"global_batch {shape.global_batch} % (dp {mesh_cfg.dp} * "
            f"microbatch {tc.microbatch_per_device}) != 0")
    return shape.global_batch // per_step


def make_train_step(cfg: ModelConfig, tc: TrainConfig, n_micro: int = 1,
                    batch_spec: Any = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch_spec: optional PartitionSpec pytree for ONE microbatch (leading
    batch dim sharded over the DP axes).  Without it, the (global_batch,) ->
    (n_micro, micro) reshape lets the SPMD partitioner move the batch
    sharding onto the scan axis, replicating compute dp-fold — we measured
    exactly that before pinning the constraint (EXPERIMENTS.md §Perf).
    """

    def loss_fn(p, mb):
        return lm_loss(p, mb, cfg, remat=tc.remat)

    def constrain(mb):
        if batch_spec is None:
            return mb
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            mb, {k: batch_spec[k] for k in mb})

    # named_scope blocks are trace-time HLO metadata (free at runtime), so
    # XLA profiles split a step into grad / microbatch / update regions
    def train_step(params, opt_state: OptState, batch: Dict[str, jax.Array]):
        if n_micro == 1:
            with jax.named_scope("train_grad"):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, constrain(batch))
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                g_acc, l_acc = carry
                with jax.named_scope("train_microbatch_grad"):
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, constrain(mb))
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), m

            with jax.named_scope("train_grad_accum"):
                (grads, loss_sum), ms = jax.lax.scan(
                    acc, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)
        with jax.named_scope("train_update"):
            new_params, new_opt, om = apply_updates(params, grads, opt_state,
                                                    tc)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, tc: TrainConfig):
    def eval_step(params, batch):
        loss, metrics = lm_loss(params, batch, cfg, remat=tc.remat)
        return metrics
    return eval_step
