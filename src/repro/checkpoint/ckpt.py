"""Pure-JAX checkpointing: sharded-safe save/restore with elastic reshape.

Fault-tolerance contract (DESIGN.md §5):
  * `save` writes an atomic checkpoint (tmp dir + rename): one .npz of
    flattened leaves + a JSON manifest (step, config name, mesh shape,
    leaf paths/dtypes).  Save can run asynchronously on a worker thread —
    training continues while the host writes.
  * `restore` returns numpy trees; the caller `device_put`s them with the
    *current* mesh's shardings — a checkpoint written on 512 chips restores
    onto any device count whose divisibility rules hold (elastic reshape:
    resharding is free because leaves are stored unsharded).
  * rotation keeps the newest `keep` checkpoints; a half-written checkpoint
    can never be selected (manifest is written last).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat = {}
    for path, leaf in leaves_with_paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Any, flat: dict):
    def pick(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        return arr
    return jax.tree_util.tree_map_with_path(pick, template)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # --- save -------------------------------------------------------------
    def save(self, step: int, params: Any, opt_state: Any = None,
             meta: Optional[dict] = None, blocking: bool = True):
        # snapshot to host memory synchronously (cheap vs. disk write)
        tree = {"params": params}
        if opt_state is not None:
            tree["opt"] = opt_state
        flat = _flatten(tree)
        self.wait()  # never two writers

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "leaves.npz"), **flat)
            manifest = {"step": step, "time": time.time(),
                        "leaves": sorted(flat), **(meta or {})}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._rotate()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _rotate(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --- restore ----------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, params_template: Any, opt_template: Any = None,
                step: Optional[int] = None) -> Tuple[Any, Any, int]:
        """Returns (params, opt_state, step) as numpy trees shaped like the
        templates (device_put with current shardings is the caller's job)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "leaves.npz")) as z:
            flat = {k: z[k] for k in z.files}
        params = _unflatten_into(params_template,
                                 {k[len("params/"):]: v for k, v in flat.items()
                                  if k.startswith("params/")})
        opt = None
        if opt_template is not None:
            opt = _unflatten_into(opt_template,
                                  {k[len("opt/"):]: v for k, v in flat.items()
                                   if k.startswith("opt/")})
        return params, opt, step
