"""Pallas TPU kernels (validated with interpret=True on CPU).

  matmul           — tile-aligned GEMM with explicit BlockSpec VMEM tiling
  flash_attention  — FlashAttention-2 (causal, GQA) online-softmax kernel
  ssd              — Mamba2 SSD intra-chunk dual-form kernel
"""
from .matmul.ops import matmul, alignment_report
from .flash_attention.ops import flash_attention
from .ssd.ops import ssd_chunk

__all__ = ["matmul", "alignment_report", "flash_attention", "ssd_chunk"]
