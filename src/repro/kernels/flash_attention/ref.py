"""Pure-jnp oracle for the flash-attention kernel."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True, scale=None):
    """q: (bh, sq, d); k, v: (bkv, skv, d); GQA via bh % bkv == 0."""
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    g = bh // bkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    k = jnp.repeat(k, g, axis=0)
    v = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
