"""Pure-jnp oracles for the flash-attention and paged-decode kernels."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True, scale=None):
    """q: (bh, sq, d); k, v: (bkv, skv, d); GQA via bh % bkv == 0."""
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    g = bh // bkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    k = jnp.repeat(k, g, axis=0)
    v = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_ref(q, k_pool, v_pool, slot_idx, lengths, scale=None):
    """q: (b, a, d); k_pool, v_pool: (slots, s_max, nkv, d); slot_idx: (b,)
    row->slot gather; lengths: (b,) live kv entries per row (0 = dead slot,
    returns zeros).  GQA via a % nkv == 0.  Returns (b, a, d)."""
    b, a, d = q.shape
    _, s_max, nkv, _ = k_pool.shape
    g = a // nkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    k = k_pool[slot_idx].transpose(0, 2, 1, 3)  # (b, nkv, s_max, d)
    v = v_pool[slot_idx].transpose(0, 2, 1, 3)
    qh = q.reshape(b, nkv, g, d)
    s = jnp.einsum("bhgd,bhsd->bhgs", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    live = jnp.arange(s_max)[None, :] < lengths[:, None]  # (b, s_max)
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(b, a, d).astype(q.dtype)


def gather_block_kv(pool, block_tables):
    """(num_blocks, bs, nkv, d) pool + (b, max_blocks) tables -> contiguous
    (b, max_blocks*bs, nkv, d) per-row KV (logical layout)."""
    g = pool[block_tables]                       # (b, max_nb, bs, nkv, d)
    b, max_nb, bs = g.shape[:3]
    return g.reshape(b, max_nb * bs, *g.shape[3:])


def paged_decode_blocktable_ref(q, k_blocks, v_blocks, block_tables, lengths,
                                scale=None):
    """Oracle for the block-table kernel: gather each row's physical blocks
    into the logical layout, then slot-decode with an identity map."""
    b = q.shape[0]
    k = gather_block_kv(k_blocks, block_tables)
    v = gather_block_kv(v_blocks, block_tables)
    return paged_decode_ref(q, k, v, jnp.arange(b, dtype=jnp.int32), lengths,
                            scale=scale)
