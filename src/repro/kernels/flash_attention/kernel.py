"""FlashAttention-2-style Pallas TPU kernel: online-softmax blocked attention
with causal masking and GQA head mapping.

Grid (batch*q_heads, q_blocks, kv_blocks), kv innermost; VMEM scratch carries
(m, l, acc) across kv steps of one q block (TPU grids are sequential per
core).  Block sizes must be multiples of the (16, 128) bf16 tile — the same
alignment rule the paper derives for GPU tensor cores, with TPU constants
(DESIGN.md §2).  Fully-masked kv blocks above the causal diagonal are skipped
via pl.when (saving ~2x on causal prefill).

This kernel is the §VI-C3 recommendation realized on TPU: it converts the
naive score/AOV BMM pair (whose s^2 HBM traffic makes long-sequence training
memory-bound — see EXPERIMENTS.md §Roofline baseline) into a compute-bound
streaming kernel; the h-dependence collapses onto the roofline (paper Fig.12).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  kv_steps: int, block_q: int, block_kv: int, causal: bool,
                  scale: float):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0].astype(jnp.float32)           # (bkv, d)
        v = v_ref[0].astype(jnp.float32)           # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                            (block_q, block_kv), 0)
            kv_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                              (block_q, block_kv), 1)
            s = jnp.where(kv_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip blocks entirely above the diagonal
        pl.when(ki * block_kv <= (qi + 1) * block_q - 1)(_step)
    else:
        _step()

    @pl.when(ki == kv_steps - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_kv: int = 128, scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """q: (bh, sq, d); k, v: (bkv_h, skv, d) with bh % bkv_h == 0 (GQA).

    Requires sq % block_q == 0 and skv % block_kv == 0 (ops.py pads).
    """
    bh, sq, d = q.shape
    bkv, skv, dk = k.shape
    assert d == dk and bh % bkv == 0
    g = bh // bkv
    assert sq % block_q == 0 and skv % block_kv == 0
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_steps = skv // block_kv
    grid = (bh, sq // block_q, kv_steps)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=kv_steps, block_q=block_q,
                          block_kv=block_kv, causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j, g=g: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
