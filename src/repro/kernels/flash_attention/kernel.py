"""FlashAttention-2-style Pallas TPU kernel: online-softmax blocked attention
with causal masking, padded-KV column masking, and GQA head mapping.

Grid (batch*q_heads, q_blocks, kv_blocks), kv innermost; VMEM scratch carries
(m, l, acc) across kv steps of one q block (TPU grids are sequential per
core).  Block sizes must be multiples of the (16, 128) bf16 tile — the same
alignment rule the paper derives for GPU tensor cores, with TPU constants
(DESIGN.md §2).  Fully-masked kv blocks above the causal diagonal, or fully
beyond `kv_len`, are skipped via pl.when (saving ~2x on causal prefill).

`kv_len` is the number of *real* keys: ops.py zero-pads KV up to the block
grid and the kernel masks the padded columns with NEG_INF, so non-causal and
cross-attention shapes are exact (they no longer rely on the causal rule to
hide the padding).

The forward optionally emits per-row logsumexp residuals (`return_residuals`)
for the fused backward pass in `backward.py` — together they make the kernel
a drop-in differentiable op (wired via jax.custom_vjp in ops.py).

This kernel is the §VI-C3 recommendation realized on TPU: it converts the
naive score/AOV BMM pair (whose s^2 HBM traffic makes long-sequence training
memory-bound — see EXPERIMENTS.md §Roofline baseline) into a compute-bound
streaming kernel; the h-dependence collapses onto the roofline (paper Fig.12).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def mask_block(s, qi, ki, *, block_q: int, block_kv: int, causal: bool,
               kv_len: int | None):
    """Apply causal and padded-column masking to one (block_q, block_kv)
    score tile at grid position (qi, ki).  Shared by forward and backward."""
    kv_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 1)
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_kv), 0)
        s = jnp.where(kv_pos <= q_pos, s, NEG_INF)
    if kv_len is not None:
        s = jnp.where(kv_pos < kv_len, s, NEG_INF)
    return s


def block_live(qi, ki, *, block_q: int, block_kv: int, causal: bool,
               kv_len: int | None):
    """Whether the (qi, ki) tile has any unmasked entry (skippable otherwise).
    Returns None when no masking applies (the tile always runs)."""
    live = None
    if causal:
        live = ki * block_kv <= (qi + 1) * block_q - 1
    if kv_len is not None:
        beyond = ki * block_kv < kv_len
        live = beyond if live is None else jnp.logical_and(live, beyond)
    return live


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *rest, kv_steps: int,
                  block_q: int, block_kv: int, causal: bool, scale: float,
                  kv_len: int | None, emit_lse: bool):
    if emit_lse:
        lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        lse_ref, (m_ref, l_ref, acc_ref) = None, rest
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0].astype(jnp.float32)           # (bkv, d)
        v = v_ref[0].astype(jnp.float32)           # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = mask_block(s, qi, ki, block_q=block_q, block_kv=block_kv,
                       causal=causal, kv_len=kv_len)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # skip tiles entirely above the causal diagonal or beyond the live keys
    live = block_live(qi, ki, block_q=block_q, block_kv=block_kv,
                      causal=causal, kv_len=kv_len)
    _step() if live is None else pl.when(live)(_step)

    @pl.when(ki == kv_steps - 1)
    def _done():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, ...] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        if emit_lse:
            # lse = m + log(l) is the softmax log-normalizer the backward
            # recomputes p against (p = exp(s - lse)).  Fully-masked rows get
            # lse = 0: finite, and exp(NEG_INF - 0) == 0 keeps their dq/dk/dv
            # contributions exactly zero instead of NaN (m is NEG_INF there).
            lse = m_ref[...] + jnp.log(l_safe)
            lse_ref[0, ...] = jnp.where(l == 0.0, 0.0, lse)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_kv: int = 128, scale: float | None = None,
                           kv_len: int | None = None,
                           return_residuals: bool = False,
                           interpret: bool = False):
    """q: (bh, sq, d); k, v: (bkv_h, skv, d) with bh % bkv_h == 0 (GQA).

    Requires sq % block_q == 0 and skv % block_kv == 0 (ops.py pads).
    kv_len masks key columns >= kv_len (the zero-padded tail) with NEG_INF.
    return_residuals=True additionally returns the per-row logsumexp
    (bh, sq) f32 — the saved residual for the Pallas backward pass.
    """
    bh, sq, d = q.shape
    bkv, skv, dk = k.shape
    assert d == dk and bh % bkv == 0
    g = bh // bkv
    assert sq % block_q == 0 and skv % block_kv == 0
    if kv_len is not None and kv_len >= skv:
        kv_len = None  # nothing padded: skip the column mask entirely
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_steps = skv // block_kv
    grid = (bh, sq // block_q, kv_steps)
    from jax.experimental.pallas import tpu as pltpu
    out_shape = [jax.ShapeDtypeStruct((bh, sq, d), q.dtype)]
    out_specs = [pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0))]
    if return_residuals:
        out_shape.append(jax.ShapeDtypeStruct((bh, sq), jnp.float32))
        out_specs.append(pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)))
    res = pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=kv_steps, block_q=block_q,
                          block_kv=block_kv, causal=causal, scale=scale,
                          kv_len=kv_len, emit_lse=return_residuals),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j, g=g: (b // g, j, 0)),
        ],
        out_specs=out_specs if return_residuals else out_specs[0],
        out_shape=out_shape if return_residuals else out_shape[0],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return res
