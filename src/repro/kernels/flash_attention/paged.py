"""Paged/slotted decode-attention Pallas TPU kernel for the serving engine.

One query token per request row, K/V read from a fixed pool of cache slots
(`serving.engine.kv_pool.SlotPool`).  The slot mapping and per-slot lengths
ride in as scalar-prefetch operands (`pltpu.PrefetchScalarGridSpec`), so the
K/V BlockSpec index maps *gather by slot index*: row b's kv blocks come from
pool slot `slot_idx[b]` — the Pallas analogue of vLLM's paged attention at
page size = one whole slot.

Grid (b, kv_heads, kv_steps), kv innermost; VMEM scratch carries the online
softmax state (m, l, acc) across kv steps (TPU grids are sequential per
core).  Per-slot lengths do double duty:
  * kv blocks entirely past `lengths[b]` are skipped via pl.when — a dead
    slot (length 0) costs zero FLOPs and writes zeros;
  * the tail block is masked elementwise so slot-pool positions past the
    sequence's live prefix (stale data from a previous occupant) never
    contribute.

The score tile is (g, block_kv) where g = query heads per kv head: decode
works at tiny sublane occupancy by construction (the paper's skinny-GEMM
regime); block_kv is the lane-side knob the autotuner sweeps
(`tuning.search.autotune_paged_decode`).

`paged_decode_blocktable_pallas` is the block-table variant (vLLM paged
attention at a real page size): K/V live in a pool of physical blocks of
`block_size` tokens and row b's logical kv block j comes from
`block_table[b, j]`.  The scalar-prefetch operands carry `(block_table[b, j],
lengths[b])`, so the BlockSpec index map gathers each kv tile from an
arbitrary physical block; the kernel body is shared with the slot variant
(it only sees logical kv positions).  Here *two* knobs are tile-lattice
choices the autotuner sweeps jointly: the physical block size (the paging
granule, a weight on copy/gather cost and sharing granularity) and block_kv
(the kv tile per grid step, dividing the block size) — see
`tuning.search.autotune_paged_decode_blocktable`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_kernel(slot_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                  kv_steps: int, block_kv: int, scale: float):
    # rest is (o, m, l, acc) for the plain variant, or
    # (ks, vs, o, m, l, acc) when the pool is int8-quantized KV: the scale
    # tiles ride as extra inputs and the dequant happens per kv tile, so the
    # pool stays 1 byte/elem in HBM and only live tiles pay the multiply.
    if len(rest) == 6:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    b_i, ki = pl.program_id(0), pl.program_id(2)
    length = len_ref[b_i]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks wholly past the live prefix (dead slot: skips everything)
    @pl.when(ki * block_kv < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (bkv, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)    # (bkv, d)
        if ks_ref is not None:
            k = k * ks_ref[0, :, 0][:, None]         # per-(token, head) scale
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kv_pos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_kv), 1)
        s = jnp.where(kv_pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # dead slot -> zero output
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_pallas(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        slot_idx: jax.Array, lengths: jax.Array, *,
                        k_scale: jax.Array | None = None,
                        v_scale: jax.Array | None = None,
                        block_kv: int = 128, scale: float | None = None,
                        interpret: bool = False) -> jax.Array:
    """q: (b, a, d) one token per row; k_pool, v_pool: (slots, s_max, nkv, d);
    slot_idx: (b,) int32 row->slot; lengths: (b,) int32 live kv per row.

    k_scale/v_scale: (slots, s_max, nkv) f32 per-(token, kv_head) dequant
    scales for an int8 pool (both or neither); the kernel dequantizes each
    kv tile in VMEM, so HBM traffic stays at 1 byte per cached element.

    Requires s_max % block_kv == 0 (ops.py clamps/pads) and a % nkv == 0.
    Returns (b, a, d); rows with length 0 return zeros.
    """
    b, a, d = q.shape
    slots, s_max, nkv, dk = k_pool.shape
    assert d == dk and a % nkv == 0
    assert s_max % block_kv == 0, (s_max, block_kv)
    assert (k_scale is None) == (v_scale is None)
    g = a // nkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_steps = s_max // block_kv
    qh = q.reshape(b, nkv, g, d)
    from jax.experimental.pallas import tpu as pltpu
    kv_spec = pl.BlockSpec((1, block_kv, 1, d),
                           lambda bi, h, j, slot, lens: (slot[bi], j, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, g, d),
                     lambda bi, h, j, slot, lens: (bi, h, 0, 0)),
        kv_spec,
        kv_spec,
    ]
    operands = [qh, k_pool, v_pool]
    if k_scale is not None:
        assert k_scale.shape == (slots, s_max, nkv), k_scale.shape
        sc_spec = pl.BlockSpec((1, block_kv, 1),
                               lambda bi, h, j, slot, lens: (slot[bi], j, h))
        in_specs += [sc_spec, sc_spec]
        operands += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nkv, kv_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, h, j, slot, lens: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, kv_steps=kv_steps,
                          block_kv=block_kv, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, d), q.dtype),
        interpret=interpret,
    )(slot_idx.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
    return out.reshape(b, a, d)


def paged_decode_blocktable_pallas(q: jax.Array, k_blocks: jax.Array,
                                   v_blocks: jax.Array,
                                   block_tables: jax.Array,
                                   lengths: jax.Array, *,
                                   k_scale: jax.Array | None = None,
                                   v_scale: jax.Array | None = None,
                                   block_kv: int | None = None,
                                   scale: float | None = None,
                                   interpret: bool = False) -> jax.Array:
    """q: (b, a, d) one token per row; k_blocks, v_blocks: (num_blocks,
    block_size, nkv, d) physical KV block pool; block_tables: (b,
    max_blocks) int32 — row b's logical kv block j lives in physical block
    `block_tables[b, j]`; lengths: (b,) live kv per row.

    k_scale/v_scale: (num_blocks, block_size, nkv) f32 per-(token, kv_head)
    dequant scales for an int8 block pool (both or neither); tiles are
    dequantized in VMEM after the gather-by-table DMA.

    block_kv (default block_size) must divide block_size; the grid runs
    max_blocks * block_size/block_kv kv steps per (row, head) and skips
    steps wholly past `lengths[b]`, so table entries beyond a row's live
    blocks are never read (callers pad with any valid block id).
    Returns (b, a, d); rows with length 0 return zeros.
    """
    b, a, d = q.shape
    nb, block_size, nkv, dk = k_blocks.shape
    bt_rows, max_blocks = block_tables.shape
    assert d == dk and a % nkv == 0 and bt_rows == b
    assert (k_scale is None) == (v_scale is None)
    block_kv = block_kv or block_size
    assert block_size % block_kv == 0, (block_size, block_kv)
    g = a // nkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    steps_per_block = block_size // block_kv
    kv_steps = max_blocks * steps_per_block
    qh = q.reshape(b, nkv, g, d)
    from jax.experimental.pallas import tpu as pltpu

    def kv_spec():
        # logical kv step j -> (physical block, tile within block): the
        # scalar-prefetched table is indexed *inside the index map*, so the
        # DMA for row bi streams straight from the right physical block
        return pl.BlockSpec(
            (1, block_kv, 1, d),
            lambda bi, h, j, table, lens: (table[bi, j // steps_per_block],
                                           j % steps_per_block, h, 0))

    in_specs = [
        pl.BlockSpec((1, 1, g, d),
                     lambda bi, h, j, table, lens: (bi, h, 0, 0)),
        kv_spec(),
        kv_spec(),
    ]
    operands = [qh, k_blocks, v_blocks]
    if k_scale is not None:
        assert k_scale.shape == (nb, block_size, nkv), k_scale.shape
        def sc_spec():
            return pl.BlockSpec(
                (1, block_kv, 1),
                lambda bi, h, j, table, lens: (table[bi, j // steps_per_block],
                                               j % steps_per_block, h))
        in_specs += [sc_spec(), sc_spec()]
        operands += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nkv, kv_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, h, j, table, lens: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    # the kernel body is the slot variant's: it reasons purely in logical kv
    # positions (ki * block_kv + offset vs lengths[b]); only the index maps
    # above know the physical indirection
    out = pl.pallas_call(
        functools.partial(_paged_kernel, kv_steps=kv_steps,
                          block_kv=block_kv, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
    return out.reshape(b, a, d)
