"""FlashAttention-2-style Pallas TPU backward kernels: dq, dk, dv.

Two kernels, mirroring the FA2 split (Dao 2023, §3.1) so neither needs
atomics on a sequential TPU grid:

  dq  — grid (batch*q_heads, q_blocks, kv_blocks), kv innermost; a VMEM
        accumulator carries dq for one q block across kv steps (the same
        iteration order as the forward).
  dkv — grid (batch*q_heads, kv_blocks, q_blocks), q innermost; VMEM
        accumulators carry (dk, dv) for one kv block across q steps.

Both recompute the score tile from (q, k) and the softmax probabilities from
the saved per-row logsumexp (`p = exp(s·scale - lse)`) instead of storing
the s^2 probability matrix — the whole point of the fused backward: HBM
traffic stays O(s·block), matching the forward's roofline position.

GQA: inputs k, v stay at kv-head resolution (the BlockSpec maps q-head b to
kv-head b // g, as in the forward); dk/dv are emitted at *query*-head
resolution (bh rows) and ops.py reduces the g-sized head groups outside the
kernel — a (g·skv·d) temp instead of cross-grid-step output revisiting,
which Pallas TPU does not order-guarantee.

Masking reuses the forward's `mask_block` (causal + padded-KV `kv_len`
columns); masked entries give p = 0 and ds = 0, so padded keys and padded
query rows (do = 0 there) contribute exactly zero gradient.  Fully-masked
rows carry lse = 0 from the forward guard, keeping every exp() finite.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .kernel import block_live, mask_block


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, di_ref, dq_ref, acc_ref,
               *, kv_steps: int, block_q: int, block_kv: int, causal: bool,
               scale: float, kv_len: int | None):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bkv, d)
        v = v_ref[0].astype(jnp.float32)            # (bkv, d)
        do = do_ref[0].astype(jnp.float32)          # (bq, d)
        lse = lse_ref[0]                            # (bq,)
        di = di_ref[0]                              # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = mask_block(s, qi, ki, block_q=block_q, block_kv=block_kv,
                       causal=causal, kv_len=kv_len)
        p = jnp.exp(s - lse[:, None])               # (bq, bkv)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - di[:, None]) * scale
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    live = block_live(qi, ki, block_q=block_q, block_kv=block_kv,
                      causal=causal, kv_len=kv_len)
    _step() if live is None else pl.when(live)(_step)

    @pl.when(ki == kv_steps - 1)
    def _done():
        dq_ref[0, ...] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, di_ref, dk_ref, dv_ref,
                dk_acc, dv_acc, *, q_steps: int, block_q: int, block_kv: int,
                causal: bool, scale: float, kv_len: int | None):
    ki, qi = pl.program_id(1), pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def _step():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bkv, d)
        v = v_ref[0].astype(jnp.float32)            # (bkv, d)
        do = do_ref[0].astype(jnp.float32)          # (bq, d)
        lse = lse_ref[0]                            # (bq,)
        di = di_ref[0]                              # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = mask_block(s, qi, ki, block_q=block_q, block_kv=block_kv,
                       causal=causal, kv_len=kv_len)
        p = jnp.exp(s - lse[:, None])               # (bq, bkv)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - di[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    live = block_live(qi, ki, block_q=block_q, block_kv=block_kv,
                      causal=causal, kv_len=kv_len)
    _step() if live is None else pl.when(live)(_step)

    @pl.when(qi == q_steps - 1)
    def _done():
        dk_ref[0, ...] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, ...] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(q, k, v, o, lse, do, *, causal: bool = True,
                               block_q: int = 128, block_kv: int = 128,
                               scale: float | None = None,
                               kv_len: int | None = None,
                               interpret: bool = False):
    """Fused backward for `flash_attention_pallas`.

    q, do: (bh, sq, d); k, v: (bkv_h, skv, d); o: (bh, sq, d);
    lse: (bh, sq) f32 from the forward's return_residuals=True.
    Requires sq % block_q == 0 and skv % block_kv == 0 (ops.py pads).

    Returns (dq, dk_heads, dv_heads) with dk/dv at query-head resolution
    (bh, skv, d) — the caller reduces head groups g = bh // bkv_h.
    """
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    assert bh % bkv == 0
    g = bh // bkv
    assert sq % block_q == 0 and skv % block_kv == 0
    if kv_len is not None and kv_len >= skv:
        kv_len = None
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    # di = rowsum(do * o): the softmax-jacobian diagonal term, cheap in XLA
    di = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    from jax.experimental.pallas import tpu as pltpu
    q_steps, kv_steps = sq // block_q, skv // block_kv

    qspec = pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0))
    kvspec = pl.BlockSpec((1, block_kv, d), lambda b, i, j, g=g: (b // g, j, 0))
    rowspec = pl.BlockSpec((1, block_q), lambda b, i, j: (b, i))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, kv_steps=kv_steps, block_q=block_q,
                          block_kv=block_kv, causal=causal, scale=scale,
                          kv_len=kv_len),
        grid=(bh, q_steps, kv_steps),
        in_specs=[qspec, kvspec, kvspec, qspec, rowspec, rowspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, di)

    # dkv grid transposes the block walk: kv outer, q inner
    qspec_t = pl.BlockSpec((1, block_q, d), lambda b, j, i: (b, i, 0))
    kvspec_t = pl.BlockSpec((1, block_kv, d), lambda b, j, i, g=g: (b // g, j, 0))
    rowspec_t = pl.BlockSpec((1, block_q), lambda b, j, i: (b, i))
    dkvspec = pl.BlockSpec((1, block_kv, d), lambda b, j, i: (b, j, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, q_steps=q_steps, block_q=block_q,
                          block_kv=block_kv, causal=causal, scale=scale,
                          kv_len=kv_len),
        grid=(bh, kv_steps, q_steps),
        in_specs=[qspec_t, kvspec_t, kvspec_t, qspec_t, rowspec_t, rowspec_t],
        out_specs=[dkvspec, dkvspec],
        out_shape=[jax.ShapeDtypeStruct((bh, skv, d), k.dtype),
                   jax.ShapeDtypeStruct((bh, skv, d), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_kv, d), jnp.float32),
                        pltpu.VMEM((block_kv, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, di)
    return dq, dk, dv
