"""jit'd public wrapper for the flash-attention kernel.

`flash_attention` takes model-layout tensors (b, s, heads, head_dim), folds
batch x heads, pads seq to the block grid, dispatches to the Pallas kernel
(TPU) or the jnp oracle (CPU fallback / use_pallas=False).

With `tuned=True` the wrapper consults the autotuning cache
(`repro.tuning.cache`) for a measured-best (block_q, block_kv) for this
exact problem before falling back to the 128x128 default — see
`repro.tuning.search.autotune_flash_attention`.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.hardware import get_hardware
from ...core.quantization import round_up
from ...tuning.cache import lookup as _tuning_lookup
from .kernel import flash_attention_pallas
from .paged import paged_decode_pallas
from .ref import attention_ref, paged_decode_ref


def _fold(x):
    b, s, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)


def _unfold(x, b, h):
    bh, s, d = x.shape
    return x.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret", "use_pallas"))
def _flash_jit(q, k, v, *, causal: bool, block_q: int, block_kv: int,
               interpret: bool, use_pallas: bool):
    b, sq, a, d = q.shape
    qf, kf, vf = _fold(q), _fold(k), _fold(v)
    if not use_pallas:
        return _unfold(attention_ref(qf, kf, vf, causal=causal), b, a)
    skv = k.shape[1]
    sq_p = round_up(sq, block_q)
    skv_p = round_up(skv, block_kv)
    if sq_p != sq:
        qf = jnp.pad(qf, ((0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        # padded kv positions are masked out by the causal rule for decode-
        # free use; for non-causal we mask via a -inf score on padded keys,
        # implemented by zero-padding k and relying on softmax renorm error
        # being sliced away only when causal guards it — so require causal
        # or exact skv here.
        assert causal, "non-causal flash requires skv % block_kv == 0"
        kf = jnp.pad(kf, ((0, 0), (0, skv_p - skv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, skv_p - skv), (0, 0)))
    out = flash_attention_pallas(qf, kf, vf, causal=causal, block_q=block_q,
                                 block_kv=block_kv, interpret=interpret)
    return _unfold(out[:, :sq], b, a)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = True,
                    use_pallas: bool = True, tuned: bool = False,
                    hw_name: Optional[str] = None):
    """q: (b, sq, a, d); k, v: (b, skv, kv_heads, d).  Returns (b, sq, a, d).

    tuned=True overrides (block_q, block_kv) with the autotuning cache's
    measured-best config for this problem when one exists (cache misses keep
    the defaults).  The lookup runs at trace time, outside the jit.
    """
    if tuned and use_pallas:
        b, sq, a, d = q.shape
        skv = k.shape[1]
        op = ("flash_attention_causal" if causal else "flash_attention_full")
        cfg = _tuning_lookup(op, (b, sq, skv, a, d),
                             jnp.dtype(q.dtype).name,
                             hw_name or get_hardware().name)
        if cfg is not None:
            block_q = cfg.blocks["block_q"]
            block_kv = cfg.blocks["block_kv"]
    return _flash_jit(q, k, v, causal=causal, block_q=block_q,
                      block_kv=block_kv, interpret=interpret,
                      use_pallas=use_pallas)


# --- paged decode (serving engine) ---------------------------------------------------

# In-model kernel dispatch (models.attention attn_impl="paged") has no
# per-call interpret kwarg to thread, so it follows this env toggle: the
# default True matches the CPU container; a TPU deployment exports
# REPRO_KERNEL_INTERPRET=0 to run the compiled kernel.
ENV_INTERPRET = "REPRO_KERNEL_INTERPRET"


def default_interpret() -> bool:
    return os.environ.get(ENV_INTERPRET, "1") != "0"

@functools.partial(jax.jit, static_argnames=("block_kv", "interpret",
                                             "use_pallas"))
def _paged_jit(q, k_pool, v_pool, slot_idx, lengths, *, block_kv: int,
               interpret: bool, use_pallas: bool):
    if not use_pallas:
        return paged_decode_ref(q, k_pool, v_pool, slot_idx, lengths)
    s_max = k_pool.shape[1]
    bkv = min(block_kv, s_max)
    if s_max % bkv:
        # clamp to a divisor rather than padding: a pad would copy the whole
        # pool inside the decode program, every layer, every step.  Pool
        # depths are lane-aligned and block_kv candidates are lane
        # multiples, so the gcd stays a healthy tile-aligned block.
        import math
        g = math.gcd(s_max, bkv)
        if g >= 16:
            bkv = g
        else:  # pathological caller shapes only: pad once here
            pad = round_up(s_max, bkv) - s_max
            k_pool = jnp.pad(k_pool, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_pool = jnp.pad(v_pool, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return paged_decode_pallas(q, k_pool, v_pool, slot_idx, lengths,
                               block_kv=bkv, interpret=interpret)


def paged_decode(q, k_pool, v_pool, slot_idx, lengths, *,
                 block_kv: int = 128, interpret: bool = True,
                 use_pallas: bool = True, tuned: bool = False,
                 hw_name: Optional[str] = None):
    """Slot-gathering decode attention over a fixed KV pool.

    q: (b, a, d) — one query token per active request row; k_pool, v_pool:
    (slots, s_max, nkv, d); slot_idx: (b,) row->slot; lengths: (b,) live kv
    entries (0 = dead slot -> zero output).  Returns (b, a, d).

    tuned=True overrides block_kv with the autotuning cache's measured-best
    for this pool shape (op "paged_decode") when one exists — see
    `repro.tuning.search.autotune_paged_decode`.
    """
    if tuned and use_pallas:
        b, a, d = q.shape
        slots, s_max, nkv, _ = k_pool.shape
        cfg = _tuning_lookup("paged_decode", (b, slots, s_max, nkv, a, d),
                             jnp.dtype(q.dtype).name,
                             hw_name or get_hardware().name)
        if cfg is not None:
            block_kv = cfg.blocks["block_kv"]
    return _paged_jit(q, k_pool, v_pool, slot_idx, lengths,
                      block_kv=block_kv, interpret=interpret,
                      use_pallas=use_pallas)
