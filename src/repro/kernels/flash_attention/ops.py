"""jit'd public wrapper for the flash-attention kernel.

`flash_attention` takes model-layout tensors (b, s, heads, head_dim), folds
batch x heads, pads seq to the block grid, dispatches to the Pallas kernel
(TPU) or the jnp oracle (CPU fallback / use_pallas=False).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.quantization import round_up
from .kernel import flash_attention_pallas
from .ref import attention_ref


def _fold(x):
    b, s, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)


def _unfold(x, b, h):
    bh, s, d = x.shape
    return x.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret", "use_pallas"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = True,
                    use_pallas: bool = True):
    """q: (b, sq, a, d); k, v: (b, skv, kv_heads, d).  Returns (b, sq, a, d)."""
    b, sq, a, d = q.shape
    _, skv, nkv, _ = k.shape
    qf, kf, vf = _fold(q), _fold(k), _fold(v)
    if not use_pallas:
        return _unfold(attention_ref(qf, kf, vf, causal=causal), b, a)
    sq_p = round_up(sq, block_q)
    skv_p = round_up(skv, block_kv)
    if sq_p != sq:
        qf = jnp.pad(qf, ((0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        # padded kv positions are masked out by the causal rule for decode-
        # free use; for non-causal we mask via a -inf score on padded keys,
        # implemented by zero-padding k and relying on softmax renorm error
        # being sliced away only when causal guards it — so require causal
        # or exact skv here.
        assert causal, "non-causal flash requires skv % block_kv == 0"
        kf = jnp.pad(kf, ((0, 0), (0, skv_p - skv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, skv_p - skv), (0, 0)))
    out = flash_attention_pallas(qf, kf, vf, causal=causal, block_q=block_q,
                                 block_kv=block_kv, interpret=interpret)
    return _unfold(out[:, :sq], b, a)
