"""jit'd public wrapper for the flash-attention kernel.

`flash_attention` takes model-layout tensors (b, s, heads, head_dim), folds
batch x heads, pads seq to the block grid, dispatches to the Pallas kernel
(TPU) or the jnp oracle (CPU fallback / use_pallas=False).

The Pallas path is *differentiable*: a jax.custom_vjp pairs the forward
kernel (which saves per-row logsumexp residuals) with the fused Pallas
backward in `backward.py`, so `attn_impl="flash"` trains end-to-end on the
measured kernels.  Padded KV columns are masked inside the kernel via a real
`kv_len` (not the causal rule), so non-causal and cross-attention shapes
with unaligned skv are exact.

With `tuned=True` the wrapper consults the autotuning cache
(`repro.tuning.cache`) for a measured-best (block_q, block_kv) for this
exact problem — and separately for the backward blocks (op
"flash_attention_bwd_*") — before falling back to the 128x128 defaults; see
`repro.tuning.search.autotune_flash_attention` / `autotune_flash_backward`.
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ... import obs
from ...core.hardware import get_hardware
from ...core.quantization import round_up
from ...quant import dequantize_kv
from ...tuning.cache import lookup as _tuning_lookup
from ...tuning.cache import mixed_dtype
from .backward import flash_attention_bwd_pallas
from .kernel import flash_attention_pallas
from .paged import paged_decode_blocktable_pallas, paged_decode_pallas
from .ref import (attention_ref, paged_decode_blocktable_ref,
                  paged_decode_ref)


def _fold(x):
    b, s, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)


def _unfold(x, b, h):
    bh, s, d = x.shape
    return x.reshape(b, h, s, d).transpose(0, 2, 1, 3)


class _FlashConfig(NamedTuple):
    """Static kernel config threaded through the custom_vjp (hashable)."""
    causal: bool
    block_q: int
    block_kv: int
    bwd_block_q: int
    bwd_block_kv: int
    interpret: bool


def _pad_seq(x, target: int):
    s = x.shape[1]
    return x if s == target else jnp.pad(x, ((0, 0), (0, target - s), (0, 0)))


def _flash_fwd(cfg: _FlashConfig, q, k, v, need_residuals: bool):
    """Pad folded (bh, s, d) tensors to the block grid and run the forward
    kernel.  Returns (out, lse) sliced back to the real sq; lse is None on
    the residual-free path (inference forwards skip the logsumexp work —
    pallas_call is opaque to XLA, so DCE could never drop it)."""
    sq, skv = q.shape[1], k.shape[1]
    qf = _pad_seq(q, round_up(sq, cfg.block_q))
    kf = _pad_seq(k, round_up(skv, cfg.block_kv))
    vf = _pad_seq(v, round_up(skv, cfg.block_kv))
    res = flash_attention_pallas(
        qf, kf, vf, causal=cfg.causal, block_q=cfg.block_q,
        block_kv=cfg.block_kv, kv_len=skv, return_residuals=need_residuals,
        interpret=cfg.interpret)
    if need_residuals:
        out, lse = res
        return out[:, :sq], lse[:, :sq]
    return res[:, :sq], None


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_core(cfg: _FlashConfig, q, k, v):
    return _flash_fwd(cfg, q, k, v, need_residuals=False)[0]


def _flash_core_fwd(cfg: _FlashConfig, q, k, v):
    out, lse = _flash_fwd(cfg, q, k, v, need_residuals=True)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(cfg: _FlashConfig, residuals, g):
    q, k, v, out, lse = residuals
    sq, skv = q.shape[1], k.shape[1]
    bq, bkv = cfg.bwd_block_q, cfg.bwd_block_kv
    sq_p, skv_p = round_up(sq, bq), round_up(skv, bkv)
    # padded query rows carry do = 0 (and lse = 0, kept finite by the
    # forward's masked-row guard), so they contribute exactly zero gradient
    dq, dk_h, dv_h = flash_attention_bwd_pallas(
        _pad_seq(q, sq_p), _pad_seq(k, skv_p), _pad_seq(v, skv_p),
        _pad_seq(out, sq_p), _pad_seq(lse[..., None], sq_p)[..., 0],
        _pad_seq(g, sq_p), causal=cfg.causal, block_q=bq, block_kv=bkv,
        kv_len=skv, interpret=cfg.interpret)
    bh = q.shape[0]
    bkv_h = k.shape[0]
    grp = bh // bkv_h
    # dk/dv come back at query-head resolution: reduce each GQA head group
    dk = dk_h[:, :skv].reshape(bkv_h, grp, skv, -1).sum(1)
    dv = dv_h[:, :skv].reshape(bkv_h, grp, skv, -1).sum(1)
    return (dq[:, :sq].astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "bwd_block_q", "bwd_block_kv",
                                             "interpret", "use_pallas"))
def _flash_jit(q, k, v, *, causal: bool, block_q: int, block_kv: int,
               bwd_block_q: int, bwd_block_kv: int, interpret: bool,
               use_pallas: bool):
    b, sq, a, d = q.shape
    qf, kf, vf = _fold(q), _fold(k), _fold(v)
    if not use_pallas:
        return _unfold(attention_ref(qf, kf, vf, causal=causal), b, a)
    cfg = _FlashConfig(causal=causal, block_q=block_q, block_kv=block_kv,
                       bwd_block_q=bwd_block_q, bwd_block_kv=bwd_block_kv,
                       interpret=interpret)
    return _unfold(_flash_core(cfg, qf, kf, vf), b, a)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, bwd_block_q: int = 128,
                    bwd_block_kv: int = 128, interpret: bool = True,
                    use_pallas: bool = True, tuned: bool = False,
                    hw_name: Optional[str] = None):
    """q: (b, sq, a, d); k, v: (b, skv, kv_heads, d).  Returns (b, sq, a, d).

    Differentiable: the Pallas path carries a custom VJP onto the fused
    backward kernels (backward.py), so this op can sit inside value_and_grad
    / train_step.  (bwd_block_q, bwd_block_kv) block the backward grids
    independently of the forward.

    tuned=True overrides the forward (block_q, block_kv) — and the backward
    blocks, from the separate "flash_attention_bwd_*" entries — with the
    autotuning cache's measured-best config for this problem when one exists
    (cache misses keep the defaults).  Lookups run at trace time, outside
    the jit.
    """
    tuned_hit = None
    if tuned and use_pallas:
        b, sq, a, d = q.shape
        skv = k.shape[1]
        dtype = jnp.dtype(q.dtype).name
        hw = hw_name or get_hardware().name
        op = ("flash_attention_causal" if causal else "flash_attention_full")
        cfg = _tuning_lookup(op, (b, sq, skv, a, d), dtype, hw)
        tuned_hit = cfg is not None
        if cfg is not None:
            block_q = cfg.blocks["block_q"]
            block_kv = cfg.blocks["block_kv"]
        op_bwd = ("flash_attention_bwd_causal" if causal
                  else "flash_attention_bwd_full")
        cfg_bwd = _tuning_lookup(op_bwd, (b, sq, skv, a, d), dtype, hw)
        if cfg_bwd is not None:
            bwd_block_q = cfg_bwd.blocks["block_q"]
            bwd_block_kv = cfg_bwd.blocks["block_kv"]
    if obs.enabled():
        obs.record_dispatch(
            "flash_attention_causal" if causal else "flash_attention_full",
            impl="pallas" if use_pallas else "jnp", shape=q.shape,
            blocks={"block_q": block_q,
                    "block_kv": block_kv} if use_pallas else None,
            tuned_hit=tuned_hit)
    return _flash_jit(q, k, v, causal=causal, block_q=block_q,
                      block_kv=block_kv, bwd_block_q=bwd_block_q,
                      bwd_block_kv=bwd_block_kv, interpret=interpret,
                      use_pallas=use_pallas)


# --- paged decode (serving engine) ---------------------------------------------------

# In-model kernel dispatch (models.attention attn_impl="paged") has no
# per-call interpret kwarg to thread, so it follows this env toggle: the
# default True matches the CPU container; a TPU deployment exports
# REPRO_KERNEL_INTERPRET=0 to run the compiled kernel.
ENV_INTERPRET = "REPRO_KERNEL_INTERPRET"


def default_interpret() -> bool:
    return os.environ.get(ENV_INTERPRET, "1") != "0"

@functools.partial(jax.jit, static_argnames=("block_kv", "interpret",
                                             "use_pallas"))
def _paged_jit(q, k_pool, v_pool, slot_idx, lengths, k_scale, v_scale, *,
               block_kv: int, interpret: bool, use_pallas: bool):
    if not use_pallas:
        if k_scale is not None:
            k_pool = dequantize_kv(k_pool, k_scale, q.dtype)
            v_pool = dequantize_kv(v_pool, v_scale, q.dtype)
        return paged_decode_ref(q, k_pool.astype(q.dtype),
                                v_pool.astype(q.dtype), slot_idx, lengths)
    s_max = k_pool.shape[1]
    bkv = min(block_kv, s_max)
    if s_max % bkv:
        # clamp to a divisor rather than padding: a pad would copy the whole
        # pool inside the decode program, every layer, every step.  Pool
        # depths are lane-aligned and block_kv candidates are lane
        # multiples, so the gcd stays a healthy tile-aligned block.
        import math
        g = math.gcd(s_max, bkv)
        if g >= 16:
            bkv = g
        else:  # pathological caller shapes only: pad once here
            pad = round_up(s_max, bkv) - s_max
            k_pool = jnp.pad(k_pool, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_pool = jnp.pad(v_pool, ((0, 0), (0, pad), (0, 0), (0, 0)))
            if k_scale is not None:
                k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
                v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    return paged_decode_pallas(q, k_pool, v_pool, slot_idx, lengths,
                               k_scale=k_scale, v_scale=v_scale,
                               block_kv=bkv, interpret=interpret)


def paged_decode(q, k_pool, v_pool, slot_idx, lengths, *,
                 k_scale=None, v_scale=None,
                 block_kv: int = 128, interpret: bool = True,
                 use_pallas: bool = True, tuned: bool = False,
                 hw_name: Optional[str] = None):
    """Slot-gathering decode attention over a fixed KV pool.

    q: (b, a, d) — one query token per active request row; k_pool, v_pool:
    (slots, s_max, nkv, d); slot_idx: (b,) row->slot; lengths: (b,) live kv
    entries (0 = dead slot -> zero output).  Returns (b, a, d).

    k_scale/v_scale: (slots, s_max, nkv) f32 per-(token, kv_head) scales
    for an int8 KV pool (kv_dtype="int8"): the Pallas path dequantizes per
    kv tile inside the kernel; the jnp path dequantizes the pool up front.

    tuned=True overrides block_kv with the autotuning cache's measured-best
    for this pool shape (op "paged_decode") when one exists — see
    `repro.tuning.search.autotune_paged_decode`.  Quantized pools key the
    lookup by the mixed dtype pair (e.g. "bfloat16xint8").
    """
    tuned_hit = None
    dtype = jnp.dtype(q.dtype).name
    if k_scale is not None:
        dtype = mixed_dtype(dtype, jnp.dtype(k_pool.dtype).name)
    if tuned and use_pallas:
        b, a, d = q.shape
        slots, s_max, nkv, _ = k_pool.shape
        cfg = _tuning_lookup("paged_decode", (b, slots, s_max, nkv, a, d),
                             dtype, hw_name or get_hardware().name)
        tuned_hit = cfg is not None
        if cfg is not None:
            block_kv = cfg.blocks["block_kv"]
    if obs.enabled():
        obs.record_dispatch(
            "paged_decode", impl="pallas" if use_pallas else "jnp",
            shape=q.shape,
            blocks={"block_kv": block_kv} if use_pallas else None,
            tuned_hit=tuned_hit)
    return _paged_jit(q, k_pool, v_pool, slot_idx, lengths, k_scale, v_scale,
                      block_kv=block_kv, interpret=interpret,
                      use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret",
                                             "use_pallas"))
def _paged_bt_jit(q, k_blocks, v_blocks, block_tables, lengths, k_scale,
                  v_scale, *, block_kv: int, interpret: bool,
                  use_pallas: bool):
    if not use_pallas:
        if k_scale is not None:
            k_blocks = dequantize_kv(k_blocks, k_scale, q.dtype)
            v_blocks = dequantize_kv(v_blocks, v_scale, q.dtype)
        return paged_decode_blocktable_ref(q, k_blocks.astype(q.dtype),
                                           v_blocks.astype(q.dtype),
                                           block_tables, lengths)
    block_size = k_blocks.shape[1]
    bkv = min(block_kv, block_size)
    if block_size % bkv:
        # clamp to a divisor: the kv tile must stay inside one physical
        # block (tiles never straddle a page boundary)
        import math
        bkv = math.gcd(block_size, bkv)
    return paged_decode_blocktable_pallas(q, k_blocks, v_blocks,
                                          block_tables, lengths,
                                          k_scale=k_scale, v_scale=v_scale,
                                          block_kv=bkv, interpret=interpret)


def paged_decode_blocktable(q, k_blocks, v_blocks, block_tables, lengths, *,
                            k_scale=None, v_scale=None,
                            block_kv: Optional[int] = None,
                            interpret: bool = True, use_pallas: bool = True,
                            tuned: bool = False,
                            hw_name: Optional[str] = None):
    """Block-table decode attention over a physical KV block pool.

    q: (b, a, d) — one query token per active request row; k_blocks,
    v_blocks: (num_blocks, block_size, nkv, d); block_tables: (b,
    max_blocks) row -> physical block ids; lengths: (b,) live kv entries
    (0 = dead row -> zero output).  Returns (b, a, d).

    k_scale/v_scale: (num_blocks, block_size, nkv) f32 per-(token, kv_head)
    scales for an int8 block pool; dequantized per kv tile in-kernel on the
    Pallas path, up front on the jnp path.

    tuned=True overrides block_kv with the autotuning cache's measured-best
    for this block-pool shape (op "paged_decode_blocktable") when one exists
    — see `tuning.search.autotune_paged_decode_blocktable`, which sweeps the
    physical block size jointly and also records the winning pool geometry
    under op "paged_decode_blocktable_pool" for the engine to consult.
    Quantized pools key the lookup by the mixed dtype pair.
    """
    b, a, d = q.shape
    nb, block_size, nkv, _ = k_blocks.shape
    tuned_hit = None
    dtype = jnp.dtype(q.dtype).name
    if k_scale is not None:
        dtype = mixed_dtype(dtype, jnp.dtype(k_blocks.dtype).name)
    if tuned and use_pallas:
        cfg = _tuning_lookup("paged_decode_blocktable",
                             (b, nb, block_size, nkv, a, d),
                             dtype, hw_name or get_hardware().name)
        tuned_hit = cfg is not None
        if cfg is not None:
            block_kv = cfg.blocks["block_kv"]
    if obs.enabled():
        obs.record_dispatch(
            "paged_decode_blocktable",
            impl="pallas" if use_pallas else "jnp", shape=q.shape,
            blocks={"block_kv": block_kv or block_size,
                    "block_size": block_size} if use_pallas else None,
            tuned_hit=tuned_hit)
    return _paged_bt_jit(q, k_blocks, v_blocks, block_tables, lengths,
                         k_scale, v_scale, block_kv=block_kv or block_size,
                         interpret=interpret, use_pallas=use_pallas)
