"""Pure-jnp oracle for the SSD intra-chunk kernel."""
import jax.numpy as jnp

NEG_INF = -1e30


def ssd_chunk_ref(x_dt, B, C, seg):
    """x_dt: (bh, nc, Q, P); B, C: (bh, nc, Q, N); seg: (bh, nc, Q)."""
    Q = x_dt.shape[2]
    diff = seg[..., :, None] - seg[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(mask, diff, NEG_INF))
    CB = jnp.einsum("gcqn,gckn->gcqk", C.astype(jnp.float32),
                    B.astype(jnp.float32))
    y = jnp.einsum("gcqk,gckp->gcqp", CB * L, x_dt.astype(jnp.float32))
    decay = jnp.exp(seg[..., -1:] - seg)
    S = jnp.einsum("gcqn,gcqp->gcnp", B.astype(jnp.float32),
                   (x_dt * decay[..., None]).astype(jnp.float32))
    return y.astype(x_dt.dtype), S.astype(x_dt.dtype)
