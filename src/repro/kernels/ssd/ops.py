"""jit wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import ssd_chunk_pallas
from .ref import ssd_chunk_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def ssd_chunk(x_dt, B, C, seg, *, interpret: bool = True,
              use_pallas: bool = True):
    """Intra-chunk SSD: returns (Y_diag, chunk_states).

    Shapes: x_dt (bh, nc, Q, P); B, C (bh, nc, Q, N); seg (bh, nc, Q).
    The inter-chunk recurrence (associative scan over nc) remains the
    caller's job (models/ssm.py) — it is latency-bound, not MXU work.
    """
    if not use_pallas:
        return ssd_chunk_ref(x_dt, B, C, seg)
    return tuple(ssd_chunk_pallas(x_dt, B, C, seg, interpret=interpret))
