"""Mamba2 SSD intra-chunk Pallas kernel.

Computes, for one chunk of length Q per (batch, head) grid cell:
    Y_diag = ((C Bᵀ) ∘ L) · X        L[i,j] = exp(seg_i - seg_j), i >= j
    S      = Bᵀ · (decay_state ∘ X)   (the chunk's contribution to the
                                       inter-chunk state recurrence)
where seg is the within-chunk cumulative sum of dt·A.

This is the SSD analogue of the attention score/AOV BMM pair (DESIGN.md
§Arch-applicability): the (Q, N) x (N, Q) and (Q, Q) x (Q, P) matmuls run on
the MXU, with Q and N chosen as multiples of the 128-lane tile (the paper's
alignment rule with SSD's shape knobs).  The inter-chunk recurrence stays in
XLA (associative scan over nc chunks — latency-bound, not compute-bound).

Grid: (batch * heads, num_chunks).  Everything for one chunk fits VMEM:
Q=256, N=128, P=64 bf16 => ~0.4 MB working set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_chunk_kernel(x_ref, b_ref, c_ref, seg_ref, o_ref, s_ref, *,
                      chunk: int):
    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)  x·dt pre-scaled
    B = b_ref[0, 0].astype(jnp.float32)      # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)      # (Q, N)
    seg = seg_ref[0, 0].astype(jnp.float32)  # (Q,)

    # decay matrix with the mask inside the exponent (NaN-safe grads)
    diff = seg[:, None] - seg[None, :]                     # (Q, Q)
    iota_q = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.exp(jnp.where(iota_k <= iota_q, diff, NEG_INF))

    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(CB * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)
    o_ref[0, 0, ...] = y.astype(o_ref.dtype)

    # chunk state: S = sum_k B_k (decay_k x_k)^T   with decay = exp(seg_Q - seg_k)
    decay = jnp.exp(seg[-1] - seg)                                 # (Q,)
    xd = x * decay[:, None]
    S = jax.lax.dot_general(B, xd, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (N, P)
    s_ref[0, 0, ...] = S.astype(s_ref.dtype)


def ssd_chunk_pallas(x_dt: jax.Array, B: jax.Array, C: jax.Array,
                     seg: jax.Array, *, interpret: bool = False):
    """Intra-chunk SSD for all (bh, chunks).

    x_dt: (bh, nc, Q, P); B, C: (bh, nc, Q, N); seg: (bh, nc, Q).
    Returns (Y_diag (bh, nc, Q, P), S (bh, nc, N, P)).
    """
    bh, nc, Q, P = x_dt.shape
    N = B.shape[-1]
    grid = (bh, nc)
    return pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, chunk=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, c: (b, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc, Q, P), x_dt.dtype),
            jax.ShapeDtypeStruct((bh, nc, N, P), x_dt.dtype),
        ],
        interpret=interpret,
    )(
        x_dt.reshape(bh, nc, Q, P),
        B, C, seg,
    )
