"""Fused SwiGLU/MLP Pallas kernel: gate+up GEMM pair + elementwise combine
in one tiled pass, with a recompute-based custom-VJP backward (kernel.py /
backward.py / ops.py — same layout as kernels/flash_attention)."""
from .ops import fused_mlp_hidden, fused_mlp_op_name
from .ref import ACTS, MLP_TYPES, fused_mlp_hidden_ref, is_gated

__all__ = ["fused_mlp_hidden", "fused_mlp_op_name", "fused_mlp_hidden_ref",
           "ACTS", "MLP_TYPES", "is_gated"]
