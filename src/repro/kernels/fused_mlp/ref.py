"""Pure-jnp oracle for the fused MLP hidden computation, plus the
activation/derivative pairs shared between the forward kernel and the
recompute-based backward kernels.

The fused op is the *hidden half* of an MLP block:

    swiglu:       h = silu(x @ w_gate) * (x @ w_up)     (gated, 2 GEMMs)
    gelu / relu2: h = act(x @ w_up)                     (plain, 1 GEMM)

Fusing the gate/up GEMM pair with the elementwise silu*mul is the standard
full-stack move for the dominant transformer kernel (Kim et al., Full Stack
Optimization of Transformer Inference): the (m, f) gate and up activations
never round-trip through HBM — one hidden tensor is written instead of
three.  The down projection stays a plain GEMM (models.linear dispatches it).

Derivatives are written out explicitly (rather than jax.grad'd) because the
backward kernels recompute the pre-activations inside Pallas and need the
elementwise derivative as a plain function of the recomputed tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MLP_TYPES = ("swiglu", "gelu", "relu2")


def is_gated(mlp_type: str) -> bool:
    return mlp_type == "swiglu"


def _silu(z):
    return z * jax.nn.sigmoid(z)


def _dsilu(z):
    s = jax.nn.sigmoid(z)
    return s * (1.0 + z * (1.0 - s))


# tanh-approximate gelu (jax.nn.gelu's default), with its exact derivative
_C = 0.7978845608028654  # sqrt(2/pi)
_A = 0.044715


def _gelu(z):
    return 0.5 * z * (1.0 + jnp.tanh(_C * (z + _A * z * z * z)))


def _dgelu(z):
    t = jnp.tanh(_C * (z + _A * z * z * z))
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * _C * (1.0 + 3.0 * _A * z * z)


def _relu2(z):
    return jnp.square(jnp.maximum(z, 0.0))


def _drelu2(z):
    return 2.0 * jnp.maximum(z, 0.0)


# mlp_type -> (activation, derivative); swiglu's activation gates w_gate's GEMM
ACTS = {
    "swiglu": (_silu, _dsilu),
    "gelu": (_gelu, _dgelu),
    "relu2": (_relu2, _drelu2),
}


def fused_mlp_hidden_ref(x, w_gate, w_up, mlp_type: str = "swiglu"):
    """x: (m, h); w_gate (gated only), w_up: (h, f).  Returns (m, f)."""
    act, _ = ACTS[mlp_type]
    u = jnp.dot(x.astype(jnp.float32), w_up.astype(jnp.float32))
    if is_gated(mlp_type):
        g = jnp.dot(x.astype(jnp.float32), w_gate.astype(jnp.float32))
        return (act(g) * u).astype(x.dtype)
    return act(u).astype(x.dtype)
