"""Fused SwiGLU/MLP forward Pallas kernel — the paper's dominant GEMM pair
(§VII-B) executed as one tiled pass.

Grid (m_blocks, f_blocks, k_steps), k innermost, mirroring kernels/matmul:
each (i, j) output tile streams the shared x block once per k step while TWO
f32 VMEM accumulators carry the gate and up partial sums (TPU grids execute
sequentially per core, so scratch persists across the k steps of a tile).
At the last k step the elementwise epilogue — silu(gate) * up for swiglu,
act(up) for the 2-matrix variants — runs on the f32 accumulators and a
single (block_m, block_f) hidden tile is written.

Compared with two matmul_pallas calls + an XLA elementwise op, the fusion
(a) reads each x block once instead of twice and (b) never materializes the
(m, f) gate/up activations in HBM — exactly the activation-traffic saving
the roofline model attributes to the MLP hot path.

Block shapes are co-design knobs on the same (sublane, lane) lattice as the
matmul kernel; `tuning.candidates.fused_mlp_candidates` enumerates the
feasible set under the two-accumulator VMEM model and
`tuning.search.autotune_fused_mlp` persists measured winners.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ACTS, is_gated


def _gated_kernel(x_ref, wg_ref, wu_ref, o_ref, acc_g, acc_u, *,
                  k_steps: int, mlp_type: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[...]
    acc_g[...] += jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    acc_u[...] += jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        act, _ = ACTS[mlp_type]
        o_ref[...] = (act(acc_g[...]) * acc_u[...]).astype(o_ref.dtype)


def _plain_kernel(x_ref, wu_ref, o_ref, acc_u, *, k_steps: int, mlp_type: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_u[...] = jnp.zeros_like(acc_u)

    acc_u[...] += jnp.dot(x_ref[...], wu_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        act, _ = ACTS[mlp_type]
        o_ref[...] = act(acc_u[...]).astype(o_ref.dtype)


def fused_mlp_pallas(x: jax.Array, w_gate, w_up: jax.Array, *,
                     mlp_type: str = "swiglu", block_m: int = 128,
                     block_f: int = 128, block_k: int = 128,
                     out_dtype=None, interpret: bool = False) -> jax.Array:
    """Hidden = act-combine of the gate/up GEMMs.  x: (m, h); w_*: (h, f).

    Requires block-divisible shapes (ops.fused_mlp_hidden pads misaligned
    problems and slices the result — the tile-quantization cost the paper's
    utilization term prices stays explicit)."""
    m, h = x.shape
    h2, f = w_up.shape
    assert h == h2, (x.shape, w_up.shape)
    assert m % block_m == 0 and f % block_f == 0 and h % block_k == 0, (
        "fused_mlp_pallas requires padded shapes; use ops.fused_mlp_hidden")
    gated = is_gated(mlp_type)
    if gated:
        assert w_gate is not None and w_gate.shape == w_up.shape
    out_dtype = out_dtype or x.dtype
    k_steps = h // block_k
    grid = (m // block_m, f // block_f, k_steps)
    xspec = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
    wspec = pl.BlockSpec((block_k, block_f), lambda i, j, kk: (kk, j))
    ospec = pl.BlockSpec((block_m, block_f), lambda i, j, kk: (i, j))
    from jax.experimental.pallas import tpu as pltpu
    acc = pltpu.VMEM((block_m, block_f), jnp.float32)
    if gated:
        return pl.pallas_call(
            functools.partial(_gated_kernel, k_steps=k_steps, mlp_type=mlp_type),
            grid=grid,
            in_specs=[xspec, wspec, wspec],
            out_specs=ospec,
            out_shape=jax.ShapeDtypeStruct((m, f), out_dtype),
            scratch_shapes=[acc, acc],
            interpret=interpret,
        )(x, w_gate, w_up)
    return pl.pallas_call(
        functools.partial(_plain_kernel, k_steps=k_steps, mlp_type=mlp_type),
        grid=grid,
        in_specs=[xspec, wspec],
        out_specs=ospec,
        out_shape=jax.ShapeDtypeStruct((m, f), out_dtype),
        scratch_shapes=[acc],
        interpret=interpret,
    )(x, w_up)
