"""Pallas backward kernels for the fused MLP hidden op: dx and (dwg, dwu).

Two kernels, mirroring the flash-attention dq/dkv split (backward.py there)
so neither needs atomics on a sequential TPU grid:

  dx — grid (m_blocks, f_blocks), f innermost; a VMEM f32 accumulator
       carries dx for one m block across the f steps.
  dw — grid (f_blocks, m_blocks), m innermost; VMEM f32 accumulators carry
       (dwg, dwu) for one f block across the m steps.

Both *recompute* the gate/up pre-activations from (x, w) instead of storing
them — the same residual-free strategy as the flash backward (which
recomputes p from the saved logsumexp): the forward saves nothing but its
inputs, so activation memory for the MLP pair stays O(m*h + m*f_out), not
O(2*m*f).  The elementwise derivatives come from `ref.ACTS`.

The contraction (h) dimension rides un-blocked inside each kernel step, like
head_dim in the flash kernels: pre-activation recomputation needs full-k
GEMMs, so blocking h would force a second accumulation loop for no VMEM win
at model widths (block_m x h f32 is ~2 MB at h=4096).

Math, for hidden = act(x@wg) * (x@wu) (gated; plain drops the gate factor):

    g = x@wg, u = x@wu
    dg = dh * u * act'(g);  du = dh * act(g)
    dx = dg @ wg^T + du @ wu^T;  dwg = x^T @ dg;  dwu = x^T @ du
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ACTS, is_gated


def _tiles(x_ref, wu_ref, dh_ref, wg_ref, mlp_type: str):
    """Recompute the (dg, du) cotangent tiles for one (block_m, block_f)
    cell.  Returns (dg, du) with dg None on the un-gated path."""
    act, dact = ACTS[mlp_type]
    x = x_ref[...]
    wu = wu_ref[...]
    dh = dh_ref[...].astype(jnp.float32)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    if wg_ref is None:
        return None, dh * dact(u)
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    return dh * u * dact(g), dh * act(g)


def _dx_kernel(x_ref, *refs, f_steps: int, mlp_type: str):
    if is_gated(mlp_type):
        wg_ref, wu_ref, dh_ref, dx_ref, acc_ref = refs
    else:
        (wu_ref, dh_ref, dx_ref, acc_ref), wg_ref = refs, None
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dg, du = _tiles(x_ref, wu_ref, dh_ref, wg_ref, mlp_type)
    # d(pre) @ w^T contributions, contracted over the f block
    acc_ref[...] += jax.lax.dot_general(
        du, wu_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    if dg is not None:
        acc_ref[...] += jax.lax.dot_general(
            dg, wg_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(fi == f_steps - 1)
    def _done():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


def _dw_kernel(x_ref, *refs, m_steps: int, mlp_type: str):
    if is_gated(mlp_type):
        wg_ref, wu_ref, dh_ref, dwg_ref, dwu_ref, dwg_acc, dwu_acc = refs
    else:
        (wu_ref, dh_ref, dwu_ref, dwu_acc), wg_ref = refs, None
        dwg_ref = dwg_acc = None
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        dwu_acc[...] = jnp.zeros_like(dwu_acc)
        if dwg_acc is not None:
            dwg_acc[...] = jnp.zeros_like(dwg_acc)

    dg, du = _tiles(x_ref, wu_ref, dh_ref, wg_ref, mlp_type)
    # x^T @ d(pre) contributions, contracted over the m block
    x = x_ref[...]
    dwu_acc[...] += jax.lax.dot_general(
        x, du, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    if dg is not None:
        dwg_acc[...] += jax.lax.dot_general(
            x, dg, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(mi == m_steps - 1)
    def _done():
        dwu_ref[...] = dwu_acc[...].astype(dwu_ref.dtype)
        if dwg_ref is not None:
            dwg_ref[...] = dwg_acc[...].astype(dwg_ref.dtype)


def fused_mlp_bwd_pallas(x, w_gate, w_up, dh, *, mlp_type: str = "swiglu",
                         block_m: int = 128, block_f: int = 128,
                         interpret: bool = False):
    """Fused backward for `fused_mlp_pallas`.

    x: (m, h); w_gate (gated only), w_up: (h, f); dh: (m, f) cotangent.
    Requires m % block_m == 0 and f % block_f == 0 (ops.py pads; padded dh
    rows/columns are zero, so their dg/du tiles contribute exactly zero).

    Returns (dx, dwg, dwu) with dwg None on the un-gated path.
    """
    m, h = x.shape
    _, f = w_up.shape
    assert m % block_m == 0 and f % block_f == 0
    gated = is_gated(mlp_type)
    m_steps, f_steps = m // block_m, f // block_f

    from jax.experimental.pallas import tpu as pltpu
    xspec = pl.BlockSpec((block_m, h), lambda i, j: (i, 0))
    wspec = pl.BlockSpec((h, block_f), lambda i, j: (0, j))
    dhspec = pl.BlockSpec((block_m, block_f), lambda i, j: (i, j))
    ins = [x, w_gate, w_up, dh] if gated else [x, w_up, dh]
    in_specs = ([xspec, wspec, wspec, dhspec] if gated
                else [xspec, wspec, dhspec])
    dx = pl.pallas_call(
        functools.partial(_dx_kernel, f_steps=f_steps, mlp_type=mlp_type),
        grid=(m_steps, f_steps),
        in_specs=in_specs,
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct((m, h), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, h), jnp.float32)],
        interpret=interpret,
    )(*ins)

    # dw grid transposes the block walk: f outer, m inner
    xspec_t = pl.BlockSpec((block_m, h), lambda j, i: (i, 0))
    wspec_t = pl.BlockSpec((h, block_f), lambda j, i: (0, j))
    dhspec_t = pl.BlockSpec((block_m, block_f), lambda j, i: (i, j))
    in_specs_t = ([xspec_t, wspec_t, wspec_t, dhspec_t] if gated
                  else [xspec_t, wspec_t, dhspec_t])
    dw_shape = jax.ShapeDtypeStruct((h, f), w_up.dtype)
    dw_acc = pltpu.VMEM((h, block_f), jnp.float32)
    if gated:
        dwg, dwu = pl.pallas_call(
            functools.partial(_dw_kernel, m_steps=m_steps, mlp_type=mlp_type),
            grid=(f_steps, m_steps),
            in_specs=in_specs_t,
            out_specs=[wspec_t, wspec_t],
            out_shape=[dw_shape, dw_shape],
            scratch_shapes=[dw_acc, dw_acc],
            interpret=interpret,
        )(*ins)
        return dx, dwg, dwu
    dwu = pl.pallas_call(
        functools.partial(_dw_kernel, m_steps=m_steps, mlp_type=mlp_type),
        grid=(f_steps, m_steps),
        in_specs=in_specs_t,
        out_specs=wspec_t,
        out_shape=dw_shape,
        scratch_shapes=[dw_acc],
        interpret=interpret,
    )(*ins)
    return dx, None, dwu
