"""jit'd public wrapper for the fused SwiGLU/MLP hidden kernel.

`fused_mlp_hidden` takes model-layout activations (..., h), flattens the
leading dims to 2-D — producing exactly the (m, h, f) key the autotuner
writes — pads misaligned problems up to the block grid, and dispatches to
the Pallas kernel (TPU) or the jnp oracle (use_pallas=False).

The Pallas path is *differentiable*: a jax.custom_vjp pairs the forward
kernel with the recompute-based Pallas backward in `backward.py` (dx and
dw grids), so `linear_impl="fused"` trains end-to-end on the measured
kernels — the same forward/backward pattern as flash attention.

With `tuned=True` the wrapper consults the autotuning cache
(`repro.tuning.cache`) for a measured-best (block_m, block_f, block_k) for
this exact (m, h, f, dtype, hw) before falling back to the 128^3 defaults —
see `repro.tuning.search.autotune_fused_mlp` for how entries are produced.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ... import obs
from ...core.hardware import get_hardware
from ...core.quantization import round_up
from ...tuning.cache import lookup as _tuning_lookup
from .backward import fused_mlp_bwd_pallas
from .kernel import fused_mlp_pallas
from .ref import fused_mlp_hidden_ref, is_gated


def fused_mlp_op_name(mlp_type: str) -> str:
    """Tuning-cache op key: fused_mlp_swiglu | fused_mlp_gelu | ..."""
    return f"fused_mlp_{mlp_type}"


class _FusedConfig(NamedTuple):
    """Static kernel config threaded through the custom_vjp (hashable)."""
    mlp_type: str
    block_m: int
    block_f: int
    block_k: int
    bwd_block_m: int
    bwd_block_f: int
    interpret: bool


def _pad2(x, m, n):
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _fwd_call(cfg: _FusedConfig, x, w_gate, w_up):
    m, h = x.shape
    f = w_up.shape[1]
    mp = round_up(m, cfg.block_m)
    hp = round_up(h, cfg.block_k)
    fp = round_up(f, cfg.block_f)
    out = fused_mlp_pallas(
        _pad2(x, mp, hp),
        None if w_gate is None else _pad2(w_gate, hp, fp),
        _pad2(w_up, hp, fp), mlp_type=cfg.mlp_type, block_m=cfg.block_m,
        block_f=cfg.block_f, block_k=cfg.block_k, interpret=cfg.interpret)
    return out[:m, :f]


def _bwd_call(cfg: _FusedConfig, x, w_gate, w_up, dh):
    m, h = x.shape
    f = w_up.shape[1]
    mp = round_up(m, cfg.bwd_block_m)
    fp = round_up(f, cfg.bwd_block_f)
    # padded dh rows/columns are zero, so dg/du vanish there: the padding
    # contributes exactly zero to dx and to the sliced-off dw columns
    dx, dwg, dwu = fused_mlp_bwd_pallas(
        _pad2(x, mp, h),
        None if w_gate is None else _pad2(w_gate, h, fp),
        _pad2(w_up, h, fp), _pad2(dh, mp, fp), mlp_type=cfg.mlp_type,
        block_m=cfg.bwd_block_m, block_f=cfg.bwd_block_f,
        interpret=cfg.interpret)
    dx = dx[:m].astype(x.dtype)
    dwu = dwu[:, :f].astype(w_up.dtype)
    if dwg is None:
        return dx, dwu
    return dx, dwg[:, :f].astype(w_gate.dtype), dwu


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_gated(cfg: _FusedConfig, x, w_gate, w_up):
    return _fwd_call(cfg, x, w_gate, w_up)


def _fused_gated_fwd(cfg, x, w_gate, w_up):
    return _fwd_call(cfg, x, w_gate, w_up), (x, w_gate, w_up)


def _fused_gated_bwd(cfg, res, dh):
    x, w_gate, w_up = res
    return _bwd_call(cfg, x, w_gate, w_up, dh)


_fused_gated.defvjp(_fused_gated_fwd, _fused_gated_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_plain(cfg: _FusedConfig, x, w_up):
    return _fwd_call(cfg, x, None, w_up)


def _fused_plain_fwd(cfg, x, w_up):
    return _fwd_call(cfg, x, None, w_up), (x, w_up)


def _fused_plain_bwd(cfg, res, dh):
    x, w_up = res
    return _bwd_call(cfg, x, None, w_up, dh)


_fused_plain.defvjp(_fused_plain_fwd, _fused_plain_bwd)


@functools.partial(jax.jit, static_argnames=(
    "mlp_type", "block_m", "block_f", "block_k", "bwd_block_m", "bwd_block_f",
    "interpret", "use_pallas"))
def _fused_jit(x, w_gate, w_up, *, mlp_type: str, block_m: int, block_f: int,
               block_k: int, bwd_block_m: int, bwd_block_f: int,
               interpret: bool, use_pallas: bool):
    if not use_pallas:
        return fused_mlp_hidden_ref(x, w_gate, w_up, mlp_type)
    cfg = _FusedConfig(mlp_type=mlp_type, block_m=block_m, block_f=block_f,
                       block_k=block_k, bwd_block_m=bwd_block_m,
                       bwd_block_f=bwd_block_f, interpret=interpret)
    if is_gated(mlp_type):
        return _fused_gated(cfg, x, w_gate, w_up)
    return _fused_plain(cfg, x, w_up)


def fused_mlp_hidden(x, w_gate, w_up, *, mlp_type: str = "swiglu",
                     block_m: int = 128, block_f: int = 128,
                     block_k: int = 128, bwd_block_m: int = 128,
                     bwd_block_f: int = 128, interpret: bool = True,
                     use_pallas: bool = True, tuned: bool = False,
                     hw_name: Optional[str] = None):
    """hidden = act-combine(x @ w_gate, x @ w_up).  x: (..., h) -> (..., f).

    Differentiable: the Pallas path carries a custom VJP onto the
    recompute-based backward kernels (backward.py), so this op can sit
    inside value_and_grad / train_step.  (bwd_block_m, bwd_block_f) block
    the backward grids independently of the forward.

    tuned=True overrides (block_m, block_f, block_k) with the autotuning
    cache's measured-best config for this exact flattened (m, h, f) problem
    when one exists (cache misses keep the defaults).  The lookup runs at
    trace time, outside the jit, against the same key
    `tuning.search.autotune_fused_mlp` writes.
    """
    lead, h = x.shape[:-1], x.shape[-1]
    f = w_up.shape[-1]
    m = 1
    for d in lead:
        m *= d
    if not is_gated(mlp_type):
        w_gate = None
    tuned_hit = None
    if tuned and use_pallas:
        cfg = _tuning_lookup(fused_mlp_op_name(mlp_type), (m, h, f),
                             jnp.dtype(x.dtype).name,
                             hw_name or get_hardware().name)
        tuned_hit = cfg is not None
        if cfg is not None:
            block_m = cfg.blocks["block_m"]
            block_f = cfg.blocks["block_f"]
            block_k = cfg.blocks["block_k"]
    if obs.enabled():
        obs.record_dispatch(
            fused_mlp_op_name(mlp_type),
            impl="pallas" if use_pallas else "jnp", shape=(m, h, f),
            blocks={"block_m": block_m, "block_f": block_f,
                    "block_k": block_k} if use_pallas else None,
            tuned_hit=tuned_hit)
    out = _fused_jit(x.reshape(m, h), w_gate, w_up, mlp_type=mlp_type,
                     block_m=block_m, block_f=block_f, block_k=block_k,
                     bwd_block_m=bwd_block_m, bwd_block_f=bwd_block_f,
                     interpret=interpret, use_pallas=use_pallas)
    return out.reshape(*lead, f)
