"""jit'd public wrappers for the low-precision GEMM kernels.

Mirrors kernels/matmul/ops.py: pad misaligned problems up to the block grid,
slice the result, consult the autotuning cache when `tuned=True`.  The cache
dtype key is the *mixed* key (`tuning.cache.mixed_dtype`) — e.g.
``bfloat16xint8`` — because the activation and weight dtypes differ and an
int8-weight entry must never shadow a uniform-dtype entry for the same
(m, k, n).

Quantization policy:
  * weights quantize per output channel, once — pass a
    `repro.quant.QuantizedTensor` (from `quantize_weight`) to amortize, or a
    float matrix to quantize on the fly;
  * activations quantize per row *inside* the jit (dynamic quantization) —
    the absmax reduce fuses with the surrounding program;
  * fp8 is emulated: operands round-trip through fp8 storage and the GEMM
    itself runs the bf16-path `matmul_pallas` kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ... import obs
from ...core.hardware import get_hardware
from ...core.quantization import round_up
from ...quant import QuantizedTensor, fp8_round_trip, quantize_int8, quantize_weight
from ...tuning.cache import lookup as _tuning_lookup
from ...tuning.cache import mixed_dtype
from ..fused_mlp.ref import is_gated
from ..matmul.kernel import matmul_pallas
from ..matmul.ops import _pad2
from ..matmul.ref import matmul_ref
from .kernel import int8_fused_mlp_pallas, int8_matmul_pallas
from .ref import int8_fused_mlp_ref, int8_matmul_ref


def int8_fused_mlp_op_name(mlp_type: str) -> str:
    """Tuning-cache op key for the int8 fused-MLP hidden kernel."""
    return f"int8_fused_mlp_{mlp_type}"


def _as_quantized(w, name: str = "weight") -> QuantizedTensor:
    """Normalize a weight operand: pass through a prequantized container,
    quantize a float matrix per output channel on the fly."""
    if isinstance(w, QuantizedTensor):
        return w
    if w.dtype == jnp.int8:
        raise ValueError(
            f"{name}: raw int8 arrays are ambiguous — wrap the payload and "
            f"its scales in repro.quant.QuantizedTensor")
    return quantize_weight(w, "int8")


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "interpret", "use_pallas", "out_dtype"))
def _int8_matmul_jit(a, b_q, b_scale, *, block_m: int, block_n: int,
                     block_k: int, interpret: bool, use_pallas: bool,
                     out_dtype: str):
    a_q, a_scale = quantize_int8(a, axis=-1)
    if not use_pallas:
        return int8_matmul_ref(a_q, a_scale, b_q, b_scale, jnp.dtype(out_dtype))
    m, k = a_q.shape
    _, n = b_q.shape
    mp, kp, np_ = round_up(m, block_m), round_up(k, block_k), round_up(n, block_n)
    out = int8_matmul_pallas(
        _pad2(a_q, mp, kp), _pad2(b_q, kp, np_),
        _pad2(a_scale, mp, 1), _pad2(b_scale, 1, np_),
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=jnp.dtype(out_dtype), interpret=interpret)
    return out[:m, :n]


def int8_matmul(a: jax.Array, w, *,
                block_m: int = 128, block_n: int = 128, block_k: int = 128,
                interpret: bool = True, use_pallas: bool = True,
                tuned: bool = False, hw_name: Optional[str] = None,
                out_dtype=None) -> jax.Array:
    """C = dequant(quant(A) @ quant(W)).  A: (..., k) float; W: (k, n) float
    or a prequantized `QuantizedTensor`.  Leading dims of A flatten to one m
    axis (same cache-key discipline as ops.matmul).

    tuned=True consults the cache under op "int8_matmul" with the mixed
    dtype key (activation x weight), so int8 tiles tune independently of the
    bf16 tiles for the same shape.
    """
    lead = a.shape[:-1]
    if a.ndim != 2:
        a = a.reshape(-1, a.shape[-1])
    wq = _as_quantized(w)
    b_q, b_scale = wq.q, wq.scale.reshape(1, -1)
    out_dtype = jnp.dtype(out_dtype or a.dtype).name
    tuned_hit = None
    if tuned and use_pallas:
        m, k = a.shape
        _, n = b_q.shape
        cfg = _tuning_lookup(
            "int8_matmul", (m, k, n),
            mixed_dtype(jnp.dtype(a.dtype).name, "int8"),
            hw_name or get_hardware().name)
        tuned_hit = cfg is not None
        if cfg is not None:
            block_m = cfg.blocks["block_m"]
            block_n = cfg.blocks["block_n"]
            block_k = cfg.blocks["block_k"]
    if obs.enabled():
        obs.record_dispatch(
            "int8_matmul", impl="pallas" if use_pallas else "jnp",
            shape=(a.shape[0], a.shape[1], b_q.shape[-1]),
            blocks={"block_m": block_m, "block_n": block_n,
                    "block_k": block_k} if use_pallas else None,
            tuned_hit=tuned_hit)
    out = _int8_matmul_jit(a, b_q, b_scale, block_m=block_m, block_n=block_n,
                           block_k=block_k, interpret=interpret,
                           use_pallas=use_pallas, out_dtype=out_dtype)
    return out if len(lead) == 1 else out.reshape(*lead, b_q.shape[-1])


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "interpret", "use_pallas", "fp8_dtype"))
def _fp8_matmul_jit(a, b, *, block_m: int, block_n: int, block_k: int,
                    interpret: bool, use_pallas: bool, fp8_dtype: str):
    a8 = fp8_round_trip(a, fp8_dtype)
    b8 = fp8_round_trip(b, fp8_dtype)
    if not use_pallas:
        return matmul_ref(a8, b8)
    m, k = a8.shape
    _, n = b8.shape
    mp, kp, np_ = round_up(m, block_m), round_up(k, block_k), round_up(n, block_n)
    out = matmul_pallas(_pad2(a8, mp, kp), _pad2(b8, kp, np_),
                        block_m=block_m, block_n=block_n, block_k=block_k,
                        interpret=interpret)
    return out[:m, :n]


def fp8_matmul(a: jax.Array, b: jax.Array, *,
               fp8_dtype: str = "float8_e4m3fn",
               block_m: int = 128, block_n: int = 128, block_k: int = 128,
               interpret: bool = True, use_pallas: bool = True,
               tuned: bool = False, hw_name: Optional[str] = None) -> jax.Array:
    """Emulated-fp8 GEMM: round A and B through fp8 storage (e4m3 or e5m2),
    contract on the bf16-MXU-path kernel.  Cache op "fp8_matmul", mixed
    dtype key e.g. ``bfloat16xfloat8_e4m3fn``."""
    lead = a.shape[:-1]
    if a.ndim != 2:
        a = a.reshape(-1, a.shape[-1])
    tuned_hit = None
    if tuned and use_pallas:
        m, k = a.shape
        _, n = b.shape
        cfg = _tuning_lookup(
            "fp8_matmul", (m, k, n),
            mixed_dtype(jnp.dtype(a.dtype).name, fp8_dtype),
            hw_name or get_hardware().name)
        tuned_hit = cfg is not None
        if cfg is not None:
            block_m = cfg.blocks["block_m"]
            block_n = cfg.blocks["block_n"]
            block_k = cfg.blocks["block_k"]
    if obs.enabled():
        obs.record_dispatch(
            "fp8_matmul", impl="pallas" if use_pallas else "jnp",
            shape=(a.shape[0], a.shape[1], b.shape[-1]),
            blocks={"block_m": block_m, "block_n": block_n,
                    "block_k": block_k} if use_pallas else None,
            tuned_hit=tuned_hit)
    out = _fp8_matmul_jit(a, b, block_m=block_m, block_n=block_n,
                          block_k=block_k, interpret=interpret,
                          use_pallas=use_pallas, fp8_dtype=fp8_dtype)
    return out if len(lead) == 1 else out.reshape(*lead, b.shape[-1])


@functools.partial(jax.jit, static_argnames=(
    "mlp_type", "block_m", "block_f", "block_k", "interpret", "use_pallas",
    "out_dtype"))
def _int8_fused_mlp_jit(x, wg_q, wg_scale, wu_q, wu_scale, *, mlp_type: str,
                        block_m: int, block_f: int, block_k: int,
                        interpret: bool, use_pallas: bool, out_dtype: str):
    x_q, x_scale = quantize_int8(x, axis=-1)
    if not use_pallas:
        return int8_fused_mlp_ref(x_q, x_scale, wg_q, wg_scale, wu_q, wu_scale,
                                  mlp_type=mlp_type,
                                  out_dtype=jnp.dtype(out_dtype))
    m, h = x_q.shape
    _, f = wu_q.shape
    mp, hp, fp = round_up(m, block_m), round_up(h, block_k), round_up(f, block_f)
    gated = is_gated(mlp_type)
    out = int8_fused_mlp_pallas(
        _pad2(x_q, mp, hp),
        _pad2(wg_q, hp, fp) if gated else None,
        _pad2(wu_q, hp, fp),
        _pad2(x_scale, mp, 1),
        _pad2(wg_scale, 1, fp) if gated else None,
        _pad2(wu_scale, 1, fp),
        mlp_type=mlp_type, block_m=block_m, block_f=block_f, block_k=block_k,
        out_dtype=jnp.dtype(out_dtype), interpret=interpret)
    return out[:m, :f]


def int8_fused_mlp_hidden(x: jax.Array, w_gate, w_up, *,
                          mlp_type: str = "swiglu",
                          block_m: int = 128, block_f: int = 128,
                          block_k: int = 128, interpret: bool = True,
                          use_pallas: bool = True, tuned: bool = False,
                          hw_name: Optional[str] = None,
                          out_dtype=None) -> jax.Array:
    """int8-weight fused-MLP hidden.  x: (..., h) float; w_gate/w_up: (h, f)
    float or prequantized `QuantizedTensor` (w_gate=None for ungated
    mlp_types).  Cache op ``int8_fused_mlp_<mlp_type>``, shape (m, h, f),
    mixed dtype key."""
    lead = x.shape[:-1]
    if x.ndim != 2:
        x = x.reshape(-1, x.shape[-1])
    gated = is_gated(mlp_type)
    wuq = _as_quantized(w_up, "w_up")
    wu_q, wu_scale = wuq.q, wuq.scale.reshape(1, -1)
    if gated:
        wgq = _as_quantized(w_gate, "w_gate")
        wg_q, wg_scale = wgq.q, wgq.scale.reshape(1, -1)
    else:
        wg_q = wg_scale = None
    out_dtype = jnp.dtype(out_dtype or x.dtype).name
    op = int8_fused_mlp_op_name(mlp_type)
    tuned_hit = None
    if tuned and use_pallas:
        m, h = x.shape
        _, f = wu_q.shape
        cfg = _tuning_lookup(op, (m, h, f),
                             mixed_dtype(jnp.dtype(x.dtype).name, "int8"),
                             hw_name or get_hardware().name)
        tuned_hit = cfg is not None
        if cfg is not None:
            block_m = cfg.blocks["block_m"]
            block_f = cfg.blocks["block_f"]
            block_k = cfg.blocks["block_k"]
    if obs.enabled():
        obs.record_dispatch(
            op, impl="pallas" if use_pallas else "jnp",
            shape=(x.shape[0], x.shape[1], wu_q.shape[-1]),
            blocks={"block_m": block_m, "block_f": block_f,
                    "block_k": block_k} if use_pallas else None,
            tuned_hit=tuned_hit)
    out = _int8_fused_mlp_jit(x, wg_q, wg_scale, wu_q, wu_scale,
                              mlp_type=mlp_type, block_m=block_m,
                              block_f=block_f, block_k=block_k,
                              interpret=interpret, use_pallas=use_pallas,
                              out_dtype=out_dtype)
    return out if len(lead) == 1 else out.reshape(*lead, wu_q.shape[-1])
