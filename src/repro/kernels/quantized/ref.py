"""jnp reference implementations for the low-precision kernels.

Each oracle consumes the SAME quantized operands as its Pallas counterpart
(quantization happens once, in ops.py, outside both paths), so parity tests
isolate the kernel arithmetic from the quantization rounding itself.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...quant import fp8_round_trip
from ..fused_mlp.ref import ACTS, is_gated


def int8_matmul_ref(a_q, a_scale, b_q, b_scale, out_dtype=None):
    """De-scaled int8 GEMM oracle: widen to f32, contract, apply the
    per-row activation scale and per-output-channel weight scale.

    a_q: (m, k) int8, a_scale: (m, 1) f32;
    b_q: (k, n) int8, b_scale: (1, n) f32.
    """
    out_dtype = out_dtype or jnp.float32
    acc = jnp.dot(a_q.astype(jnp.float32), b_q.astype(jnp.float32))
    return (acc * a_scale * b_scale).astype(out_dtype)


def fp8_matmul_ref(a, b, fp8_dtype: str = "float8_e4m3fn", out_dtype=None):
    """Emulated-fp8 GEMM oracle: round both operands through fp8 storage,
    then contract in f32 (the bf16-MXU-path stand-in)."""
    out_dtype = out_dtype or a.dtype
    a8 = fp8_round_trip(a.astype(jnp.float32), fp8_dtype)
    b8 = fp8_round_trip(b.astype(jnp.float32), fp8_dtype)
    return jnp.dot(a8, b8).astype(out_dtype)


def int8_fused_mlp_ref(x_q, x_scale, wg_q, wg_scale, wu_q, wu_scale, *,
                       mlp_type: str = "swiglu", out_dtype=None):
    """Oracle for the int8-weight fused-MLP hidden: de-scaled gate/up GEMMs
    plus the elementwise activation combine, all in f32.

    x_q: (m, h) int8, x_scale: (m, 1); w*_q: (h, f) int8, w*_scale: (1, f).
    """
    out_dtype = out_dtype or jnp.float32
    act, _ = ACTS[mlp_type]
    xf = x_q.astype(jnp.float32)
    up = jnp.dot(xf, wu_q.astype(jnp.float32)) * x_scale * wu_scale
    if is_gated(mlp_type):
        gate = jnp.dot(xf, wg_q.astype(jnp.float32)) * x_scale * wg_scale
        return (act(gate) * up).astype(out_dtype)
    return act(up).astype(out_dtype)
