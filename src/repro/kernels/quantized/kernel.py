"""int8 Pallas GEMM kernels: i32 accumulate on the MXU's integer path,
f32 de-scale in the epilogue.

Same grid discipline as kernels/matmul — (m_blocks, n_blocks, k_steps) with
k innermost and a VMEM scratch carrying partial sums across the sequential
k steps — but the accumulator is int32 (int8 x int8 products are exact in
i32 for any k the VMEM model admits) and the scales enter only at the last
k step:

    o[i, j] = (acc_i32[i, j] * a_scale[i] * b_scale[j]).astype(out)

Scale operands ride in as (block_m, 1) / (1, block_n) BlockSpecs indexed by
the same i/j as their payload, so the epilogue multiply is a broadcast over
the output tile — no extra HBM pass.

The int8 native tile is (32, 128) — 32 sublanes because four int8 rows pack
per 4-byte register lane row — so the candidate lattice
(tuning.candidates.int8_matmul_candidates) quantizes block_m/block_k to 32s
where the bf16 lattice uses 16s.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fused_mlp.ref import ACTS, is_gated


def _i32_vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.int32)


def _int8_matmul_kernel(a_ref, b_ref, as_ref, bs_ref, o_ref, acc_ref, *,
                        k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        deq = acc_ref[...].astype(jnp.float32) * as_ref[...] * bs_ref[...]
        o_ref[...] = deq.astype(o_ref.dtype)


def int8_matmul_pallas(a_q: jax.Array, b_q: jax.Array,
                       a_scale: jax.Array, b_scale: jax.Array, *,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 128, out_dtype=jnp.float32,
                       interpret: bool = False) -> jax.Array:
    """C = dequant(A_q @ B_q).  a_q: (m, k) int8, a_scale: (m, 1) f32;
    b_q: (k, n) int8, b_scale: (1, n) f32.  Requires block-divisible shapes
    (ops.int8_matmul pads misaligned problems and slices the result)."""
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    assert a_scale.shape == (m, 1) and b_scale.shape == (1, n), (
        a_scale.shape, b_scale.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        "int8_matmul_pallas requires padded shapes; use ops.int8_matmul")
    k_steps = k // block_k
    grid = (m // block_m, n // block_n, k_steps)
    return pl.pallas_call(
        functools.partial(_int8_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_i32_vmem((block_m, block_n))],
        interpret=interpret,
    )(a_q, b_q, a_scale, b_scale)


def _int8_gated_kernel(x_ref, wg_ref, wu_ref, xs_ref, gs_ref, us_ref, o_ref,
                       acc_g, acc_u, *, k_steps: int, mlp_type: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[...]
    acc_g[...] += jnp.dot(x, wg_ref[...], preferred_element_type=jnp.int32)
    acc_u[...] += jnp.dot(x, wu_ref[...], preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        act, _ = ACTS[mlp_type]
        xs = xs_ref[...]
        gate = acc_g[...].astype(jnp.float32) * xs * gs_ref[...]
        up = acc_u[...].astype(jnp.float32) * xs * us_ref[...]
        o_ref[...] = (act(gate) * up).astype(o_ref.dtype)


def _int8_plain_kernel(x_ref, wu_ref, xs_ref, us_ref, o_ref, acc_u, *,
                       k_steps: int, mlp_type: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_u[...] = jnp.zeros_like(acc_u)

    acc_u[...] += jnp.dot(x_ref[...], wu_ref[...],
                          preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        act, _ = ACTS[mlp_type]
        up = acc_u[...].astype(jnp.float32) * xs_ref[...] * us_ref[...]
        o_ref[...] = act(up).astype(o_ref.dtype)


def int8_fused_mlp_pallas(x_q: jax.Array, wg_q, wu_q: jax.Array,
                          x_scale: jax.Array, wg_scale, wu_scale: jax.Array, *,
                          mlp_type: str = "swiglu", block_m: int = 128,
                          block_f: int = 128, block_k: int = 128,
                          out_dtype=jnp.float32,
                          interpret: bool = False) -> jax.Array:
    """int8-weight fused-MLP hidden: de-scaled gate/up GEMMs + activation
    combine in one pass.  x_q: (m, h) int8, x_scale: (m, 1) f32;
    w*_q: (h, f) int8, w*_scale: (1, f) f32.  Two i32 accumulators carry the
    pair; scales and the nonlinearity enter only at the final k step."""
    m, h = x_q.shape
    h2, f = wu_q.shape
    assert h == h2, (x_q.shape, wu_q.shape)
    assert x_scale.shape == (m, 1) and wu_scale.shape == (1, f), (
        x_scale.shape, wu_scale.shape)
    assert m % block_m == 0 and f % block_f == 0 and h % block_k == 0, (
        "int8_fused_mlp_pallas requires padded shapes; "
        "use ops.int8_fused_mlp_hidden")
    gated = is_gated(mlp_type)
    if gated:
        assert wg_q is not None and wg_q.shape == wu_q.shape
        assert wg_scale is not None and wg_scale.shape == wu_scale.shape
    k_steps = h // block_k
    grid = (m // block_m, f // block_f, k_steps)
    xspec = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
    wspec = pl.BlockSpec((block_k, block_f), lambda i, j, kk: (kk, j))
    xs_spec = pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0))
    ws_spec = pl.BlockSpec((1, block_f), lambda i, j, kk: (0, j))
    ospec = pl.BlockSpec((block_m, block_f), lambda i, j, kk: (i, j))
    acc = _i32_vmem((block_m, block_f))
    if gated:
        return pl.pallas_call(
            functools.partial(_int8_gated_kernel, k_steps=k_steps,
                              mlp_type=mlp_type),
            grid=grid,
            in_specs=[xspec, wspec, wspec, xs_spec, ws_spec, ws_spec],
            out_specs=ospec,
            out_shape=jax.ShapeDtypeStruct((m, f), out_dtype),
            scratch_shapes=[acc, acc],
            interpret=interpret,
        )(x_q, wg_q, wu_q, x_scale, wg_scale, wu_scale)
    return pl.pallas_call(
        functools.partial(_int8_plain_kernel, k_steps=k_steps,
                          mlp_type=mlp_type),
        grid=grid,
        in_specs=[xspec, wspec, xs_spec, ws_spec],
        out_specs=ospec,
        out_shape=jax.ShapeDtypeStruct((m, f), out_dtype),
        scratch_shapes=[acc],
        interpret=interpret,
    )(x_q, wu_q, x_scale, wu_scale)
