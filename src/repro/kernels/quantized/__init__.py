"""Low-precision Pallas GEMM kernels: int8 (per-channel scales, i32
accumulate, f32 de-scale epilogue), emulated fp8, and the int8-weight fused
MLP.  Public entry points live in `ops`; `ref` holds the jnp oracles."""
from .ops import fp8_matmul, int8_fused_mlp_hidden, int8_fused_mlp_op_name, int8_matmul  # noqa: F401
from .ref import fp8_matmul_ref, int8_fused_mlp_ref, int8_matmul_ref  # noqa: F401
