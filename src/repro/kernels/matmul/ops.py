"""jit'd public wrapper for the tile-aligned GEMM kernel.

`matmul` pads misaligned problems up to the block grid (tile quantization
made explicit — the zero-padding FLOPs are exactly the waste the paper's
utilization term predicts) and reports alignment via `alignment_report`.

With `tuned=True` the wrapper consults the autotuning cache
(`repro.tuning.cache`) for a measured-best block shape for this exact
(m, k, n, dtype, hardware) before falling back to the 128^3 default —
see `repro.tuning.search.autotune_matmul` for how entries are produced.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ... import obs
from ...core.hardware import get_hardware
from ...core.quantization import round_up, tile_utilization
from ...tuning.cache import lookup as _tuning_lookup
from .kernel import matmul_pallas
from .ref import matmul_ref


def _pad2(x, m, n):
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "use_pallas"))
def _matmul_jit(a: jax.Array, b: jax.Array, *,
                block_m: int, block_n: int, block_k: int,
                interpret: bool, use_pallas: bool) -> jax.Array:
    if not use_pallas:
        return matmul_ref(a, b)
    m, k = a.shape
    _, n = b.shape
    mp, kp, np_ = round_up(m, block_m), round_up(k, block_k), round_up(n, block_n)
    out = matmul_pallas(_pad2(a, mp, kp), _pad2(b, kp, np_),
                        block_m=block_m, block_n=block_n, block_k=block_k,
                        interpret=interpret)
    return out[:m, :n]


def matmul(a: jax.Array, b: jax.Array, *,
           block_m: int = 128, block_n: int = 128, block_k: int = 128,
           interpret: bool = True, use_pallas: bool = True,
           tuned: bool = False, hw_name: Optional[str] = None) -> jax.Array:
    """C = A @ B.  A: (..., k) — leading dims are flattened into one m axis
    and restored on the output, so a (b, s, h) activation keys the tuning
    cache as (b*s, h, n), the exact shape `autotune_matmul` writes (a
    >2-D A used to miss the cache silently).  use_pallas=False falls back
    to the jnp oracle (the CPU-container default for model code; kernels
    are TPU-targeted and validated in interpret mode).

    tuned=True overrides block_* with the autotuning cache's measured-best
    config for this (m, k, n, dtype, hw) when one exists (cache misses keep
    the defaults).  The lookup runs at trace time, outside the jit.
    """
    lead = a.shape[:-1]
    if a.ndim != 2:
        a = a.reshape(-1, a.shape[-1])
    tuned_hit = None
    if tuned and use_pallas:
        m, k = a.shape
        _, n = b.shape
        cfg = _tuning_lookup("matmul", (m, k, n), jnp.dtype(a.dtype).name,
                             hw_name or get_hardware().name)
        tuned_hit = cfg is not None
        if cfg is not None:
            block_m = cfg.blocks["block_m"]
            block_n = cfg.blocks["block_n"]
            block_k = cfg.blocks["block_k"]
    if obs.enabled():
        obs.record_dispatch(
            "matmul", impl="pallas" if use_pallas else "jnp",
            shape=(a.shape[0], a.shape[1], b.shape[-1]),
            blocks={"block_m": block_m, "block_n": block_n,
                    "block_k": block_k} if use_pallas else None,
            tuned_hit=tuned_hit)
    out = _matmul_jit(a, b, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret,
                      use_pallas=use_pallas)
    return out if len(lead) == 1 else out.reshape(*lead, b.shape[-1])


def alignment_report(m: int, k: int, n: int, dtype=jnp.bfloat16,
                     hw_name: Optional[str] = None) -> dict:
    """Tile-alignment report for an (m, k, n) GEMM.  `dtype` (an array dtype,
    not a byte count) and `hw_name` default to the benchmark dtype and
    `get_hardware()`'s default chip; callers on other hardware thread their
    own through."""
    from ...core.gemm_model import GEMM, recommend_precision
    hw = get_hardware(hw_name) if hw_name else get_hardware()
    dtype_bytes = jnp.dtype(dtype).itemsize
    util = tile_utilization(m, n, k, hw, dtype_bytes)
    gemm = GEMM("alignment_report", m, k, n, dtype_bytes=dtype_bytes)
    rec_dtype, rec_speedup = recommend_precision(
        gemm, hw, dtypes=(jnp.dtype(dtype).name, "int8"))
    return {
        "hw_name": hw.name,
        "dtype": jnp.dtype(dtype).name,
        "mxu_utilization": util,
        "padded_shape": (round_up(m, 128), round_up(k, 128), round_up(n, 128)),
        "aligned": util > 0.999,
        "vmem_per_tile_bytes": (128 * 128 * dtype_bytes * 2 + 128 * 128 * 4),
        # dtype-aware pricing: int8 weights win exactly where the GEMM is
        # bandwidth-bound (see core.gemm_model.recommend_precision)
        "int8_utilization": tile_utilization(m, n, k, hw, 1),
        "recommended_dtype": rec_dtype,
        "recommended_speedup": rec_speedup,
    }
