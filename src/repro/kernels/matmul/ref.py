"""Pure-jnp oracle for the tile-aligned GEMM kernel."""
import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(out_dtype)
