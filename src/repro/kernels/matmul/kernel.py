"""Tile-aligned GEMM Pallas kernel — the paper's central object on TPU.

Grid (m_blocks, n_blocks, k_blocks), k innermost; a VMEM f32 scratch
accumulates across the k dimension (TPU grids execute sequentially per core,
so the scratch carries between k steps of the same (i, j) tile).

BlockSpec shapes ARE the co-design knobs: (block_m, block_k, block_n) must be
multiples of the (sublane, lane) = (16, 128) bf16 tile for full MXU
utilization — exactly the paper's tensor-core alignment rule with TPU
constants.  The `ops.py` wrapper reports the padding waste for misaligned
problem shapes via core.quantization.tile_utilization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *,
                  block_m: int = 128, block_n: int = 128, block_k: int = 128,
                  out_dtype=None, interpret: bool = False) -> jax.Array:
    """C = A @ B with explicit VMEM tiling.  Requires block-divisible shapes
    (ops.matmul pads misaligned problems and slices the result — making the
    tile-quantization cost explicit rather than implicit)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        "matmul_pallas requires padded shapes; use ops.matmul")
    out_dtype = out_dtype or a.dtype
    k_steps = k // block_k
    grid = (m // block_m, n // block_n, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_vmem((block_m, block_n))],
        interpret=interpret,
    )(a, b)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
