"""Deterministic synthetic LM data pipeline.

Design goals for 1000+-node runs:
  * STATELESS: batch(step) is a pure function of (seed, step, shape) — any
    host can (re)produce any shard at any time.  This is the straggler /
    elastic-restart story: a replacement host needs no data-state handoff,
    it just computes its shard of batch(step).
  * host-sharded: each process materializes only its slice of the global
    batch (`process_slice`), matching jax.make_array_from_process_local_data.

The token stream is a reproducible xorshift stream with a Zipf-ish marginal
(so losses are non-degenerate), plus deterministic VLM patch / audio-frame
stubs where the architecture needs them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab_size: int = 50304


def _keys(seed: int, step: int, rows: int, row0: int = 0) -> np.ndarray:
    """Per-row deterministic RNG keys (uint64 wraparound is intended).
    row0 offsets the GLOBAL row index so host shards tile the global batch."""
    with np.errstate(over="ignore"):
        return ((np.uint64(row0) + np.arange(rows, dtype=np.uint64))
                * np.uint64(0xD1B54A32D192ED03)
                + np.uint64(step) * np.uint64(0x9E3779B97F4A7C15)
                + np.uint64(seed) * np.uint64(0xBF58476D1CE4E5B9))


def _xorshift(x: np.ndarray) -> np.ndarray:
    x = x ^ (x >> np.uint64(12))
    x = x ^ (x << np.uint64(25))
    x = x ^ (x >> np.uint64(27))
    return x * np.uint64(0x2545F4914F6CDD1D)


def synthetic_tokens(seed: int, step: int, batch: int, seq: int,
                     vocab: int, row0: int = 0) -> np.ndarray:
    """(batch, seq) int32 tokens, Zipf-flavored, deterministic in
    (seed, step, global row index)."""
    state = _keys(seed, step, batch, row0)[:, None] + np.arange(seq, dtype=np.uint64)[None, :]
    r = _xorshift(state)
    u = (r >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    # Zipf-ish marginal via inverse power transform
    toks = np.floor((vocab - 1) * np.power(u, 3.0)).astype(np.int32)
    return toks


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
               seed: int = 1234, process_index: int = 0,
               process_count: int = 1) -> Dict[str, np.ndarray]:
    """The (host-local slice of the) training batch for `step`."""
    gb = shape.global_batch
    assert gb % process_count == 0, "global batch must divide hosts"
    local = gb // process_count
    row0 = process_index * local
    toks = synthetic_tokens(seed, step, local, shape.seq_len, cfg.vocab_size,
                            row0=row0)
    batch: Dict[str, np.ndarray] = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm" and cfg.num_patches:
        # deterministic patch-embedding stub
        r = _xorshift(_keys(seed + 7, step, local, row0))[:, None, None]
        grid = (np.arange(cfg.num_patches)[None, :, None]
                + np.arange(cfg.d_model)[None, None, :])
        batch["patch_embeds"] = (np.sin(0.01 * (grid + (r % 1000).astype(np.int64)))
                                 ).astype(np.float32)
        # tokens shrink so total stream length stays seq_len
        batch["tokens"] = toks[:, : shape.seq_len - cfg.num_patches]
        batch["labels"] = batch["tokens"]
    if cfg.is_encoder_decoder:
        r = _xorshift(_keys(seed + 13, step, local, row0))[:, None, None]
        grid = (np.arange(cfg.encoder_seq)[None, :, None]
                + np.arange(cfg.d_model)[None, None, :])
        batch["encoder_frames"] = (np.sin(0.02 * (grid + (r % 997).astype(np.int64)))
                                   ).astype(np.float32)
    return batch


def batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for input_specs()/dry-run."""
    gb, s = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((gb, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((gb, s), jnp.int32)}
    if cfg.family == "vlm" and cfg.num_patches:
        out["tokens"] = jax.ShapeDtypeStruct((gb, s - cfg.num_patches), jnp.int32)
        out["labels"] = out["tokens"]
        out["patch_embeds"] = jax.ShapeDtypeStruct((gb, cfg.num_patches, cfg.d_model),
                                                    jnp.bfloat16)
    if cfg.is_encoder_decoder:
        out["encoder_frames"] = jax.ShapeDtypeStruct((gb, cfg.encoder_seq, cfg.d_model),
                                                      jnp.bfloat16)
    return out
