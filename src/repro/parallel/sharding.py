"""Sharding rules: map every parameter / batch / cache leaf to a PartitionSpec.

Mesh axes:
  pod    — outer axis across pods (pure DP by default; PP optional)
  data   — within-pod data parallelism + FSDP (ZeRO-3 parameter sharding)
  model  — tensor parallelism (Megatron column/row pairs), expert parallelism,
           vocab sharding, and sequence sharding of decode KV caches

Rules implement the paper's parallel shape constraints: h/t, d_ff/t, a/t,
v/t, experts/t divisibility (checked by core.advisor.check_alignment before
lowering).  Parameters carry one dim sharded on `model` (TP) and one on
`data` (FSDP); XLA SPMD inserts the per-layer all-gathers inside the scan.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import MeshConfig, ModelConfig


# --- activation partitioning context -------------------------------------------------
# Models are mesh-agnostic; the launcher installs the axis names here and
# model code anchors activations via `constrain` (no-op when unset, e.g. in
# single-device CPU tests).  One anchor at the embedding output is what stops
# the SPMD partitioner from replicating the whole forward pass dp-fold.

_ACT_CTX: dict = {"dp": None, "tp": None, "mesh": None}


def set_activation_context(dp_axes, tp_axis="model", mesh=None):
    _ACT_CTX["dp"] = tuple(dp_axes) if dp_axes else None
    _ACT_CTX["tp"] = tp_axis
    _ACT_CTX["mesh"] = mesh


def clear_activation_context():
    _ACT_CTX["dp"] = None
    _ACT_CTX["tp"] = None
    _ACT_CTX["mesh"] = None


def activation_context():
    return dict(_ACT_CTX)


def constrain(x, kind: str):
    """Anchor an activation layout (no-op outside a mesh context).

    kinds:
      btd    (batch, seq, dim)           — residual stream
      btv    (batch, seq, vocab)         — logits, vocab TP-sharded
      bd     (batch, dim)
      bskh   (batch, seq, kv, hd)        — decode K/V: SEQUENCE over model
      bkgqs  (batch, kv, g, q, seq)      — decode scores: seq over model
                                           (distributed flash-decode softmax)
      bsr    (batch, seq, rank)          — MLA latent cache: seq over model
    """
    dp, tp = _ACT_CTX["dp"], _ACT_CTX["tp"]
    if dp is None:
        return x
    if tp in dp:  # pure-DP mode: the model axis is data-parallel
        tp = None
    spec = {"btd": P(dp, None, None),
            "btd_sp": P(dp, tp, None),  # sequence parallelism
            "btv": P(dp, None, tp),
            "bd": P(dp, None),
            "td": P(dp, None),          # flat token-major (MoE dispatch)
            "eh": P(tp, None),          # flat (expert*capacity, h) buffers
            "bskh": P(dp, tp, None, None),
            "bkgqs": P(dp, None, None, None, tp),
            "bsr": P(dp, tp, None)}[kind]
    # skip when the batch dim doesn't divide the dp axes (long_500k b=1)
    import numpy as _np
    mesh_size = 1
    try:
        from jax.sharding import get_abstract_mesh
        am = get_abstract_mesh()
        if am is not None and am.shape:
            mesh_size = int(_np.prod([am.shape.get(a, 1) for a in dp]))
    except Exception:
        pass
    if mesh_size > 1 and x.shape[0] % mesh_size:
        spec = P(*((None,) + tuple(spec)[1:]))
    return jax.lax.with_sharding_constraint(x, spec)


def make_mesh(mesh_cfg: MeshConfig) -> Mesh:
    if mesh_cfg.pod > 1:
        return jax.make_mesh((mesh_cfg.pod, mesh_cfg.data, mesh_cfg.model),
                             ("pod", "data", "model"))
    return jax.make_mesh((mesh_cfg.data, mesh_cfg.model), ("data", "model"))


def _axes(mesh: Mesh):
    return set(mesh.axis_names)


# Base (unstacked) PartitionSpecs by leaf name.  Leading stack dims (scan
# segments, vmapped sub-layers) are detected by ndim and padded with None.
# fsdp axis = "data"; tp/ep axis = "model".
def _base_spec(name: str, path: str, ndim_base: int, fsdp: str | None):
    col = P(fsdp, "model")       # (in, out) column-parallel
    row = P("model", fsdp)       # (in, out) row-parallel
    if name == "embed":
        return P("model", fsdp), 2          # (vocab, h)
    if name == "lm_head":
        return P(fsdp, "model"), 2          # (h, vocab)
    if name == "pos_embed":
        return P(None, fsdp), 2
    if name in ("wq", "wk", "wv", "wq_down", "wq_up", "wkv_down", "wk_up",
                "wv_up"):
        return col, 2
    if name in ("wo", "out_proj"):
        return row, 2
    if name in ("w_up", "w_gate"):
        if ndim_base == 3:                   # MoE expert stack (E, h, f)
            return P("model", fsdp, None), 3
        return col, 2
    if name == "w_down":
        if ndim_base == 3:                   # (E, f, h)
            return P("model", None, fsdp), 3
        return row, 2
    if name == "router":
        return P(fsdp, None), 2
    if name == "proj":                        # MTP projection (2h, h)
        return P(fsdp, None), 2
    if name in ("in_z", "in_x"):
        return col, 2
    if name in ("in_B", "in_C", "in_dt"):     # small n-dim: shard only fan-in
        return P(fsdp, None), 2
    if name == "conv_x":
        return P(None, "model"), 2
    if name in ("conv_B", "conv_C"):
        return P(None, None), 2
    if name == "conv_bx":
        return P("model"), 1
    if name in ("conv_bB", "conv_bC"):
        return P(None), 1
    if name in ("A_log", "D", "dt_bias"):
        return P("model"), 1                 # nh sharded with d_inner
    if name in ("bq", "bk", "bv"):
        return P("model"), 1
    if name in ("scale", "bias"):
        # the SSD gated-norm scale lives on the TP-sharded d_inner dim
        if ".ssm." in path or "/ssm/" in path:
            return P("model"), 1
        return P(None), 1
    return None, None


def _path_str(path) -> str:
    return "." + ".".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path) + "."


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh,
                fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching `params` (works on ShapeDtypeStructs)."""
    fsdp_ax = "data" if (fsdp and "data" in _axes(mesh)) else None

    def spec(path, leaf):
        pstr = _path_str(path)
        name = pstr.rstrip(".").rsplit(".", 1)[-1]
        # MoE routed-expert stacks carry a leading expert dim (E, h, f)
        is_expert = (name in ("w_up", "w_gate", "w_down")
                     and ".moe." in pstr and ".shared." not in pstr)
        base, nd = _base_spec(name, pstr, 3 if is_expert else 2, fsdp_ax)
        if base is None:
            return P()  # replicated fallback (norm scales etc.)
        lead = leaf.ndim - nd
        if lead < 0:
            return P()
        return P(*([None] * lead), *base)

    return jax.tree_util.tree_map_with_path(spec, params)


def batch_specs(cfg: ModelConfig, mesh: Mesh) -> Any:
    """Input batch: global batch dim sharded over (pod, data)."""
    dp = ("pod", "data") if "pod" in _axes(mesh) else ("data",)
    return {
        "tokens": P(dp, None),
        "labels": P(dp, None),
        "loss_mask": P(dp, None),
        "patch_embeds": P(dp, None, None),
        "encoder_frames": P(dp, None, None),
    }


def cache_specs(cfg: ModelConfig, mesh: Mesh) -> Any:
    """Decode caches: batch over (pod,data); SEQUENCE over model.

    Sequence-sharding the KV cache turns decode attention into a
    flash-decode-style distributed softmax: XLA keeps the s dim sharded and
    all-reduces only the (b, a, hd)-sized stats — tiny collectives instead of
    gathering a 32k cache (DESIGN.md §5).  SSM states shard their head dim on
    `model` (d_inner is TP-sharded).
    """
    dp = ("pod", "data") if "pod" in _axes(mesh) else ("data",)

    def one(kind):
        kv = {"k": P(None, dp, "model", None, None),
              "v": P(None, dp, "model", None, None)}
        if cfg.attn_type == "mla":
            kv = {"latent": P(None, dp, "model", None)}
        ssm = {"state": P(None, dp, "model", None, None),
               "conv_x": P(None, dp, None, "model"),
               "conv_B": P(None, dp, None, None),
               "conv_C": P(None, dp, None, None)}
        if kind in ("dense", "moe"):
            return kv
        if kind == "pair":
            return {"moe_blk": kv, "dense_blk": kv}
        if kind == "ssm":
            return ssm
        if kind == "hybrid_super":
            ssm2 = jax.tree.map(lambda s: P(*s[:1], None, *s[1:]), ssm,
                                is_leaf=lambda x: isinstance(x, P))
            return {"ssm": ssm2, "shared_attn": kv}
        raise ValueError(kind)

    from ..models.blocks import stack_plan
    return [one(kind) for kind, _ in stack_plan(cfg)]


def strip_axis(spec_tree: Any, axis: str = "model") -> Any:
    """Remove one mesh axis from every spec (e.g. disable TP for models whose
    per-shard widths fall under the 128-lane tile — whisper-small at tp=16
    has h/t = 48; the advisor's hidden_shard_alignment rule)."""
    def fix(p):
        return P(*[None if e == axis else
                   (tuple(a for a in e if a != axis) if isinstance(e, tuple) else e)
                   for e in p])
    return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def validate_divisibility(cfg: ModelConfig, mesh_cfg: MeshConfig,
                          global_batch: int) -> list[str]:
    """Hard constraints that must hold before lowering (paper §VI-B rules)."""
    errs = []
    t, d = mesh_cfg.model, mesh_cfg.dp
    if global_batch % d:
        errs.append(f"global_batch {global_batch} % dp {d} != 0")
    if cfg.num_heads and cfg.num_heads % t:
        errs.append(f"num_heads {cfg.num_heads} % tp {t} != 0")
    if cfg.d_ff and cfg.d_ff % t:
        errs.append(f"d_ff {cfg.d_ff} % tp {t} != 0")
    if cfg.num_experts and cfg.num_experts % t:
        errs.append(f"experts {cfg.num_experts} % ep {t} != 0")
    if cfg.ssm_state and cfg.ssm_d_inner % t:
        errs.append(f"ssm_d_inner {cfg.ssm_d_inner} % tp {t} != 0")
    return errs
