"""GPipe-style pipeline parallelism over a mesh axis (the `pod` axis of the
production mesh), via shard_map + collective_permute.

Design (DESIGN.md §5): each pipeline stage holds L/num_stages layers
(the paper's §VI-B rule "L divisible by the number of pipeline stages" is
asserted).  Microbatches stream through stages; activations hop stages with
`jax.lax.ppermute`.  The schedule is the classic GPipe loop of
(num_micro + num_stages - 1) ticks, bubble fraction
(S-1)/(M+S-1); each device computes every tick on its resident stage,
masking ticks outside its active window — SPMD-friendly (no per-device
control flow).

This module is self-contained on purpose: the 40-cell dry-run uses the pod
axis as outer data parallelism (the default, best for the assigned shapes
where DP is cheap); `pipeline_apply` is the drop-in for bandwidth-poor
cross-pod links, exercised by tests/test_pipeline.py on 8 host devices and
by the `--pp` dryrun treatment.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn: Callable, stage_params: Any, x: jax.Array,
                   mesh: Mesh, axis: str = "pod"):
    """Run a layer stack split across `axis` as a GPipe pipeline.

    stage_fn(params_for_stage, microbatch) -> microbatch  (one stage's layers)
    stage_params: pytree whose leaves have leading dim == num_stages
                  (sharded over `axis`).
    x: (num_micro, micro_batch, ...) microbatched input (replicated over
       `axis`; each stage consumes/produces as the schedule dictates).

    Returns (num_micro, micro_batch, ...) outputs (gathered on all devices).
    """
    num_stages = mesh.shape[axis]
    num_micro = x.shape[0]

    def per_stage(params, xs):
        # params: (1, ...) this stage's slice; xs: full (num_micro, ...)
        params = jax.tree.map(lambda t: t[0], params)
        stage = jax.lax.axis_index(axis)
        ticks = num_micro + num_stages - 1

        state = jnp.zeros_like(xs[0])  # activation resident on this stage
        outputs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, num_micro - 1)
            injected = jnp.where(stage == 0, xs[mb_idx], state)
            # compute only when this stage holds a live microbatch:
            # stage s is active for t in [s, s + num_micro)
            live = (t >= stage) & (t < stage + num_micro)
            out = stage_fn(params, injected)
            out = jnp.where(live, out, state)
            # last stage retires microbatch (t - (S-1))
            retire_idx = jnp.clip(t - (num_stages - 1), 0, num_micro - 1)
            retire = (stage == num_stages - 1) & (t >= num_stages - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(retire, out, outputs[retire_idx]),
                retire_idx, 0)
            # hop activations forward one stage
            perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
            state = jax.lax.ppermute(out, axis, perm)
            return (state, outputs), None

        (state, outputs), _ = jax.lax.scan(tick, (state, outputs),
                                           jnp.arange(ticks))
        # gather retired outputs from the last stage to all stages
        outputs = jax.lax.psum(
            jnp.where(stage == num_stages - 1, outputs, 0.0), axis)
        return outputs

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    return shard_map(per_stage, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)(stage_params, x)


def split_layers_into_stages(stacked_params: Any, num_stages: int) -> Any:
    """(L, ...) stacked layer params -> (num_stages, L/num_stages, ...).

    Asserts the paper's §VI-B rule: L % num_stages == 0.
    """
    def reshape(t):
        L = t.shape[0]
        assert L % num_stages == 0, (
            f"L={L} not divisible by pipeline stages={num_stages} "
            "(paper §VI-B)")
        return t.reshape((num_stages, L // num_stages) + t.shape[1:])
    return jax.tree.map(reshape, stacked_params)
