"""Tile-aligned block-size candidate lattices (the autotuner's search space).

The paper's thesis is that tile geometry drives efficiency; this module turns
that into a *search space*: every candidate block shape is (a) a multiple of
the hardware's native (sublane, lane) register tile at the given dtype, and
(b) small enough that the kernel's VMEM working set fits the chip's on-chip
memory budget (`Hardware.sram_bytes`).  The autotuner (`tuning.search`) then
*measures* each candidate instead of trusting the analytic model — closing
the loop between the roofline prediction and the kernel that actually runs.
"""
from __future__ import annotations

from typing import List, Tuple

from ..core.hardware import Hardware, get_hardware
from ..core.quantization import round_up

# Don't let the lattice explode: per-dimension caps keep the sweep tractable
# while covering every block size the kernels plausibly benefit from.
MAX_BLOCK = 1024
# Double-buffering factor for streamed input blocks (Pallas pipelines the
# next block's DMA while computing on the current one).
DOUBLE_BUFFER = 2


def sublane_granule(hw: Hardware, dtype_bytes: int = 2) -> int:
    """Native second-to-minor tile granularity at `dtype_bytes`.

    TPU packs (32 / dtype_bytes) x 128 register tiles (f32: 8, bf16: 16,
    int8: 32) — the same scaling quantization.tile_utilization applies.
    """
    sub, _ = hw.tile_2byte
    if hw.name.startswith("tpu"):
        return max(1, sub * 2 // max(dtype_bytes, 1))
    return sub


def lane_granule(hw: Hardware) -> int:
    """Minor-most tile granularity (always the full lane width)."""
    return hw.tile_2byte[1]


def _steps(dim: int, granule: int, cap: int = MAX_BLOCK) -> List[int]:
    """Power-of-two multiples of `granule`, capped by the (padded) problem
    dim and `cap` — blocks larger than the problem only add padding."""
    hi = min(cap, round_up(max(dim, 1), granule))
    out = []
    b = granule
    while b <= hi:
        out.append(b)
        b *= 2
    if not out:
        out = [granule]
    return out


def bucket_steps(dim: int, granule: int, cap: int = MAX_BLOCK) -> List[int]:
    """Public lattice for the serving engine's bucket policy: power-of-two
    multiples of the hardware tile granule, up to (the padding of) `dim`.

    The engine snaps batch/prompt buckets to this lattice so every lowered
    program shape is tile-aligned and the jit program set is bounded —
    the same lattice the autotuner sweeps (`_steps`)."""
    return _steps(dim, granule, cap)


def matmul_vmem_bytes(block_m: int, block_n: int, block_k: int,
                      dtype_bytes: int = 2) -> int:
    """VMEM working set of kernels/matmul: double-buffered A and B input
    blocks, an f32 accumulator scratch, and the output block."""
    a_blk = block_m * block_k * dtype_bytes
    b_blk = block_k * block_n * dtype_bytes
    acc = block_m * block_n * 4
    out = block_m * block_n * dtype_bytes
    return DOUBLE_BUFFER * (a_blk + b_blk) + acc + out


def flash_vmem_bytes(block_q: int, block_kv: int, head_dim: int,
                     dtype_bytes: int = 2) -> int:
    """VMEM working set of kernels/flash_attention: q block + double-buffered
    k/v blocks + (m, l, acc) f32 scratch + the f32 score tile + output."""
    q_blk = block_q * head_dim * dtype_bytes
    kv_blk = 2 * block_kv * head_dim * dtype_bytes
    scratch = block_q * head_dim * 4 + 2 * block_q * 4
    scores = block_q * block_kv * 4
    out = block_q * head_dim * dtype_bytes
    return q_blk + DOUBLE_BUFFER * kv_blk + scratch + scores + out


def flash_bwd_vmem_bytes(block_q: int, block_kv: int, head_dim: int,
                         dtype_bytes: int = 2) -> int:
    """VMEM working set of the flash backward kernels (the dkv grid is the
    larger of the two): streamed q/do blocks + lse/di rows, resident k/v
    blocks, the f32 score and ds tiles, two f32 (block_kv, d) accumulators,
    and the dk/dv output blocks."""
    q_stream = DOUBLE_BUFFER * (2 * block_q * head_dim * dtype_bytes
                                + 2 * block_q * 4)
    kv_blk = 2 * block_kv * head_dim * dtype_bytes
    tiles = 2 * block_q * block_kv * 4
    acc = 2 * block_kv * head_dim * 4
    out = 2 * block_kv * head_dim * dtype_bytes
    return q_stream + kv_blk + tiles + acc + out


def flash_backward_candidates(seq_q: int, seq_kv: int, head_dim: int,
                              hw: Hardware | None = None,
                              dtype_bytes: int = 2,
                              max_candidates: int | None = None
                              ) -> List[Tuple[int, int]]:
    """All (block_q, block_kv) worth timing for the flash *backward* grids.

    Same tile-alignment lattice as `flash_candidates`, but under the
    backward VMEM model: the dkv kernel keeps two extra f32 accumulators and
    the ds tile resident, so the feasible region is strictly smaller than
    the forward's.  The 128x128 default is always included.
    """
    return _flash_lattice(seq_q, seq_kv, head_dim, flash_bwd_vmem_bytes,
                          hw, dtype_bytes, max_candidates)


def _gemm_lattice(m: int, n: int, k: int, vmem_bytes,
                  hw: Hardware | None, dtype_bytes: int,
                  max_candidates: int | None) -> List[Tuple[int, int, int]]:
    """Shared (block_m, block_n, block_k) lattice for the GEMM-shaped sweeps
    (matmul and the fused MLP hidden): block_m sublane-aligned, block_n and
    block_k lane-aligned, feasibility decided by the given VMEM model.
    Candidates are ordered largest-first (bigger blocks amortize more grid
    overhead and are usually the winners on real hardware) and the 128^3
    default is always included when it fits — it is the baseline the
    measured speedup is quoted against."""
    hw = hw or get_hardware()
    sub = sublane_granule(hw, dtype_bytes)
    lane = lane_granule(hw)
    # block_m starts at the MXU row count if the problem allows: sub-MXU
    # blocks only make sense for skinny problems.
    m_steps = [s for s in _steps(m, sub) if s >= min(128, round_up(m, sub))]
    m_steps = m_steps or _steps(m, sub)[-1:]
    n_steps = _steps(n, lane)
    k_steps = _steps(k, lane)
    cands = [
        (bm, bn, bk)
        for bm in m_steps
        for bn in n_steps
        for bk in k_steps
        if vmem_bytes(bm, bn, bk) <= hw.sram_bytes
    ]
    cands.sort(key=lambda c: -(c[0] * c[1] * c[2]))
    default = (128, 128, 128)
    if default not in cands and vmem_bytes(*default) <= hw.sram_bytes:
        cands.append(default)
    if max_candidates is not None and len(cands) > max_candidates:
        keep = cands[:max_candidates]
        if default in cands and default not in keep:
            keep[-1] = default
        cands = keep
    return cands


def matmul_candidates(m: int, k: int, n: int, hw: Hardware | None = None,
                      dtype_bytes: int = 2,
                      max_candidates: int | None = None
                      ) -> List[Tuple[int, int, int]]:
    """All (block_m, block_n, block_k) worth timing for an (m, k, n) GEMM.

    Every candidate is tile-aligned (block_m % sublane == 0, block_n and
    block_k % lane == 0) and fits the VMEM budget (`_gemm_lattice`).
    """
    return _gemm_lattice(
        m, n, k,
        lambda bm, bn, bk: matmul_vmem_bytes(bm, bn, bk, dtype_bytes),
        hw, dtype_bytes, max_candidates)


def fused_mlp_vmem_bytes(block_m: int, block_f: int, block_k: int,
                         dtype_bytes: int = 2, gated: bool = True) -> int:
    """VMEM working set of kernels/fused_mlp forward: double-buffered x and
    gate/up weight blocks, one f32 accumulator per GEMM of the pair, and the
    combined hidden output block.  The gated (swiglu) variant streams two
    weight blocks and keeps two accumulators resident — its feasible region
    is strictly smaller than a plain matmul's at equal blocks."""
    nw = 2 if gated else 1
    x_blk = block_m * block_k * dtype_bytes
    w_blk = nw * block_k * block_f * dtype_bytes
    acc = nw * block_m * block_f * 4
    out = block_m * block_f * dtype_bytes
    return DOUBLE_BUFFER * (x_blk + w_blk) + acc + out


def fused_mlp_candidates(m: int, h: int, f: int, hw: Hardware | None = None,
                         dtype_bytes: int = 2, gated: bool = True,
                         max_candidates: int | None = None
                         ) -> List[Tuple[int, int, int]]:
    """All (block_m, block_f, block_k) worth timing for an (m, h, f) fused
    MLP hidden problem (m tokens, h model width, f ffn width).

    Same tile-alignment lattice as `matmul_candidates` (block_m sublane-,
    block_f/block_k lane-aligned) under the fused-MLP VMEM model; the 128^3
    default is always included.  The §VII-B hook: an 8h/3 heuristic f pads
    up to the lattice and the waste shows up in every candidate's timing.
    """
    return _gemm_lattice(
        m, f, h,
        lambda bm, bn, bk: fused_mlp_vmem_bytes(bm, bn, bk, dtype_bytes, gated),
        hw, dtype_bytes, max_candidates)


def int8_matmul_vmem_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """VMEM working set of kernels/quantized int8 matmul: double-buffered
    int8 A and B blocks (1 byte/elem), the i32 accumulator scratch, the f32
    scale rows/cols, and the output block (f32 worst case).  Halving operand
    bytes roughly doubles the feasible block area vs the bf16 lattice —
    the dtype/shape coupling the paper's alignment rules predict."""
    a_blk = block_m * block_k * 1
    b_blk = block_k * block_n * 1
    scales = (block_m + block_n) * 4
    acc = block_m * block_n * 4
    out = block_m * block_n * 4
    return DOUBLE_BUFFER * (a_blk + b_blk + scales) + acc + out


def int8_matmul_candidates(m: int, k: int, n: int, hw: Hardware | None = None,
                           max_candidates: int | None = None
                           ) -> List[Tuple[int, int, int]]:
    """All (block_m, block_n, block_k) worth timing for an int8 (m, k, n)
    GEMM.  The lattice quantizes block_m to the *int8* sublane granule
    (32 on TPU — four int8 rows pack per register row) under the int8 VMEM
    model; the 128^3 default is always included."""
    return _gemm_lattice(
        m, n, k,
        lambda bm, bn, bk: int8_matmul_vmem_bytes(bm, bn, bk),
        hw, 1, max_candidates)


def fp8_matmul_vmem_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """VMEM working set of the emulated-fp8 matmul.  The GEMM itself runs
    the bf16-path kernel on widened operands, so the resident footprint is
    the 2-byte matmul model — fp8 only changes the HBM story."""
    return matmul_vmem_bytes(block_m, block_n, block_k, 2)


def fp8_matmul_candidates(m: int, k: int, n: int, hw: Hardware | None = None,
                          max_candidates: int | None = None
                          ) -> List[Tuple[int, int, int]]:
    """(block_m, block_n, block_k) lattice for the emulated-fp8 GEMM: bf16
    tile granules (the compute path is the bf16 MXU) bounded by
    `fp8_matmul_vmem_bytes`."""
    return _gemm_lattice(
        m, n, k,
        lambda bm, bn, bk: fp8_matmul_vmem_bytes(bm, bn, bk),
        hw, 2, max_candidates)


def int8_fused_mlp_vmem_bytes(block_m: int, block_f: int, block_k: int,
                              gated: bool = True) -> int:
    """VMEM working set of the int8-weight fused MLP: double-buffered int8 x
    and weight blocks plus f32 scale vectors, one i32 accumulator per GEMM
    of the pair, and the f32 hidden output block."""
    nw = 2 if gated else 1
    x_blk = block_m * block_k * 1
    w_blk = nw * block_k * block_f * 1
    scales = (block_m + nw * block_f) * 4
    acc = nw * block_m * block_f * 4
    out = block_m * block_f * 4
    return DOUBLE_BUFFER * (x_blk + w_blk + scales) + acc + out


def int8_fused_mlp_candidates(m: int, h: int, f: int,
                              hw: Hardware | None = None, gated: bool = True,
                              max_candidates: int | None = None
                              ) -> List[Tuple[int, int, int]]:
    """(block_m, block_f, block_k) lattice for the int8 fused-MLP hidden:
    int8 sublane granule on block_m, bounded by `int8_fused_mlp_vmem_bytes`
    (two i32 accumulators for the gated pair); 128^3 always included."""
    return _gemm_lattice(
        m, f, h,
        lambda bm, bn, bk: int8_fused_mlp_vmem_bytes(bm, bn, bk, gated),
        hw, 1, max_candidates)


def paged_decode_candidates(s_max: int, head_dim: int, group: int = 1,
                            hw: Hardware | None = None, dtype_bytes: int = 2,
                            max_candidates: int | None = None) -> List[int]:
    """block_kv values worth timing for the paged decode kernel.

    The score tile is (group, block_kv) with group = query heads per kv head,
    so only the lane-side block is searchable; candidates are lane-aligned
    and bounded by the streaming VMEM working set (the flash budget at
    block_q = group).  The 128 default is always included."""
    hw = hw or get_hardware()
    lane = lane_granule(hw)
    cands = [bkv for bkv in _steps(s_max, lane)
             if flash_vmem_bytes(group, bkv, head_dim, dtype_bytes)
             <= hw.sram_bytes]
    cands.sort(key=lambda c: -c)
    default = 128
    if default not in cands and flash_vmem_bytes(
            group, default, head_dim, dtype_bytes) <= hw.sram_bytes:
        cands.append(default)
    if max_candidates is not None and len(cands) > max_candidates:
        keep = cands[:max_candidates]
        if default in cands and default not in keep:
            keep[-1] = default
        cands = keep
    return cands


def paged_blocktable_candidates(seq_max: int, head_dim: int, group: int = 1,
                                hw: Hardware | None = None,
                                dtype_bytes: int = 2,
                                max_candidates: int | None = None
                                ) -> List[Tuple[int, int]]:
    """(block_size, block_kv) pairs worth timing for the block-table decode
    kernel — the paging granule and the kv tile are swept *jointly*.

    block_size candidates are power-of-two multiples of the sublane granule
    that divide seq_max (so a full sequence is a whole number of blocks and
    the pool capacity proof num_blocks = rows * seq_max/block_size holds);
    block_kv must divide block_size (a kv tile never straddles a physical
    block) and fit the streaming VMEM budget at block_q = group.  Larger
    pairs first: fewer grid steps usually win, but small blocks buy sharing
    granularity — that tension is exactly what the measurement decides.
    """
    hw = hw or get_hardware()
    sub = sublane_granule(hw, dtype_bytes)
    sizes = [bs for bs in _steps(seq_max, sub, cap=min(MAX_BLOCK, seq_max))
             if seq_max % bs == 0]
    cands = [
        (bs, bkv)
        for bs in sizes
        for bkv in _steps(bs, sub, cap=bs)
        if bs % bkv == 0
        and flash_vmem_bytes(group, bkv, head_dim, dtype_bytes)
        <= hw.sram_bytes
    ]
    cands.sort(key=lambda c: (-c[0], -c[1]))
    if max_candidates is not None and len(cands) > max_candidates:
        # keep coverage across block sizes rather than the head of the list
        # (which is all-largest-block): take the biggest bkv per size first
        by_size: List[Tuple[int, int]] = []
        seen = set()
        for bs, bkv in cands:
            if bs not in seen:
                by_size.append((bs, bkv))
                seen.add(bs)
        rest = [c for c in cands if c not in by_size]
        cands = (by_size + rest)[:max_candidates]
        cands.sort(key=lambda c: (-c[0], -c[1]))
    return cands


def _flash_lattice(seq_q: int, seq_kv: int, head_dim: int, vmem_bytes,
                   hw: Hardware | None, dtype_bytes: int,
                   max_candidates: int | None) -> List[Tuple[int, int]]:
    """Shared (block_q, block_kv) lattice for the flash forward/backward
    sweeps: block_q sublane-aligned, block_kv lane-aligned (the score tile
    feeds the MXU), feasibility decided by the given VMEM model.  The
    128x128 default is always included when it fits."""
    hw = hw or get_hardware()
    sub = sublane_granule(hw, dtype_bytes)
    lane = lane_granule(hw)
    q_steps = [s for s in _steps(seq_q, sub) if s >= min(128, round_up(seq_q, sub))]
    q_steps = q_steps or _steps(seq_q, sub)[-1:]
    kv_steps = _steps(seq_kv, lane)
    cands = [
        (bq, bkv)
        for bq in q_steps
        for bkv in kv_steps
        if vmem_bytes(bq, bkv, head_dim, dtype_bytes) <= hw.sram_bytes
    ]
    cands.sort(key=lambda c: -(c[0] * c[1]))
    default = (128, 128)
    if default not in cands and vmem_bytes(*default, head_dim, dtype_bytes) <= hw.sram_bytes:
        cands.append(default)
    if max_candidates is not None and len(cands) > max_candidates:
        keep = cands[:max_candidates]
        if default in cands and default not in keep:
            keep[-1] = default
        cands = keep
    return cands


def flash_candidates(seq_q: int, seq_kv: int, head_dim: int,
                     hw: Hardware | None = None, dtype_bytes: int = 2,
                     max_candidates: int | None = None
                     ) -> List[Tuple[int, int]]:
    """All (block_q, block_kv) worth timing for a flash-attention problem.

    block_q is sublane-aligned, block_kv lane-aligned (the (block_q,
    block_kv) score tile feeds the MXU), and the streaming working set must
    fit VMEM.  The 128x128 default is always included.
    """
    return _flash_lattice(seq_q, seq_kv, head_dim, flash_vmem_bytes,
                          hw, dtype_bytes, max_candidates)
