"""Persistent tuning cache: measured-best kernel configs, keyed by
(op, shape, dtype, hw_name).

The cache is a plain JSON file (documented in docs/codesign-guide.md) so it
can be committed, diffed, and shipped with a deployment:

    {
      "version": 1,
      "entries": {
        "matmul/512x512x512/bfloat16/tpu_v5e": {
          "op": "matmul", "shape": [512, 512, 512], "dtype": "bfloat16",
          "hw_name": "tpu_v5e", "blocks": {"block_m": 512, ...},
          "time_us": 812.4, "baseline_us": 1034.9, "candidates_tried": 12
        }, ...
      }
    }

`kernels/*/ops.py` consult the *default* cache (module-level, loaded lazily
from $REPRO_TUNING_CACHE or ./tuning_cache.json) when called with
`tuned=True`; `core.gemm_model.MeasuredProfile` reads the same entries to
calibrate the analytic cost model.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, Optional, Tuple

CACHE_VERSION = 1
ENV_VAR = "REPRO_TUNING_CACHE"
DEFAULT_FILENAME = "tuning_cache.json"


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One measured-best kernel configuration."""

    op: str                    # "matmul" | "flash_attention_causal" | ...
    shape: Tuple[int, ...]     # op-specific problem shape
    dtype: str                 # jnp dtype name, e.g. "bfloat16"
    hw_name: str               # core.hardware name the timing was taken on
    blocks: Dict[str, int]     # kernel kwargs, e.g. {"block_m": 512, ...}
    time_us: float             # best measured wall time per call
    baseline_us: float = 0.0   # measured time of the 128-default config
    candidates_tried: int = 0
    time_us_std: float = 0.0   # per-iteration std of the winner's timing

    @property
    def speedup_vs_default(self) -> float:
        if self.baseline_us <= 0 or self.time_us <= 0:
            return 1.0
        return self.baseline_us / self.time_us

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TunedConfig":
        return cls(op=d["op"], shape=tuple(d["shape"]), dtype=d["dtype"],
                   hw_name=d["hw_name"],
                   blocks={k: int(v) for k, v in d["blocks"].items()},
                   time_us=float(d["time_us"]),
                   baseline_us=float(d.get("baseline_us", 0.0)),
                   candidates_tried=int(d.get("candidates_tried", 0)),
                   time_us_std=float(d.get("time_us_std", 0.0)))


def cache_key(op: str, shape: Iterable[int], dtype: str, hw_name: str) -> str:
    return f"{op}/{'x'.join(str(int(s)) for s in shape)}/{dtype}/{hw_name}"


def mixed_dtype(act_dtype: str, weight_dtype: str) -> str:
    """Cache dtype key for mixed-precision ops: encodes *both* operand
    dtypes (e.g. ``bfloat16xint8``) so an int8-weight entry can never
    shadow — or be shadowed by — a uniform-dtype entry for the same shape.
    Uniform ops keep the plain single-dtype key unchanged."""
    if act_dtype == weight_dtype:
        return act_dtype
    return f"{act_dtype}x{weight_dtype}"


class TuningCache:
    """In-memory view of the JSON tuning cache."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: Dict[str, TunedConfig] = {}

    # -- persistence ---------------------------------------------------------
    @classmethod
    def load(cls, path: str) -> "TuningCache":
        """Load from `path`; a missing file yields an empty cache bound to
        that path (so the first save() creates it)."""
        cache = cls(path)
        if os.path.exists(path):
            with open(path) as f:
                raw = json.load(f)
            if raw.get("version", 1) != CACHE_VERSION:
                raise ValueError(
                    f"tuning cache {path}: version {raw.get('version')} "
                    f"unsupported (expected {CACHE_VERSION})")
            for key, d in raw.get("entries", {}).items():
                cache.entries[key] = TunedConfig.from_json(d)
        return cache

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("TuningCache.save: no path given or bound")
        payload = {
            "version": CACHE_VERSION,
            "entries": {k: v.to_json() for k, v in sorted(self.entries.items())},
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        self.path = path
        return path

    # -- access --------------------------------------------------------------
    def get(self, op: str, shape: Iterable[int], dtype: str,
            hw_name: str) -> Optional[TunedConfig]:
        return self.entries.get(cache_key(op, shape, dtype, hw_name))

    def put(self, cfg: TunedConfig) -> None:
        self.entries[cache_key(cfg.op, cfg.shape, cfg.dtype, cfg.hw_name)] = cfg

    def by_op(self, op: str, hw_name: Optional[str] = None) -> list:
        return [c for c in self.entries.values()
                if c.op == op and (hw_name is None or c.hw_name == hw_name)]

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self):
        return iter(self.entries.values())


# -- default cache (what `tuned=True` kernel calls consult) -------------------
_default_cache: Optional[TuningCache] = None


def default_cache_path() -> str:
    return os.environ.get(ENV_VAR, DEFAULT_FILENAME)


def get_default_cache(reload: bool = False) -> TuningCache:
    global _default_cache
    if _default_cache is None or reload:
        _default_cache = TuningCache.load(default_cache_path())
    return _default_cache


def set_default_cache(cache: "TuningCache | str | None") -> None:
    """Install `cache` (a TuningCache, a path to load, or None to reset) as
    the process-wide cache that `tuned=True` kernel calls consult."""
    global _default_cache
    if isinstance(cache, str):
        cache = TuningCache.load(cache)
    _default_cache = cache


def lookup(op: str, shape: Iterable[int], dtype: str,
           hw_name: str) -> Optional[TunedConfig]:
    """Default-cache lookup used by kernels/*/ops.py `tuned=True` paths."""
    return get_default_cache().get(op, shape, dtype, hw_name)
