"""Measured block-size search for the Pallas kernels.

For each problem shape the search sweeps the tile-aligned candidate lattice
(`tuning.candidates`), times every candidate with `tuning.measure.wall_us`,
and records the winner in a `TuningCache` — the measured counterpart of the
analytic model in `core.gemm_model`.  Kernel wrappers then consult the cache
via `tuned=True`, and `core.gemm_model.MeasuredProfile` uses the same
entries to calibrate advisor predictions.

On CPU the kernels run in Pallas interpret mode: absolute times are not
TPU times, but the *relative* ranking across block shapes still reflects
blocking/padding work, and the full loop (search -> cache -> tuned dispatch
-> calibrated advisor) is exercised end to end.  On a TPU host, pass
interpret=False and the cache holds real hardware timings.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import obs
from ..core.hardware import Hardware, get_hardware
from .cache import TunedConfig, TuningCache, get_default_cache, mixed_dtype
from .candidates import (flash_backward_candidates, flash_candidates,
                         fp8_matmul_candidates, fused_mlp_candidates,
                         int8_fused_mlp_candidates, int8_matmul_candidates,
                         matmul_candidates, paged_blocktable_candidates,
                         paged_decode_candidates)
from .measure import wall_us

DEFAULT_MATMUL_BLOCKS = (128, 128, 128)
DEFAULT_FLASH_BLOCKS = (128, 128)
DEFAULT_PAGED_BLOCK_KV = 128
DEFAULT_FUSED_MLP_BLOCKS = (128, 128, 128)


@dataclasses.dataclass(frozen=True)
class Trial:
    blocks: Tuple[int, ...]
    time_us: float
    time_us_std: float = 0.0


def _measure(op: str, fn, *args, iters: int, warmup: int,
             jit: bool = False) -> Tuple[float, float]:
    """Time one candidate with per-iteration samples: (mean_us, std_us).

    The std rides into `Trial`/`TunedConfig.time_us_std` so a winner whose
    margin over the runner-up is inside the noise band is visible as such;
    with obs enabled the raw samples also feed a per-op histogram."""
    mean, samples = wall_us(fn, *args, iters=iters, warmup=warmup, jit=jit,
                            return_samples=True)
    std = statistics.pstdev(samples) if len(samples) > 1 else 0.0
    if obs.enabled():
        obs.histogram(f"tuning.{op}.us").observe_many(samples)
    return mean, std


def flash_op_name(causal: bool) -> str:
    return "flash_attention_causal" if causal else "flash_attention_full"


def flash_bwd_op_name(causal: bool) -> str:
    return ("flash_attention_bwd_causal" if causal
            else "flash_attention_bwd_full")


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


def autotune_matmul(m: int, k: int, n: int, *, dtype=jnp.float32,
                    hw: Optional[Hardware] = None,
                    cache: Optional[TuningCache] = None,
                    interpret: bool = True, iters: int = 3, warmup: int = 1,
                    max_candidates: Optional[int] = None,
                    verbose: bool = False) -> TunedConfig:
    """Sweep (block_m, block_n, block_k) for an (m, k, n) matmul; persist
    and return the measured winner.  `cache=None` uses the default cache."""
    from ..kernels.matmul.ops import matmul

    hw = hw or get_hardware()
    cache = cache if cache is not None else get_default_cache()
    dtype_bytes = jnp.dtype(dtype).itemsize
    cands = matmul_candidates(m, k, n, hw, dtype_bytes,
                              max_candidates=max_candidates)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n)).astype(dtype)

    trials: List[Trial] = []
    baseline_us = 0.0
    for bm, bn, bk in cands:
        t, std = _measure(
            "matmul",
            lambda a, b, bm=bm, bn=bn, bk=bk: matmul(
                a, b, block_m=bm, block_n=bn, block_k=bk,
                interpret=interpret),
            a, b, iters=iters, warmup=warmup)
        trials.append(Trial((bm, bn, bk), t, std))
        if (bm, bn, bk) == DEFAULT_MATMUL_BLOCKS:
            baseline_us = t
        if verbose:
            print(f"  matmul {m}x{k}x{n} blocks=({bm},{bn},{bk}): {t:.1f} us")
    best = min(trials, key=lambda t: t.time_us)
    cfg = TunedConfig(
        op="matmul", shape=(m, k, n), dtype=_dtype_name(dtype),
        hw_name=hw.name,
        blocks={"block_m": best.blocks[0], "block_n": best.blocks[1],
                "block_k": best.blocks[2]},
        time_us=best.time_us, baseline_us=baseline_us,
        candidates_tried=len(trials), time_us_std=best.time_us_std)
    cache.put(cfg)
    return cfg


def autotune_fused_mlp(m: int, h: int, f: int, *, mlp_type: str = "swiglu",
                       dtype=jnp.float32, hw: Optional[Hardware] = None,
                       cache: Optional[TuningCache] = None,
                       interpret: bool = True, iters: int = 3,
                       warmup: int = 1,
                       max_candidates: Optional[int] = None,
                       verbose: bool = False) -> TunedConfig:
    """Sweep (block_m, block_f, block_k) for an (m, h, f) fused MLP hidden
    problem (kernels/fused_mlp); persist and return the measured winner
    under op "fused_mlp_<mlp_type>".

    `fused_mlp_hidden(tuned=True)` — and therefore `linear_impl="fused"`
    model MLPs, which flatten (b, s, h) to m = b*s — picks the entry up by
    the same (m, h, f) key.
    """
    from ..kernels.fused_mlp.ops import fused_mlp_hidden, fused_mlp_op_name
    from ..kernels.fused_mlp.ref import is_gated

    hw = hw or get_hardware()
    cache = cache if cache is not None else get_default_cache()
    dtype_bytes = jnp.dtype(dtype).itemsize
    gated = is_gated(mlp_type)
    cands = fused_mlp_candidates(m, h, f, hw, dtype_bytes, gated=gated,
                                 max_candidates=max_candidates)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, h)).astype(dtype)
    wg = (jax.random.normal(jax.random.fold_in(key, 1), (h, f)).astype(dtype)
          if gated else None)
    wu = jax.random.normal(jax.random.fold_in(key, 2), (h, f)).astype(dtype)

    trials: List[Trial] = []
    baseline_us = 0.0
    for bm, bf, bk in cands:
        t, std = _measure(
            fused_mlp_op_name(mlp_type),
            lambda x, wu, bm=bm, bf=bf, bk=bk: fused_mlp_hidden(
                x, wg, wu, mlp_type=mlp_type, block_m=bm, block_f=bf,
                block_k=bk, interpret=interpret),
            x, wu, iters=iters, warmup=warmup)
        trials.append(Trial((bm, bf, bk), t, std))
        if (bm, bf, bk) == DEFAULT_FUSED_MLP_BLOCKS:
            baseline_us = t
        if verbose:
            print(f"  fused_mlp[{mlp_type}] {m}x{h}x{f} "
                  f"blocks=({bm},{bf},{bk}): {t:.1f} us")
    best = min(trials, key=lambda t: t.time_us)
    cfg = TunedConfig(
        op=fused_mlp_op_name(mlp_type), shape=(m, h, f),
        dtype=_dtype_name(dtype), hw_name=hw.name,
        blocks={"block_m": best.blocks[0], "block_f": best.blocks[1],
                "block_k": best.blocks[2]},
        time_us=best.time_us, baseline_us=baseline_us,
        candidates_tried=len(trials), time_us_std=best.time_us_std)
    cache.put(cfg)
    return cfg


def autotune_int8_matmul(m: int, k: int, n: int, *, dtype=jnp.float32,
                         hw: Optional[Hardware] = None,
                         cache: Optional[TuningCache] = None,
                         interpret: bool = True, iters: int = 3,
                         warmup: int = 1,
                         max_candidates: Optional[int] = None,
                         verbose: bool = False) -> TunedConfig:
    """Sweep (block_m, block_n, block_k) for an int8-weight (m, k, n) GEMM
    over the int8 lattice (32-sublane granule, int8 VMEM model); persist and
    return the winner under op "int8_matmul" with the *mixed* dtype key
    (activation x weight, e.g. "float32xint8") — the key
    `int8_matmul(tuned=True)` looks up."""
    from ..kernels.quantized.ops import int8_matmul
    from ..quant import quantize_weight

    hw = hw or get_hardware()
    cache = cache if cache is not None else get_default_cache()
    cands = int8_matmul_candidates(m, k, n, hw, max_candidates=max_candidates)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k)).astype(dtype)
    wq = quantize_weight(
        jax.random.normal(jax.random.fold_in(key, 1), (k, n)).astype(dtype))

    trials: List[Trial] = []
    baseline_us = 0.0
    for bm, bn, bk in cands:
        t, std = _measure(
            "int8_matmul",
            lambda a, bm=bm, bn=bn, bk=bk: int8_matmul(
                a, wq, block_m=bm, block_n=bn, block_k=bk,
                interpret=interpret),
            a, iters=iters, warmup=warmup)
        trials.append(Trial((bm, bn, bk), t, std))
        if (bm, bn, bk) == DEFAULT_MATMUL_BLOCKS:
            baseline_us = t
        if verbose:
            print(f"  int8_matmul {m}x{k}x{n} blocks=({bm},{bn},{bk}): "
                  f"{t:.1f} us")
    best = min(trials, key=lambda t: t.time_us)
    cfg = TunedConfig(
        op="int8_matmul", shape=(m, k, n),
        dtype=mixed_dtype(_dtype_name(dtype), "int8"), hw_name=hw.name,
        blocks={"block_m": best.blocks[0], "block_n": best.blocks[1],
                "block_k": best.blocks[2]},
        time_us=best.time_us, baseline_us=baseline_us,
        candidates_tried=len(trials), time_us_std=best.time_us_std)
    cache.put(cfg)
    return cfg


def autotune_fp8_matmul(m: int, k: int, n: int, *,
                        fp8_dtype: str = "float8_e4m3fn", dtype=jnp.float32,
                        hw: Optional[Hardware] = None,
                        cache: Optional[TuningCache] = None,
                        interpret: bool = True, iters: int = 3,
                        warmup: int = 1,
                        max_candidates: Optional[int] = None,
                        verbose: bool = False) -> TunedConfig:
    """Sweep blocks for the emulated-fp8 (m, k, n) GEMM; persist the winner
    under op "fp8_matmul" with the mixed dtype key (e.g.
    "float32xfloat8_e4m3fn")."""
    from ..kernels.quantized.ops import fp8_matmul

    hw = hw or get_hardware()
    cache = cache if cache is not None else get_default_cache()
    cands = fp8_matmul_candidates(m, k, n, hw, max_candidates=max_candidates)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n)).astype(dtype)

    trials: List[Trial] = []
    baseline_us = 0.0
    for bm, bn, bk in cands:
        t, std = _measure(
            "fp8_matmul",
            lambda a, b, bm=bm, bn=bn, bk=bk: fp8_matmul(
                a, b, fp8_dtype=fp8_dtype, block_m=bm, block_n=bn,
                block_k=bk, interpret=interpret),
            a, b, iters=iters, warmup=warmup)
        trials.append(Trial((bm, bn, bk), t, std))
        if (bm, bn, bk) == DEFAULT_MATMUL_BLOCKS:
            baseline_us = t
        if verbose:
            print(f"  fp8_matmul[{fp8_dtype}] {m}x{k}x{n} "
                  f"blocks=({bm},{bn},{bk}): {t:.1f} us")
    best = min(trials, key=lambda t: t.time_us)
    cfg = TunedConfig(
        op="fp8_matmul", shape=(m, k, n),
        dtype=mixed_dtype(_dtype_name(dtype), fp8_dtype), hw_name=hw.name,
        blocks={"block_m": best.blocks[0], "block_n": best.blocks[1],
                "block_k": best.blocks[2]},
        time_us=best.time_us, baseline_us=baseline_us,
        candidates_tried=len(trials), time_us_std=best.time_us_std)
    cache.put(cfg)
    return cfg


def autotune_int8_fused_mlp(m: int, h: int, f: int, *,
                            mlp_type: str = "swiglu", dtype=jnp.float32,
                            hw: Optional[Hardware] = None,
                            cache: Optional[TuningCache] = None,
                            interpret: bool = True, iters: int = 3,
                            warmup: int = 1,
                            max_candidates: Optional[int] = None,
                            verbose: bool = False) -> TunedConfig:
    """Sweep (block_m, block_f, block_k) for the int8-weight fused-MLP
    hidden; persist the winner under op "int8_fused_mlp_<mlp_type>" with the
    mixed dtype key."""
    from ..kernels.fused_mlp.ref import is_gated
    from ..kernels.quantized.ops import (int8_fused_mlp_hidden,
                                         int8_fused_mlp_op_name)
    from ..quant import quantize_weight

    hw = hw or get_hardware()
    cache = cache if cache is not None else get_default_cache()
    gated = is_gated(mlp_type)
    cands = int8_fused_mlp_candidates(m, h, f, hw, gated=gated,
                                      max_candidates=max_candidates)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, h)).astype(dtype)
    wg = (quantize_weight(jax.random.normal(
        jax.random.fold_in(key, 1), (h, f)).astype(dtype)) if gated else None)
    wu = quantize_weight(jax.random.normal(
        jax.random.fold_in(key, 2), (h, f)).astype(dtype))

    trials: List[Trial] = []
    baseline_us = 0.0
    for bm, bf, bk in cands:
        t, std = _measure(
            int8_fused_mlp_op_name(mlp_type),
            lambda x, bm=bm, bf=bf, bk=bk: int8_fused_mlp_hidden(
                x, wg, wu, mlp_type=mlp_type, block_m=bm, block_f=bf,
                block_k=bk, interpret=interpret),
            x, iters=iters, warmup=warmup)
        trials.append(Trial((bm, bf, bk), t, std))
        if (bm, bf, bk) == DEFAULT_FUSED_MLP_BLOCKS:
            baseline_us = t
        if verbose:
            print(f"  int8_fused_mlp[{mlp_type}] {m}x{h}x{f} "
                  f"blocks=({bm},{bf},{bk}): {t:.1f} us")
    best = min(trials, key=lambda t: t.time_us)
    cfg = TunedConfig(
        op=int8_fused_mlp_op_name(mlp_type), shape=(m, h, f),
        dtype=mixed_dtype(_dtype_name(dtype), "int8"), hw_name=hw.name,
        blocks={"block_m": best.blocks[0], "block_f": best.blocks[1],
                "block_k": best.blocks[2]},
        time_us=best.time_us, baseline_us=baseline_us,
        candidates_tried=len(trials), time_us_std=best.time_us_std)
    cache.put(cfg)
    return cfg


def autotune_paged_decode(batch: int, slots: int, s_max: int, kv_heads: int,
                          heads: int, head_dim: int, *, dtype=jnp.float32,
                          hw: Optional[Hardware] = None,
                          cache: Optional[TuningCache] = None,
                          interpret: bool = True, iters: int = 3,
                          warmup: int = 1,
                          max_candidates: Optional[int] = None,
                          verbose: bool = False) -> TunedConfig:
    """Sweep block_kv for the serving engine's paged decode kernel over a
    (slots, s_max, kv_heads, head_dim) KV pool with `batch` active rows;
    persist and return the measured winner (op "paged_decode")."""
    from ..kernels.flash_attention.ops import paged_decode

    hw = hw or get_hardware()
    cache = cache if cache is not None else get_default_cache()
    dtype_bytes = jnp.dtype(dtype).itemsize
    g = heads // kv_heads
    cands = paged_decode_candidates(s_max, head_dim, g, hw, dtype_bytes,
                                    max_candidates=max_candidates)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (batch, heads, head_dim)).astype(dtype)
    pool_shape = (slots, s_max, kv_heads, head_dim)
    kp = jax.random.normal(jax.random.fold_in(key, 1), pool_shape).astype(dtype)
    vp = jax.random.normal(jax.random.fold_in(key, 2), pool_shape).astype(dtype)
    slot_idx = jnp.arange(batch, dtype=jnp.int32) % slots
    lengths = jnp.full((batch,), s_max, jnp.int32)

    trials: List[Trial] = []
    baseline_us = 0.0
    for bkv in cands:
        t, std = _measure(
            "paged_decode",
            lambda q, kp, vp, si, ln, bkv=bkv: paged_decode(
                q, kp, vp, si, ln, block_kv=bkv, interpret=interpret),
            q, kp, vp, slot_idx, lengths, iters=iters, warmup=warmup)
        trials.append(Trial((bkv,), t, std))
        if bkv == DEFAULT_PAGED_BLOCK_KV:
            baseline_us = t
        if verbose:
            print(f"  paged b{batch} pool{slots}x{s_max} kv{kv_heads} "
                  f"d{head_dim} block_kv={bkv}: {t:.1f} us")
    best = min(trials, key=lambda t: t.time_us)
    cfg = TunedConfig(
        op="paged_decode",
        shape=(batch, slots, s_max, kv_heads, heads, head_dim),
        dtype=_dtype_name(dtype), hw_name=hw.name,
        blocks={"block_kv": best.blocks[0]},
        time_us=best.time_us, baseline_us=baseline_us,
        candidates_tried=len(trials), time_us_std=best.time_us_std)
    cache.put(cfg)
    return cfg


def autotune_paged_decode_blocktable(batch: int, num_rows: int, s_max: int,
                                     kv_heads: int, heads: int,
                                     head_dim: int, *, dtype=jnp.float32,
                                     hw: Optional[Hardware] = None,
                                     cache: Optional[TuningCache] = None,
                                     interpret: bool = True, iters: int = 3,
                                     warmup: int = 1,
                                     max_candidates: Optional[int] = None,
                                     verbose: bool = False) -> TunedConfig:
    """Jointly sweep (block_size, block_kv) for the block-table decode kernel
    over a pool sized for `num_rows` sequences of up to `s_max` tokens.

    Each block_size candidate implies its own pool geometry — num_blocks =
    num_rows * s_max/block_size physical blocks of block_size tokens — so the
    paging granule is measured as a real cost (more table indirections per
    row at small blocks vs. coarser sharing at large ones), not assumed.

    Two kinds of cache entry are written:
      * op "paged_decode_blocktable_pool", shape (batch, num_rows, s_max,
        kv_heads, heads, head_dim), blocks {block_size, block_kv} — the
        engine-level entry `ServeEngine(prefix_cache=True)` consults to pick
        its physical block size;
      * op "paged_decode_blocktable", shape (batch, num_blocks, block_size,
        kv_heads, heads, head_dim), blocks {block_kv} — one per block_size
        tried (best block_kv at that size), so
        `paged_decode_blocktable(tuned=True)` hits whatever pool shape the
        engine ends up running.
    Returns the pool-level winner.
    """
    from ..kernels.flash_attention.ops import paged_decode_blocktable

    hw = hw or get_hardware()
    cache = cache if cache is not None else get_default_cache()
    dtype_bytes = jnp.dtype(dtype).itemsize
    g = heads // kv_heads
    cands = paged_blocktable_candidates(s_max, head_dim, g, hw, dtype_bytes,
                                        max_candidates=max_candidates)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (batch, heads, head_dim)).astype(dtype)
    lengths = jnp.full((batch,), s_max, jnp.int32)

    trials: List[Trial] = []
    best_at_size: dict = {}
    for bs, bkv in cands:
        max_blocks = s_max // bs
        nb = num_rows * max_blocks
        pool_shape = (nb, bs, kv_heads, head_dim)
        kb = jax.random.normal(jax.random.fold_in(key, 1),
                               pool_shape).astype(dtype)
        vb = jax.random.normal(jax.random.fold_in(key, 2),
                               pool_shape).astype(dtype)
        tables = (jnp.arange(batch, dtype=jnp.int32)[:, None] * max_blocks
                  + jnp.arange(max_blocks, dtype=jnp.int32)[None, :]) % nb
        t, std = _measure(
            "paged_decode_blocktable",
            lambda q, kb, vb, tb, ln, bs=bs, bkv=bkv: paged_decode_blocktable(
                q, kb, vb, tb, ln, block_kv=bkv, interpret=interpret),
            q, kb, vb, tables, lengths, iters=iters, warmup=warmup)
        trials.append(Trial((bs, bkv), t, std))
        if bs not in best_at_size or t < best_at_size[bs][1]:
            best_at_size[bs] = (bkv, t, nb, std)
        if verbose:
            print(f"  paged_bt b{batch} rows{num_rows} s{s_max} kv{kv_heads} "
                  f"d{head_dim} block_size={bs} block_kv={bkv}: {t:.1f} us")
    # per-pool-shape entries: the kernel-level tuned lookup
    for bs, (bkv, t, nb, std) in best_at_size.items():
        cache.put(TunedConfig(
            op="paged_decode_blocktable",
            shape=(batch, nb, bs, kv_heads, heads, head_dim),
            dtype=_dtype_name(dtype), hw_name=hw.name,
            blocks={"block_kv": bkv}, time_us=t, baseline_us=0.0,
            candidates_tried=sum(1 for tr in trials if tr.blocks[0] == bs),
            time_us_std=std))
    best = min(trials, key=lambda t: t.time_us)
    # baseline for the speedup quote: the coarsest paging granule tried
    # (one block = whole sequence, i.e. the slot-pool layout)
    bs_max = max(bs for bs, _ in cands)
    baseline_us = min((t.time_us for t in trials if t.blocks[0] == bs_max),
                      default=0.0)
    cfg = TunedConfig(
        op="paged_decode_blocktable_pool",
        shape=(batch, num_rows, s_max, kv_heads, heads, head_dim),
        dtype=_dtype_name(dtype), hw_name=hw.name,
        blocks={"block_size": best.blocks[0], "block_kv": best.blocks[1]},
        time_us=best.time_us, baseline_us=baseline_us,
        candidates_tried=len(trials), time_us_std=best.time_us_std)
    cache.put(cfg)
    return cfg


def autotune_flash_attention(batch: int, seq: int, heads: int, head_dim: int,
                             *, seq_kv: Optional[int] = None,
                             causal: bool = True, dtype=jnp.float32,
                             hw: Optional[Hardware] = None,
                             cache: Optional[TuningCache] = None,
                             interpret: bool = True, iters: int = 3,
                             warmup: int = 1,
                             max_candidates: Optional[int] = None,
                             verbose: bool = False) -> TunedConfig:
    """Sweep (block_q, block_kv) for a (batch, seq, heads, head_dim)
    attention problem; persist and return the measured winner."""
    from ..kernels.flash_attention.ops import flash_attention

    hw = hw or get_hardware()
    cache = cache if cache is not None else get_default_cache()
    seq_kv = seq_kv or seq
    dtype_bytes = jnp.dtype(dtype).itemsize
    cands = flash_candidates(seq, seq_kv, head_dim, hw, dtype_bytes,
                             max_candidates=max_candidates)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (batch, seq, heads, head_dim)).astype(dtype)
    kv_shape = (batch, seq_kv, heads, head_dim)
    k = jax.random.normal(jax.random.fold_in(key, 1), kv_shape).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), kv_shape).astype(dtype)

    trials: List[Trial] = []
    baseline_us = 0.0
    for bq, bkv in cands:
        t, std = _measure(
            flash_op_name(causal),
            lambda q, k, v, bq=bq, bkv=bkv: flash_attention(
                q, k, v, causal=causal, block_q=bq, block_kv=bkv,
                interpret=interpret),
            q, k, v, iters=iters, warmup=warmup)
        trials.append(Trial((bq, bkv), t, std))
        if (bq, bkv) == DEFAULT_FLASH_BLOCKS:
            baseline_us = t
        if verbose:
            print(f"  flash b{batch} s{seq} a{heads} d{head_dim} "
                  f"blocks=({bq},{bkv}): {t:.1f} us")
    best = min(trials, key=lambda t: t.time_us)
    cfg = TunedConfig(
        op=flash_op_name(causal),
        shape=(batch, seq, seq_kv, heads, head_dim),
        dtype=_dtype_name(dtype), hw_name=hw.name,
        blocks={"block_q": best.blocks[0], "block_kv": best.blocks[1]},
        time_us=best.time_us, baseline_us=baseline_us,
        candidates_tried=len(trials), time_us_std=best.time_us_std)
    cache.put(cfg)
    return cfg


def autotune_flash_backward(batch: int, seq: int, heads: int, head_dim: int,
                            *, seq_kv: Optional[int] = None,
                            causal: bool = True, dtype=jnp.float32,
                            hw: Optional[Hardware] = None,
                            cache: Optional[TuningCache] = None,
                            interpret: bool = True, iters: int = 3,
                            warmup: int = 1,
                            max_candidates: Optional[int] = None,
                            verbose: bool = False) -> TunedConfig:
    """Sweep (block_q, block_kv) for the flash-attention *backward* grids of
    a (batch, seq, heads, head_dim) problem; persist and return the measured
    winner under op "flash_attention_bwd_causal" / "..._full".

    Each trial times jax.grad through `flash_attention` with the forward
    pinned to its 128 defaults and only the backward blocks varying, so the
    ranking isolates the dq/dkv grids (the forward cost is a constant
    offset).  `flash_attention(tuned=True)` then picks the entry up
    alongside the forward one — forward and backward tile geometries tune
    independently, as on real hardware.
    """
    from ..kernels.flash_attention.ops import flash_attention

    hw = hw or get_hardware()
    cache = cache if cache is not None else get_default_cache()
    seq_kv = seq_kv or seq
    dtype_bytes = jnp.dtype(dtype).itemsize
    cands = flash_backward_candidates(seq, seq_kv, head_dim, hw, dtype_bytes,
                                      max_candidates=max_candidates)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (batch, seq, heads, head_dim)).astype(dtype)
    kv_shape = (batch, seq_kv, heads, head_dim)
    k = jax.random.normal(jax.random.fold_in(key, 1), kv_shape).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), kv_shape).astype(dtype)

    trials: List[Trial] = []
    baseline_us = 0.0
    for bq, bkv in cands:
        def vjp(q, k, v, bq=bq, bkv=bkv):
            return jax.grad(
                lambda q, k, v: flash_attention(
                    q, k, v, causal=causal, bwd_block_q=bq, bwd_block_kv=bkv,
                    interpret=interpret).sum().astype(jnp.float32),
                argnums=(0, 1, 2))(q, k, v)
        t, std = _measure(flash_bwd_op_name(causal), vjp, q, k, v,
                          iters=iters, warmup=warmup, jit=True)
        trials.append(Trial((bq, bkv), t, std))
        if (bq, bkv) == DEFAULT_FLASH_BLOCKS:
            baseline_us = t
        if verbose:
            print(f"  flash_bwd b{batch} s{seq} a{heads} d{head_dim} "
                  f"blocks=({bq},{bkv}): {t:.1f} us")
    best = min(trials, key=lambda t: t.time_us)
    cfg = TunedConfig(
        op=flash_bwd_op_name(causal),
        shape=(batch, seq, seq_kv, heads, head_dim),
        dtype=_dtype_name(dtype), hw_name=hw.name,
        blocks={"block_q": best.blocks[0], "block_kv": best.blocks[1]},
        time_us=best.time_us, baseline_us=baseline_us,
        candidates_tried=len(trials), time_us_std=best.time_us_std)
    cache.put(cfg)
    return cfg
