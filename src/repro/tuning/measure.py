"""Wall-clock measurement for autotuning and benchmarks.

This is the single timing primitive for the repo: `benchmarks/common.py`
delegates here so the autotuner and the benchmark harness measure the same
way.  On this CPU container, Pallas kernels run in interpret mode and the
numbers rank candidates *relatively*; on a real TPU the same code times the
compiled kernels and the cache entries become deployment-grade.
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def wall_us(fn: Callable, *args, iters: int = 5, warmup: int = 2,
            jit: bool = True) -> float:
    """Mean wall time of `fn(*args)` in microseconds, after `warmup` calls.

    `fn` is jitted by default (pass jit=False for already-jitted callables or
    functions that must not be traced twice)."""
    f = jax.jit(fn) if jit else fn
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    out = None
    for _ in range(max(iters, 1)):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / max(iters, 1) * 1e6
