"""Wall-clock measurement for autotuning and benchmarks.

This is the single timing primitive for the repo: `benchmarks/common.py`
delegates here so the autotuner and the benchmark harness measure the same
way.  On this CPU container, Pallas kernels run in interpret mode and the
numbers rank candidates *relatively*; on a real TPU the same code times the
compiled kernels and the cache entries become deployment-grade.

`wall_us(..., return_samples=True)` additionally returns the per-iteration
samples (each iteration individually synced), so callers can report
variance: the autotuner records the winner's std in the tuning cache
(`TunedConfig.time_us_std`) and feeds the samples to the obs histograms —
a candidate whose mean wins inside the noise band is not a real ranking.
"""
from __future__ import annotations

import time
from typing import Callable, List

import jax


def wall_us(fn: Callable, *args, iters: int = 5, warmup: int = 2,
            jit: bool = True, return_samples: bool = False
            ) -> "Union[float, Tuple[float, List[float]]]":
    """Mean wall time of `fn(*args)` in microseconds, after `warmup` calls.

    `fn` is jitted by default (pass jit=False for already-jitted callables or
    functions that must not be traced twice).

    Default path: one sync after the whole loop (back-to-back dispatch, the
    steady-state number).  With return_samples=True each iteration is timed
    and synced individually and (mean, samples_us) is returned — slightly
    more sync overhead per iteration, in exchange for a variance estimate.
    """
    f = jax.jit(fn) if jit else fn
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(f(*args))
    if return_samples:
        samples: List[float] = []
        for _ in range(max(iters, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*args))
            samples.append((time.perf_counter() - t0) * 1e6)
        return sum(samples) / len(samples), samples
    t0 = time.perf_counter()
    out = None
    for _ in range(max(iters, 1)):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / max(iters, 1) * 1e6
