"""Kernel autotuning: measured block-size search + persistent tuning cache.

The subsystem closes the loop the analytic model leaves open:

    candidates.py  tile-aligned (block_*) lattice under the VMEM budget
    measure.py     the wall-clock timer (shared with benchmarks/)
    search.py      sweep + time candidates, persist winners
    cache.py       JSON cache keyed by (op, shape, dtype, hw_name)

Kernel wrappers (`kernels/*/ops.py`) consult the default cache when called
with `tuned=True`; `core.gemm_model.MeasuredProfile` turns the same cache
into a calibration layer for `core.advisor` predictions.

`search` is imported lazily (PEP 562) because it imports the kernel
wrappers, which themselves import `tuning.cache` — eager import would cycle.
"""
from .cache import (TunedConfig, TuningCache, cache_key, default_cache_path,
                    get_default_cache, lookup, mixed_dtype, set_default_cache)
from .candidates import (bucket_steps, flash_backward_candidates,
                         flash_bwd_vmem_bytes, flash_candidates,
                         flash_vmem_bytes, fp8_matmul_candidates,
                         fp8_matmul_vmem_bytes, fused_mlp_candidates,
                         fused_mlp_vmem_bytes, int8_fused_mlp_candidates,
                         int8_fused_mlp_vmem_bytes, int8_matmul_candidates,
                         int8_matmul_vmem_bytes, matmul_candidates,
                         matmul_vmem_bytes, paged_blocktable_candidates,
                         paged_decode_candidates)
from .measure import wall_us

_SEARCH_EXPORTS = ("autotune_matmul", "autotune_flash_attention",
                   "autotune_flash_backward", "autotune_fused_mlp",
                   "autotune_int8_matmul", "autotune_fp8_matmul",
                   "autotune_int8_fused_mlp",
                   "autotune_paged_decode",
                   "autotune_paged_decode_blocktable",
                   "flash_op_name", "flash_bwd_op_name")

__all__ = [
    "TunedConfig", "TuningCache", "cache_key", "default_cache_path",
    "get_default_cache", "lookup", "mixed_dtype", "set_default_cache",
    "bucket_steps", "flash_backward_candidates", "flash_bwd_vmem_bytes",
    "flash_candidates", "flash_vmem_bytes",
    "fp8_matmul_candidates", "fp8_matmul_vmem_bytes",
    "fused_mlp_candidates", "fused_mlp_vmem_bytes",
    "int8_fused_mlp_candidates", "int8_fused_mlp_vmem_bytes",
    "int8_matmul_candidates", "int8_matmul_vmem_bytes",
    "matmul_candidates", "matmul_vmem_bytes", "paged_blocktable_candidates",
    "paged_decode_candidates",
    "wall_us", *_SEARCH_EXPORTS,
]


def __getattr__(name):
    if name in _SEARCH_EXPORTS:
        from . import search
        return getattr(search, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
