"""Predicted-vs-measured drift monitor.

The analytic cost model (`core.gemm_model`, calibrated by
`MeasuredProfile` from the tuning cache) predicts a step time for every
program the engine lowers; the span tracer measures what each program
actually took.  This module holds both sides against each other, per
program site (one row per prefill bucket + one for the pool decode step),
and reports the prediction error — the exact quantity the ROADMAP's
measured shape-search loop will optimize against: a shape whose *relative*
drift is high is a shape where the model would mis-rank candidates.

Two error views per site:

  * ratio      — measured_p50 / predicted.  On a real TPU this is the
    model's absolute error (~1-2x); on this CPU container (interpret-mode
    kernels vs TPU analytic constants) it is huge but roughly uniform;
  * rel_drift  — ratio / median(ratio over all sites).  The uniform
    calibration constant divides out, so rel_drift ~ 1.0 everywhere means
    the model ranks the engine's programs correctly even when its absolute
    scale is off.  This is the number to watch on CPU.
"""
from __future__ import annotations

import dataclasses
import json
import statistics
from typing import Dict, List, Optional

from ..configs.base import ModelConfig, ShapeConfig
from ..core.advisor import step_time
from ..core.gemm_model import MeasuredProfile
from ..core.hardware import Hardware, get_hardware


@dataclasses.dataclass
class _Site:
    predicted_s: float
    observed_s: List[float] = dataclasses.field(default_factory=list)


class DriftMonitor:
    """Accumulate observed durations per predicted site; report drift."""

    def __init__(self, hw_name: str = ""):
        self.hw_name = hw_name
        self._sites: Dict[str, _Site] = {}

    # -- construction --------------------------------------------------------

    @classmethod
    def for_engine(cls, cfg: ModelConfig, policy,
                   hw: Optional[Hardware] = None,
                   profile: Optional[MeasuredProfile] = None
                   ) -> "DriftMonitor":
        """One predicted site per engine program: every prefill bucket
        (batch 1, forward-only at the bucket length) plus the pool-wide
        decode step (batch = num_slots against a seq_max-deep cache —
        the upper bound the bucket policy sizes for)."""
        hw = hw or get_hardware()
        if profile is None:
            profile = MeasuredProfile.from_cache(None, hw.name)
        mon = cls(hw_name=hw.name)
        for b in policy.prompt_buckets:
            shape = ShapeConfig(f"obs_prefill_{b}", b, 1, "prefill")
            mon.add_site(f"prefill_{b}",
                         step_time(cfg, shape, hw, profile=profile))
        shape = ShapeConfig("obs_decode", policy.seq_max, policy.num_slots,
                            "decode")
        mon.add_site("decode_step",
                     step_time(cfg, shape, hw, microbatch=policy.num_slots,
                               profile=profile))
        return mon

    def add_site(self, site: str, predicted_s: float) -> None:
        self._sites[site] = _Site(predicted_s=predicted_s)

    # -- observation ---------------------------------------------------------

    def observe(self, site: str, dur_s: float) -> None:
        st = self._sites.get(site)
        if st is None:
            st = self._sites[site] = _Site(predicted_s=0.0)
        st.observed_s.append(dur_s)

    # -- reporting -----------------------------------------------------------

    def report(self) -> List[dict]:
        """One row per site with >= 1 observation, plus the per-site ratio
        normalized by the median ratio (rel_drift) — see module docstring."""
        rows = []
        for site, st in sorted(self._sites.items()):
            if not st.observed_s:
                continue
            xs = sorted(st.observed_s)
            p50 = xs[len(xs) // 2]
            ratio = (p50 / st.predicted_s) if st.predicted_s > 0 else None
            rows.append({
                "site": site,
                "count": len(xs),
                "predicted_ms": st.predicted_s * 1e3,
                "measured_p50_ms": p50 * 1e3,
                "measured_mean_ms": sum(xs) / len(xs) * 1e3,
                "ratio": ratio,
            })
        ratios = [r["ratio"] for r in rows if r["ratio"]]
        med = statistics.median(ratios) if ratios else 0.0
        for r in rows:
            r["rel_drift"] = (r["ratio"] / med) if (r["ratio"] and med) else None
        return rows

    def to_json(self) -> dict:
        return {"hw_name": self.hw_name, "rows": self.report()}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
