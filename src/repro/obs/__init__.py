"""Observability layer: span tracing, metrics, recompile watchdog, drift.

The paper's argument is a *measurement* argument — step time attributed to
shape choices — and this package is where the repo measures itself:

  * `obs.trace`         — nestable host-clock spans, exported as Chrome
    trace-event JSON (Perfetto-loadable) with `jax.profiler.TraceAnnotation`
    pass-through for XLA profile attribution;
  * `obs.metrics`       — counters / gauges / histograms with JSON and
    Prometheus text snapshots;
  * `obs.compile_watch` — records every XLA compile and, when armed,
    *fails* on an unexpected one (the engine's bounded-program invariant,
    enforced);
  * `obs.drift`         — predicted (analytic / MeasuredProfile) vs
    measured step time, per engine program site;
  * `obs.view`          — `python -m repro.obs.view DUMP_DIR` summarizes a
    dump (top spans, step percentiles, compile table, drift table).

Everything is OFF by default and zero-cost when disabled: instrumented
call sites go through the module-level helpers below, which check one
bool and hand back shared no-op objects — no events, no allocation, no
device sync.  `obs.enable()` flips the flag (or set REPRO_OBS=1 before
launch); instrumentation lives strictly outside jitted code, so enabling
it never changes a traced program.

    from repro import obs
    obs.enable()
    ... run the engine / train loop ...
    obs.export_all("obs_dump", drift=engine.drift)
    # then: python -m repro.obs.view obs_dump
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

from .compile_watch import CompileRecord, CompileWatch, UnexpectedCompile
from .drift import DriftMonitor
from .metrics import REGISTRY, MetricsRegistry
from .trace import NULL_SPAN, Tracer

__all__ = [
    "enable", "disable", "enabled", "span", "instant", "counter", "gauge",
    "histogram", "record_dispatch", "get_tracer", "get_metrics",
    "export_all", "Tracer", "MetricsRegistry", "CompileWatch",
    "CompileRecord", "UnexpectedCompile", "DriftMonitor",
]

_enabled = os.environ.get("REPRO_OBS", "") not in ("", "0")
_tracer = Tracer()


def enable(capacity: Optional[int] = None,
           annotate_device: bool = True) -> None:
    """Turn instrumentation on (optionally resizing the trace buffer)."""
    global _enabled, _tracer
    if capacity is not None and capacity != _tracer.capacity:
        _tracer = Tracer(capacity=capacity, annotate_device=annotate_device)
    else:
        _tracer.annotate_device = annotate_device
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def get_tracer() -> Tracer:
    return _tracer


def get_metrics() -> MetricsRegistry:
    return REGISTRY


# -- hot-path helpers: one bool check when disabled ---------------------------


def span(name: str, cat: str = "engine", **args):
    """Timed span context manager; shared no-op when disabled."""
    if not _enabled:
        return NULL_SPAN
    return _tracer.span(name, cat, **args)


def instant(name: str, cat: str = "engine", **args) -> None:
    if _enabled:
        _tracer.instant(name, cat, **args)


def counter(name: str):
    return REGISTRY.counter(name)


def gauge(name: str):
    return REGISTRY.gauge(name)


def histogram(name: str, sample_cap: int = 1024):
    return REGISTRY.histogram(name, sample_cap)


def record_dispatch(op: str, *, impl: str, shape, site: str = "",
                    blocks: Optional[Dict[str, int]] = None,
                    tuned_hit: Optional[bool] = None) -> None:
    """Annotate one kernel-dispatch decision (op, impl, problem shape, and
    the chosen block config).  Called from the kernel `ops.py` wrappers at
    trace/dispatch time — i.e. once per lowered program, not per step — so
    the dump shows exactly which impl and blocking every model GEMM site
    ended up with."""
    if not _enabled:
        return
    key = f"dispatch.{op}.{impl}"
    REGISTRY.counter(key).inc()
    if tuned_hit is not None:
        REGISTRY.counter(
            f"dispatch.{op}.cache_{'hit' if tuned_hit else 'miss'}").inc()
    _tracer.instant(op, cat="dispatch", impl=impl, site=site,
                    shape=list(shape), blocks=dict(blocks or {}),
                    tuned_hit=tuned_hit)


# -- export -------------------------------------------------------------------


def export_all(dump_dir: str, *, drift: Optional[DriftMonitor] = None,
               watch: Optional[CompileWatch] = None) -> Dict[str, str]:
    """Write trace.json / metrics.json / metrics.prom (and drift.json /
    compiles.json when given) into `dump_dir`; returns the paths written.
    `python -m repro.obs.view <dump_dir>` summarizes the result."""
    import json

    os.makedirs(dump_dir, exist_ok=True)
    paths: Dict[str, str] = {}

    paths["trace"] = os.path.join(dump_dir, "trace.json")
    _tracer.save(paths["trace"])
    paths["metrics"] = os.path.join(dump_dir, "metrics.json")
    REGISTRY.save(paths["metrics"])
    paths["prometheus"] = os.path.join(dump_dir, "metrics.prom")
    with open(paths["prometheus"], "w") as f:
        f.write(REGISTRY.to_prometheus())
    if drift is not None:
        paths["drift"] = os.path.join(dump_dir, "drift.json")
        drift.save(paths["drift"])
    if watch is not None:
        paths["compiles"] = os.path.join(dump_dir, "compiles.json")
        with open(paths["compiles"], "w") as f:
            json.dump(watch.to_json(), f, indent=2)
    return paths


def reset() -> None:
    """Clear the trace buffer and metrics registry (test hook)."""
    _tracer.clear()
    REGISTRY.clear()
