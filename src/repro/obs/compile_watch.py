"""Recompile watchdog: observe every XLA compile, and optionally FAIL on one.

The serving engine's whole shape discipline (bucket lattice, bounded
program set — `serving/engine/buckets.py`) exists so that steady-state
serving never re-jits.  Until now that was a comment; this module makes it
an enforced invariant:

  * every backend compile is recorded as `(program key, compile wall s)`
    where the program key is the jitted function name + its abstract input
    shapes — the exact identity the jit cache misses on;
  * after `arm()`, any further compile is a *violation*: with
    `raise_on_violation=True` (default) the `UnexpectedCompile` is raised
    from inside the compile itself, so the offending `jit` call site is on
    the stack; `check()` re-raises for callers that prefer to poll.

Two independent signals are tapped (they cross-check each other):

  * jax's compile log records (`jax._src.interpreters.pxla` "Compiling
    <name> with global shapes ..." + `jax._src.dispatch` "Finished XLA
    compilation of jit(<name>) in <s> sec"), captured by installing this
    handler at DEBUG level — jax emits them regardless of
    `jax_log_compiles`, at DEBUG priority, so nothing is printed;
  * `jax.monitoring`'s `/jax/core/compile/backend_compile_duration` event,
    a name-free backend-compile count `check()` also compares against (in
    case a jax upgrade reword the log messages).

`install()` bumps the two jax loggers to DEBUG and restores their previous
levels on `uninstall()`; use the instance as a context manager for scoped
watching.  Compile records are mirrored into `obs.trace`/`obs.metrics`
when observability is enabled.
"""
from __future__ import annotations

import dataclasses
import logging
import re
import threading
import time
from typing import Dict, List, Optional

_COMPILING_RE = re.compile(
    r"^Compiling (\S+) with global shapes and types (.*?)\.\s*Argument",
    re.DOTALL)
_FINISHED_RE = re.compile(
    r"^Finished XLA compilation of (?:jit\()?(.*?)\)? in ([0-9.eE+-]+) sec")

_JAX_COMPILE_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")
_BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class UnexpectedCompile(RuntimeError):
    """An armed CompileWatch saw a compile it was promised would not happen."""


@dataclasses.dataclass(frozen=True)
class CompileRecord:
    key: str          # "<fn name> <abstract input shapes>"
    name: str
    wall_s: float
    armed: bool       # recorded while the watch was armed (= a violation)
    t_s: float        # process-clock time of the record


# jax.monitoring listeners cannot be unregistered individually, so one
# module-level dispatcher forwards backend-compile events to whichever
# watches are currently installed.
_active_watches: "Set[CompileWatch]" = set()
_monitoring_hooked = False
_hook_lock = threading.Lock()


def _on_backend_compile(event: str, duration: float, **kw) -> None:
    if event != _BACKEND_COMPILE_EVENT:
        return
    for w in list(_active_watches):
        w._backend_compile(duration)


def _ensure_monitoring_hook() -> None:
    global _monitoring_hooked
    with _hook_lock:
        if _monitoring_hooked:
            return
        try:
            import jax.monitoring
            jax.monitoring.register_event_duration_secs_listener(
                _on_backend_compile)
            _monitoring_hooked = True
        except Exception:  # pragma: no cover - old jax without monitoring
            pass


class CompileWatch(logging.Handler):
    """Record (and optionally forbid) XLA compiles.  See module docstring."""

    def __init__(self, raise_on_violation: bool = True):
        super().__init__(level=logging.DEBUG)
        self.raise_on_violation = raise_on_violation
        self.records: List[CompileRecord] = []
        self.violations: List[CompileRecord] = []
        self.backend_compiles = 0          # monitoring-event count
        self.armed = False
        self._armed_at_backend = 0
        self._pending: Dict[str, str] = {}  # fn name -> program key
        self._prev_levels: Optional[Dict[str, int]] = None
        self._rec_lock = threading.Lock()

    # -- install / uninstall -------------------------------------------------

    def install(self) -> "CompileWatch":
        _ensure_monitoring_hook()
        self._prev_levels = {}
        self._prev_propagate = {}
        for name in _JAX_COMPILE_LOGGERS:
            lg = logging.getLogger(name)
            self._prev_levels[name] = lg.level
            self._prev_propagate[name] = lg.propagate
            if not lg.isEnabledFor(logging.DEBUG):
                lg.setLevel(logging.DEBUG)
            # the DEBUG records we force through must not reach jax's own
            # stream handler (they'd spam stderr); restored on uninstall
            lg.propagate = False
            lg.addHandler(self)
        _active_watches.add(self)
        return self

    def uninstall(self) -> None:
        _active_watches.discard(self)
        if self._prev_levels is None:
            return
        for name, lvl in self._prev_levels.items():
            lg = logging.getLogger(name)
            lg.removeHandler(self)
            lg.setLevel(lvl)
            lg.propagate = self._prev_propagate[name]
        self._prev_levels = None

    def __enter__(self) -> "CompileWatch":
        return self.install()

    def __exit__(self, *exc) -> bool:
        self.uninstall()
        return False

    # -- arming --------------------------------------------------------------

    def arm(self) -> None:
        """From now on, every compile is a violation.  Call after warmup /
        `Engine.calibrate_step_s()` to enforce the bounded-program claim."""
        self.armed = True
        self._armed_at_backend = self.backend_compiles

    def disarm(self) -> None:
        self.armed = False

    def check(self) -> None:
        """Raise UnexpectedCompile if any compile happened while armed —
        from the parsed log records, or (cross-check) from the name-free
        backend-compile event count."""
        if self.violations:
            keys = ", ".join(v.key for v in self.violations[:4])
            raise UnexpectedCompile(
                f"{len(self.violations)} unexpected compile(s) while armed: "
                f"{keys}")
        if self.armed and self.backend_compiles > self._armed_at_backend:
            raise UnexpectedCompile(
                f"{self.backend_compiles - self._armed_at_backend} backend "
                f"compile event(s) while armed (log records missed them)")

    # -- event sinks ---------------------------------------------------------

    def _backend_compile(self, duration: float) -> None:
        self.backend_compiles += 1

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        m = _COMPILING_RE.match(msg)
        if m:
            name, shapes = m.group(1), " ".join(m.group(2).split())
            with self._rec_lock:
                self._pending[name] = f"{name} {shapes}"
            return
        m = _FINISHED_RE.match(msg)
        if not m:
            return
        name, secs = m.group(1), float(m.group(2))
        with self._rec_lock:
            key = self._pending.pop(name, name)
            rec = CompileRecord(key=key, name=name, wall_s=secs,
                                armed=self.armed, t_s=time.perf_counter())
            self.records.append(rec)
            if self.armed:
                self.violations.append(rec)
        self._mirror(rec)
        if rec.armed and self.raise_on_violation:
            raise UnexpectedCompile(
                f"unexpected compile while armed: {rec.key} "
                f"({rec.wall_s * 1e3:.1f} ms)")

    def _mirror(self, rec: CompileRecord) -> None:
        """Copy the record into the obs trace/metrics when enabled."""
        from . import enabled, get_metrics, get_tracer
        if not enabled():
            return
        get_tracer().instant("compile", cat="compile", key=rec.key,
                             wall_s=rec.wall_s, armed=rec.armed)
        get_metrics().counter("compile.count").inc()
        get_metrics().histogram("compile.wall_s").observe(rec.wall_s)
        if rec.armed:
            get_metrics().counter("compile.violations").inc()

    # -- export --------------------------------------------------------------

    def table(self) -> List[dict]:
        return [dataclasses.asdict(r) for r in self.records]

    def to_json(self) -> dict:
        return {
            "records": self.table(),
            "violations": [dataclasses.asdict(r) for r in self.violations],
            "backend_compiles": self.backend_compiles,
            "armed": self.armed,
        }
