"""Host-side span tracer: nestable timed spans on the engine clock.

One `Tracer` owns a thread-safe ring buffer of trace events.  `span(...)`
is a context manager recording one Chrome trace-event "complete" ("X")
event on exit; `instant(...)` records a point event ("i").  The buffer
exports as Chrome trace-event JSON (`to_chrome` / `save`) — the dump loads
directly in Perfetto / chrome://tracing, with span nesting recovered from
interval containment per thread track.

When `annotate_device=True` every span also enters a
`jax.profiler.TraceAnnotation`, so a concurrent `jax.profiler.trace(...)`
capture attributes XLA host/device activity to the same model sites
(engine step, prefill bucket, ...) the host spans name.

The tracer is deliberately dumb and cheap: no sampling, no aggregation
(that is `obs.metrics`), one lock around a bounded deque.  The module-level
enable flag lives in `repro.obs.__init__`; disabled call sites get a shared
no-op span and never touch this module's state.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

try:  # host->XLA-profile attribution; absent on very old jax
    from jax.profiler import TraceAnnotation as _JaxTraceAnnotation
except Exception:  # pragma: no cover - import guard
    _JaxTraceAnnotation = None

DEFAULT_CAPACITY = 65536


class NullSpan:
    """Shared no-op span handed out when tracing is disabled (and the safe
    default for `dur_s` readers)."""

    __slots__ = ()
    dur_s = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = NullSpan()


class Span:
    """One live span; records an "X" event into its tracer on exit."""

    __slots__ = ("_tr", "name", "cat", "args", "_t0_us", "_ann", "dur_s")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self._tr = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._t0_us = 0.0
        self._ann = None
        self.dur_s = 0.0

    def __enter__(self):
        tr = self._tr
        stack = tr._stack()
        stack.append(self.name)
        if tr.annotate_device and _JaxTraceAnnotation is not None:
            self._ann = _JaxTraceAnnotation(self.name)
            self._ann.__enter__()
        self._t0_us = tr._now_us()
        return self

    def __exit__(self, *exc):
        tr = self._tr
        t1 = tr._now_us()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        stack = tr._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        self.dur_s = (t1 - self._t0_us) * 1e-6
        tr._append({
            "name": self.name, "cat": self.cat, "ph": "X",
            "ts": self._t0_us, "dur": t1 - self._t0_us,
            "pid": tr.pid, "tid": threading.get_ident(),
            "args": dict(self.args, depth=len(stack)),
        })
        return False


class Tracer:
    """Thread-safe bounded event buffer with Chrome trace-event export."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 annotate_device: bool = True):
        self.capacity = capacity
        self.annotate_device = annotate_device
        self.pid = os.getpid()
        self.dropped = 0
        self._buf: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._t0 = time.perf_counter()

    # -- internals -----------------------------------------------------------

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _stack(self) -> List[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = []
            self._local.stack = st
        return st

    def _append(self, ev: dict) -> None:
        with self._lock:
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(ev)

    # -- recording -----------------------------------------------------------

    def span(self, name: str, cat: str = "host", **args) -> Span:
        return Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "host", **args) -> None:
        self._append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._now_us(), "pid": self.pid,
            "tid": threading.get_ident(), "args": args,
        })

    # -- reading / export ----------------------------------------------------

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0
        self._t0 = time.perf_counter()

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (loads in Perfetto as-is)."""
        meta = [{
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": "repro"},
        }]
        return {
            "traceEvents": meta + self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


def span_durations_us(events: List[dict],
                      name: Optional[str] = None) -> List[float]:
    """Durations (us) of the "X" events, optionally filtered by name —
    the helper `view` and the drift/step-percentile reports share."""
    return [e["dur"] for e in events
            if e.get("ph") == "X" and (name is None or e["name"] == name)]
