"""Metrics registry: counters, gauges, histograms with JSON + Prometheus
text snapshots.

The registry is the aggregate sibling of the span tracer (`obs.trace`):
spans answer "where did this step's time go", metrics answer "how many / how
much over the whole run" (tokens emitted, blocks evicted, queue depth,
per-step latency distribution).  Instruments are created on first use and
are individually thread-safe; `snapshot()` / `to_prometheus()` render the
whole registry.

Histograms keep exact count/sum/min/max plus a bounded reservoir of the
most recent samples for percentile estimates — decode-step times are
stationary enough in steady state that a recency window is the right
percentile base, and it bounds memory on long runs.
"""
from __future__ import annotations

import json
import re
import threading
from collections import deque
from typing import Dict, Iterable, List, Optional

import numpy as np

_PROM_SAFE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _PROM_SAFE.sub("_", name)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Sum/count/min/max plus a recency reservoir for percentiles."""

    __slots__ = ("name", "count", "total", "min", "max", "_samples", "_lock")

    def __init__(self, name: str, sample_cap: int = 1024):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples: deque = deque(maxlen=sample_cap)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            self._samples.append(v)

    def observe_many(self, vs: Iterable[float]) -> None:
        for v in vs:
            self.observe(v)

    def percentile(self, p: float) -> Optional[float]:
        with self._lock:
            if not self._samples:
                return None
            return float(np.percentile(np.asarray(self._samples, np.float64), p))

    def summary(self) -> dict:
        with self._lock:
            if not self.count:
                return {"count": 0}
            xs = np.asarray(self._samples, np.float64)
            return {
                "count": self.count,
                "sum": self.total,
                "mean": self.total / self.count,
                "min": self.min,
                "max": self.max,
                "p50": float(np.percentile(xs, 50)),
                "p90": float(np.percentile(xs, 90)),
                "p99": float(np.percentile(xs, 99)),
                "std": float(xs.std()),
            }


class MetricsRegistry:
    """Name -> instrument map; instruments are created on first use."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str, sample_cap: int = 1024) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, sample_cap)
            return h

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    # -- export --------------------------------------------------------------

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {n: g.value for n, g in sorted(gauges.items())},
            "histograms": {n: h.summary() for n, h in sorted(hists.items())},
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition (counters/gauges literal; histograms
        as summaries with p50/p90/p99 quantiles)."""
        snap = self.snapshot()
        out: List[str] = []
        for name, v in snap["counters"].items():
            pn = _prom_name(name)
            out.append(f"# TYPE {pn} counter")
            out.append(f"{pn} {v:g}")
        for name, v in snap["gauges"].items():
            pn = _prom_name(name)
            out.append(f"# TYPE {pn} gauge")
            out.append(f"{pn} {v:g}")
        for name, s in snap["histograms"].items():
            pn = _prom_name(name)
            out.append(f"# TYPE {pn} summary")
            if s["count"]:
                for q, key in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99")):
                    out.append(f'{pn}{{quantile="{q}"}} {s[key]:g}')
                out.append(f"{pn}_sum {s['sum']:g}")
            out.append(f"{pn}_count {s['count']}")
        return "\n".join(out) + "\n"

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)


REGISTRY = MetricsRegistry()
