"""Summarize an observability dump:

    PYTHONPATH=src python -m repro.obs.view OBS_DUMP_DIR [--top 12]

Reads the files `obs.export_all` wrote (trace.json, metrics.json, and
optionally drift.json / compiles.json) and prints:

  * top spans — total/mean/p50/p99 wall time grouped by span name;
  * step-time percentiles — the `decode_step` spans (the engine's
    steady-state heartbeat);
  * kernel dispatch table — which impl/block config every lowered GEMM
    site chose (from the `dispatch` instant events);
  * compile table — every recorded XLA compile (key, wall time, whether
    the watchdog was armed);
  * drift table — predicted vs measured per engine program site, with the
    calibration-free `rel_drift` column (see `obs.drift`).

`render_summary` returns the same report as lines so `benchmarks/report.py`
can embed it in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
import os
from collections import defaultdict
from typing import List, Optional

import numpy as np


def _load(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs, np.float64), p))


def top_spans(trace: dict, top: int = 12) -> List[str]:
    by_name = defaultdict(list)
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "X":
            by_name[ev["name"]].append(ev["dur"])
    out = ["| span | count | total ms | mean ms | p50 ms | p99 ms |",
           "|---|---|---|---|---|---|"]
    ranked = sorted(by_name.items(), key=lambda kv: -sum(kv[1]))[:top]
    for name, durs in ranked:
        out.append(f"| {name} | {len(durs)} | {sum(durs) / 1e3:.2f} "
                   f"| {sum(durs) / len(durs) / 1e3:.3f} "
                   f"| {_pct(durs, 50) / 1e3:.3f} "
                   f"| {_pct(durs, 99) / 1e3:.3f} |")
    if len(out) == 2:
        out.append("| (no spans) | | | | | |")
    return out


def step_percentiles(trace: dict, name: str = "decode_step") -> List[str]:
    durs = [ev["dur"] for ev in trace.get("traceEvents", [])
            if ev.get("ph") == "X" and ev["name"] == name]
    if not durs:
        return [f"(no `{name}` spans)"]
    return [f"{name}: {len(durs)} steps | "
            f"p50 {_pct(durs, 50) / 1e3:.3f} ms | "
            f"p90 {_pct(durs, 90) / 1e3:.3f} ms | "
            f"p99 {_pct(durs, 99) / 1e3:.3f} ms | "
            f"mean {sum(durs) / len(durs) / 1e3:.3f} ms"]


def dispatch_table(trace: dict, top: int = 20) -> List[str]:
    rows = [ev for ev in trace.get("traceEvents", [])
            if ev.get("ph") == "i" and ev.get("cat") == "dispatch"]
    if not rows:
        return ["(no dispatch records — kernels ran with obs disabled or "
                "on the jnp path)"]
    seen = {}
    for ev in rows:
        a = ev.get("args", {})
        key = (ev["name"], a.get("impl"), tuple(a.get("shape", [])))
        if key not in seen:
            seen[key] = a
    out = ["| op | impl | shape | blocks | tuned hit |",
           "|---|---|---|---|---|"]
    for (op, impl, shape), a in list(seen.items())[:top]:
        blocks = ",".join(f"{k}={v}" for k, v in a.get("blocks", {}).items())
        out.append(f"| {op} | {impl} | {'x'.join(map(str, shape))} "
                   f"| {blocks or '-'} | {a.get('tuned_hit')} |")
    return out


def compile_table(compiles: Optional[dict], trace: dict) -> List[str]:
    recs = None
    if compiles is not None:
        recs = [(r["key"], r["wall_s"], r["armed"])
                for r in compiles.get("records", [])]
    else:  # fall back to the mirrored trace instants
        recs = [(ev["args"].get("key", "?"), ev["args"].get("wall_s", 0.0),
                 ev["args"].get("armed", False))
                for ev in trace.get("traceEvents", [])
                if ev.get("ph") == "i" and ev.get("cat") == "compile"]
    if not recs:
        return ["(no compiles recorded)"]
    out = ["| program | compile ms | armed |", "|---|---|---|"]
    for key, wall_s, armed in recs:
        flag = "**VIOLATION**" if armed else ""
        out.append(f"| {key[:90]} | {wall_s * 1e3:.1f} | {flag} |")
    n_armed = sum(1 for _, _, a in recs if a)
    out.append("")
    out.append(f"{len(recs)} compiles total, {n_armed} while armed.")
    return out


def drift_table(drift: Optional[dict]) -> List[str]:
    if not drift or not drift.get("rows"):
        return ["(no drift data — run the engine with obs enabled)"]
    out = [f"hardware model: {drift.get('hw_name', '?')}", "",
           "| site | count | predicted ms | measured p50 ms | ratio | "
           "rel drift |", "|---|---|---|---|---|---|"]
    for r in drift["rows"]:
        ratio = f"{r['ratio']:.1f}x" if r.get("ratio") else "n/a"
        rel = f"{r['rel_drift']:.2f}" if r.get("rel_drift") else "n/a"
        out.append(f"| {r['site']} | {r['count']} | {r['predicted_ms']:.3f} "
                   f"| {r['measured_p50_ms']:.3f} | {ratio} | {rel} |")
    return out


def metrics_lines(metrics: Optional[dict]) -> List[str]:
    if not metrics:
        return ["(no metrics.json)"]
    out = []
    if metrics.get("counters"):
        out.append("counters: " + ", ".join(
            f"{k}={v:g}" for k, v in metrics["counters"].items()))
    if metrics.get("gauges"):
        out.append("gauges: " + ", ".join(
            f"{k}={v:g}" for k, v in metrics["gauges"].items()))
    for name, s in (metrics.get("histograms") or {}).items():
        if s.get("count"):
            out.append(f"hist {name}: n={s['count']} mean={s['mean']:.4g} "
                       f"p50={s['p50']:.4g} p99={s['p99']:.4g} "
                       f"std={s['std']:.4g}")
        else:
            out.append(f"hist {name}: empty")
    return out or ["(empty metrics)"]


def render_summary(dump_dir: str, top: int = 12) -> List[str]:
    """The full report as markdown-ish lines (CLI prints these;
    benchmarks/report.py embeds them)."""
    trace = _load(os.path.join(dump_dir, "trace.json")) or {}
    metrics = _load(os.path.join(dump_dir, "metrics.json"))
    drift = _load(os.path.join(dump_dir, "drift.json"))
    compiles = _load(os.path.join(dump_dir, "compiles.json"))

    out = [f"# obs summary: {dump_dir}", ""]
    out += ["## Top spans", ""] + top_spans(trace, top) + [""]
    out += ["## Step time", ""] + step_percentiles(trace) + [""]
    out += ["## Kernel dispatch", ""] + dispatch_table(trace) + [""]
    out += ["## Compiles", ""] + compile_table(compiles, trace) + [""]
    out += ["## Drift (predicted vs measured)", ""] + drift_table(drift) + [""]
    out += ["## Metrics", ""] + metrics_lines(metrics)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize an obs.export_all dump directory.")
    ap.add_argument("dump_dir", help="directory written by obs.export_all")
    ap.add_argument("--top", type=int, default=12,
                    help="span rows in the top-spans table")
    args = ap.parse_args(argv)
    trace_path = os.path.join(args.dump_dir, "trace.json")
    if not os.path.exists(trace_path):
        ap.error(f"{trace_path} not found — did obs.export_all run?")
    try:
        print("\n".join(render_summary(args.dump_dir, args.top)))
    except BrokenPipeError:  # `view DIR | head` closing the pipe is fine
        os.close(1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
