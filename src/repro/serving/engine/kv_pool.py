"""Fixed pool of KV-cache slots with tile-aligned (slots, seq_max) shape.

The pool is the engine's only persistent device state: one cache pytree with
batch dim = `num_slots` and sequence depth = `seq_max`, both snapped to the
bucket lattice (`buckets.BucketPolicy`).  Requests borrow a slot for their
lifetime; prefilled single-request caches are scattered into the pool at the
slot index (donated, so the scatter is in-place on device), and a freed slot
is simply marked length-0 — the stale bytes are masked by per-slot lengths
everywhere downstream (decode masks, paged kernel) and overwritten by the
next occupant's prefill.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from ...configs.base import ModelConfig
from ...models import init_caches
from ...models.blocks import stack_plan


def _update(pool_leaf, new_leaf, slot, axis: int):
    return jax.lax.dynamic_update_slice_in_dim(
        pool_leaf, new_leaf.astype(pool_leaf.dtype), slot, axis=axis)


def _write_segment(kind: str, pool_seg, new_seg, slot):
    """Scatter one segment's single-request cache into the pool at `slot`.

    Cache leaves carry the scanned layer dim first, so batch is axis 1 —
    except the SSM states inside a hybrid superblock, which stack the
    per-superblock sub-layers ahead of batch (axis 2); mirrors
    models.blocks.init_cache_segment.
    """
    if kind == "hybrid_super":
        return {
            "ssm": jax.tree.map(lambda p, n: _update(p, n, slot, 2),
                                pool_seg["ssm"], new_seg["ssm"]),
            "shared_attn": jax.tree.map(lambda p, n: _update(p, n, slot, 1),
                                        pool_seg["shared_attn"],
                                        new_seg["shared_attn"]),
        }
    return jax.tree.map(lambda p, n: _update(p, n, slot, 1),
                        pool_seg, new_seg)


def make_slot_writer(cfg: ModelConfig):
    """jit'd (pool_caches, new_caches, slot) -> pool_caches, donating the
    pool so the scatter updates buffers in place."""
    kinds = [kind for kind, _ in stack_plan(cfg)]

    def write(pool_caches, new_caches, slot):
        return [
            _write_segment(kind, pool_seg, new_seg, slot)
            for kind, pool_seg, new_seg in zip(kinds, pool_caches, new_caches)
        ]

    return jax.jit(write, donate_argnums=(0,))


class SlotPool:
    """Host-side slot bookkeeping + the device cache pytree."""

    def __init__(self, cfg: ModelConfig, num_slots: int, seq_max: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_slots = num_slots
        self.seq_max = seq_max
        self.caches = init_caches(cfg, num_slots, seq_max, dtype)
        self.lengths = [0] * num_slots   # live kv entries per slot
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self._writer = make_slot_writer(cfg)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_active(self) -> int:
        return self.num_slots - len(self._free)

    def alloc(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def release(self, slot: int) -> None:
        self.lengths[slot] = 0
        self._free.append(slot)

    def write(self, slot: int, new_caches: Any, length: int) -> None:
        """Install a prefilled batch-1 cache pytree into `slot`."""
        self.caches = self._writer(self.caches, new_caches,
                                   jnp.asarray(slot, jnp.int32))
        self.lengths[slot] = length
