"""KV-cache pools for the serving engine: contiguous slots and paged blocks.

Two pool designs share this module:

`SlotPool` — slot = one contiguous KV region.  One cache pytree with batch
dim = `num_slots` and sequence depth = `seq_max`, both snapped to the bucket
lattice (`buckets.BucketPolicy`).  Requests borrow a slot for their
lifetime; prefilled single-request caches are scattered into the pool at the
slot index (donated, so the scatter is in-place on device), and a freed slot
is simply marked length-0 — the stale bytes are masked by per-slot lengths
everywhere downstream (decode masks, paged kernel) and overwritten by the
next occupant's prefill.

`BlockPool` + `PagedPool` — vLLM-style block-table indirection.  The KV
space is a fixed pool of physical blocks of `block_size` tokens (the block
size is a tile-lattice choice: snapped to the bucket lattice and picked from
the `paged_decode_blocktable` tuning-cache entry, exactly like a GEMM
blocking dimension).  A request's logical KV positions [j*bs, (j+1)*bs) live
in physical block `table[j]`; full prompt blocks are content-addressed
(chained SHA-256 over the token prefix) and shared across requests with
refcounts, copy-on-write on divergence, and LRU eviction of unreferenced
cached blocks under pressure.  `BlockPool` is the pure-host state machine
(what the property-based tests drive); `PagedPool` wraps it with the device
cache pytree and the jitted gather/scatter/copy programs the engine uses.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...configs.base import ModelConfig
from ...models import init_caches
from ...models.blocks import stack_plan


def _update(pool_leaf, new_leaf, slot, axis: int):
    return jax.lax.dynamic_update_slice_in_dim(
        pool_leaf, new_leaf.astype(pool_leaf.dtype), slot, axis=axis)


def _write_segment(kind: str, pool_seg, new_seg, slot):
    """Scatter one segment's single-request cache into the pool at `slot`.

    Cache leaves carry the scanned layer dim first, so batch is axis 1 —
    except the SSM states inside a hybrid superblock, which stack the
    per-superblock sub-layers ahead of batch (axis 2); mirrors
    models.blocks.init_cache_segment.
    """
    if kind == "hybrid_super":
        return {
            "ssm": jax.tree.map(lambda p, n: _update(p, n, slot, 2),
                                pool_seg["ssm"], new_seg["ssm"]),
            "shared_attn": jax.tree.map(lambda p, n: _update(p, n, slot, 1),
                                        pool_seg["shared_attn"],
                                        new_seg["shared_attn"]),
        }
    return jax.tree.map(lambda p, n: _update(p, n, slot, 1),
                        pool_seg, new_seg)


def make_slot_writer(cfg: ModelConfig):
    """jit'd (pool_caches, new_caches, slot) -> pool_caches, donating the
    pool so the scatter updates buffers in place."""
    kinds = [kind for kind, _ in stack_plan(cfg)]

    def write(pool_caches, new_caches, slot):
        return [
            _write_segment(kind, pool_seg, new_seg, slot)
            for kind, pool_seg, new_seg in zip(kinds, pool_caches, new_caches)
        ]

    return jax.jit(write, donate_argnums=(0,))


class SlotPool:
    """Host-side slot bookkeeping + the device cache pytree."""

    def __init__(self, cfg: ModelConfig, num_slots: int, seq_max: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_slots = num_slots
        self.seq_max = seq_max
        self.caches = init_caches(cfg, num_slots, seq_max, dtype)
        self.lengths = [0] * num_slots   # live kv entries per slot
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self._writer = make_slot_writer(cfg)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_active(self) -> int:
        return self.num_slots - len(self._free)

    def alloc(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def can_admit(self, prompt_len: int) -> bool:
        """A free slot always fits a validated prompt: slots are full
        seq_max-deep regions, so depth was checked at validation time."""
        return bool(self._free)

    def release(self, slot: int) -> None:
        self.lengths[slot] = 0
        self._free.append(slot)

    def write(self, slot: int, new_caches: Any, length: int) -> None:
        """Install a prefilled batch-1 cache pytree into `slot`."""
        self.caches = self._writer(self.caches, new_caches,
                                   jnp.asarray(slot, jnp.int32))
        self.lengths[slot] = length

    def advance(self, slot: int) -> None:
        """One decode token was written at position `lengths[slot]`."""
        self.lengths[slot] += 1


# --- block-table pool ----------------------------------------------------------------


class PoolExhausted(RuntimeError):
    """No free physical block and nothing evictable."""


@dataclasses.dataclass
class BlockSeq:
    """One sequence's view of the block pool: a table of physical block ids
    covering logical positions [0, length)."""
    sid: int
    table: List[int]
    length: int
    num_cached: int = 0    # leading tokens whose KV came from the prefix cache


@dataclasses.dataclass(frozen=True)
class CowCopy:
    """Device-side obligation emitted by the host state machine: block `src`
    was copy-on-write forked into `dst`; the caller must copy the KV bytes
    before the next write lands in `dst`."""
    src: int
    dst: int


class BlockPool:
    """Pure-host state machine for a fixed pool of physical KV blocks.

    Every block is in exactly one of three states:
      * free        — on `_free`, refcount 0, not content-addressed;
      * cached-free — refcount 0 but still holding a registered prefix
                      block (on the `_cached` LRU; evictable);
      * referenced  — refcount >= 1 (held by that many sequence tables).

    Full prompt blocks are registered under a chained content hash
    (sha256(parent_digest || chunk_bytes)), so an identical prefix reaching
    a block boundary maps to the same key regardless of what follows —
    the dedupe never has to compare KV bytes, only token ids.  Keys are
    purged when their block is evicted, so a map hit always points at a
    live, content-valid block.

    The class owns no device memory: `PagedPool` mirrors every transition
    onto the cache pytree (and honors the returned `CowCopy` obligations).
    The property-based suite drives this class directly.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 1 and block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.ref = [0] * num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._cached: "OrderedDict[int, bytes]" = OrderedDict()  # block -> key (LRU)
        self._hash: Dict[bytes, int] = {}        # chain key -> block
        self._block_key: Dict[int, bytes] = {}   # registered block -> chain key
        self.seqs: Dict[int, BlockSeq] = {}
        self._next_sid = 0
        self.evictions = 0

    # -- stats ---------------------------------------------------------------

    @property
    def num_free_blocks(self) -> int:
        return len(self._free)

    @property
    def num_cached_blocks(self) -> int:
        return len(self._cached)

    @property
    def num_referenced_blocks(self) -> int:
        return sum(1 for r in self.ref if r > 0)

    # -- content addressing ---------------------------------------------------

    @staticmethod
    def _chain_key(parent: Optional[bytes], chunk: Sequence[int]) -> bytes:
        h = hashlib.sha256(parent or b"root")
        h.update(np.asarray(chunk, np.int64).tobytes())
        return h.digest()

    # -- block alloc/free -----------------------------------------------------

    def _alloc_block(self) -> int:
        if self._free:
            if obs.enabled():
                obs.counter("kv.blocks_allocated").inc()
            return self._free.pop()
        if self._cached:  # evict the least-recently-used cached-free block
            blk, key = self._cached.popitem(last=False)
            del self._hash[key]
            del self._block_key[blk]
            self.evictions += 1
            if obs.enabled():
                obs.counter("kv.blocks_allocated").inc()
                obs.counter("kv.evictions").inc()
            return blk
        raise PoolExhausted(
            f"all {self.num_blocks} blocks referenced; nothing evictable")

    def _unref(self, blk: int) -> None:
        assert self.ref[blk] > 0, blk
        self.ref[blk] -= 1
        if self.ref[blk] == 0:
            key = self._block_key.get(blk)
            if key is not None:
                self._cached[blk] = key      # stays warm for future hits
                self._cached.move_to_end(blk)
            else:
                self._free.append(blk)

    def _take_cached(self, blk: int) -> None:
        """A cached-free block got a prefix hit: back to referenced."""
        self._cached.pop(blk, None)
        self.ref[blk] += 1

    # -- sequence lifecycle ---------------------------------------------------

    def alloc_sequence(self, tokens: Sequence[int], *,
                       prefix_cache: bool = True
                       ) -> Tuple[BlockSeq, List[CowCopy]]:
        """Build a block table covering `tokens` (a prompt).

        Walks the prefix cache chunk by chunk: every leading full block whose
        chain key is registered is shared (ref++) instead of allocated.  If
        the *whole* prompt is covered, the last matched block is immediately
        copy-on-write forked so the final prompt token can be recomputed into
        private storage (its logits are needed, and a shared block must never
        be written).  Fresh blocks cover the remainder.  Raises PoolExhausted
        (with every transition rolled back) if blocks run out.
        """
        tokens = [int(t) for t in tokens]
        n = len(tokens)
        assert n >= 1, "empty prompt"
        bs = self.block_size
        table: List[int] = []
        cows: List[CowCopy] = []
        matched = 0
        parent: Optional[bytes] = None
        if prefix_cache:
            while (matched + 1) * bs <= n:
                key = self._chain_key(parent, tokens[matched * bs:(matched + 1) * bs])
                blk = self._hash.get(key)
                if blk is None:
                    break
                if self.ref[blk] == 0:
                    self._take_cached(blk)
                else:
                    self.ref[blk] += 1
                table.append(blk)
                parent = key
                matched += 1
        num_cached = matched * bs
        if obs.enabled() and matched:
            obs.counter("kv.prefix_hit_blocks").inc(matched)

        def rollback():
            for blk in table:
                self._unref(blk)

        if num_cached == n:
            # full hit: recompute the last token into a private fork of the
            # tail block (COW — the shared original is never mutated)
            src = table[-1]
            try:
                dst = self._alloc_block()
            except PoolExhausted:
                rollback()
                raise
            self._unref(src)
            self.ref[dst] = 1
            table[-1] = dst
            cows.append(CowCopy(src=src, dst=dst))
            if obs.enabled():
                obs.counter("kv.cow_copies").inc()
            num_cached = n - 1
        else:
            # fresh private blocks for the uncached remainder of the prompt
            need = -(-n // bs) - len(table)   # ceil(n / bs) - shared
            for _ in range(need):
                try:
                    blk = self._alloc_block()
                except PoolExhausted:
                    rollback()
                    raise
                self.ref[blk] = 1
                table.append(blk)

        seq = BlockSeq(sid=self._next_sid, table=table, length=n,
                       num_cached=num_cached)
        self._next_sid += 1
        self.seqs[seq.sid] = seq
        return seq, cows

    def commit(self, seq: BlockSeq, tokens: Sequence[int]) -> None:
        """Register `seq`'s full blocks over `tokens` in the prefix cache
        (call after their KV content is final, i.e. post-prefill).  Keys that
        already map to a live block are left alone — first writer wins."""
        tokens = [int(t) for t in tokens]
        bs = self.block_size
        parent: Optional[bytes] = None
        for j in range(len(tokens) // bs):
            key = self._chain_key(parent, tokens[j * bs:(j + 1) * bs])
            blk = seq.table[j]
            if key not in self._hash and blk not in self._block_key:
                self._hash[key] = blk
                self._block_key[blk] = key
            parent = key

    def prepare_append(self, seq: BlockSeq) -> Optional[CowCopy]:
        """Make position `seq.length` writable: allocate a fresh tail block
        at a block boundary, or copy-on-write fork a shared tail.  Returns
        the copy obligation (None when the tail was already private)."""
        bs = self.block_size
        j = seq.length // bs
        if j == len(seq.table):           # boundary: open a new private block
            blk = self._alloc_block()
            self.ref[blk] = 1
            seq.table.append(blk)
            return None
        tgt = seq.table[j]
        if self.ref[tgt] > 1:             # shared tail: COW before writing
            dst = self._alloc_block()
            self._unref(tgt)
            self.ref[dst] = 1
            seq.table[j] = dst
            if obs.enabled():
                obs.counter("kv.cow_copies").inc()
            return CowCopy(src=tgt, dst=dst)
        if tgt in self._block_key:
            # private but registered: writing would corrupt the cache entry
            # for every future hit, so un-register it first
            del self._hash[self._block_key.pop(tgt)]
        return None

    def advance(self, seq: BlockSeq) -> None:
        """Commit one appended token (after prepare_append + the write)."""
        seq.length += 1
        assert seq.length <= len(seq.table) * self.block_size

    def fork(self, seq: BlockSeq) -> BlockSeq:
        """New sequence sharing every block (ref++); divergence later goes
        through prepare_append's COW path."""
        for blk in seq.table:
            if self.ref[blk] == 0:
                self._take_cached(blk)
            else:
                self.ref[blk] += 1
        child = BlockSeq(sid=self._next_sid, table=list(seq.table),
                         length=seq.length, num_cached=seq.num_cached)
        self._next_sid += 1
        self.seqs[child.sid] = child
        return child

    def release(self, seq: BlockSeq) -> None:
        """Drop the sequence; registered blocks stay warm (cached-free)."""
        for blk in seq.table:
            self._unref(blk)
        self.seqs.pop(seq.sid, None)

    def reserve(self, n: int) -> BlockSeq:
        """Grab up to `n` blocks as an opaque held sequence (chaos / test
        hook: simulates external memory pressure).  Takes free blocks first,
        then evicts cached-free ones; stops early — never raises — when the
        pool is fully referenced.  Release with `release(seq)`."""
        table: List[int] = []
        for _ in range(n):
            try:
                blk = self._alloc_block()
            except PoolExhausted:
                break
            self.ref[blk] = 1
            table.append(blk)
        seq = BlockSeq(sid=self._next_sid, table=table,
                       length=len(table) * self.block_size)
        self._next_sid += 1
        self.seqs[seq.sid] = seq
        return seq

    # -- invariants (test hook) ----------------------------------------------

    def check(self) -> None:
        """Assert the pool invariants the property suite locks down."""
        counts = [0] * self.num_blocks
        for seq in self.seqs.values():
            assert len(seq.table) == len(set(seq.table)), \
                f"seq {seq.sid}: duplicate physical block in table"
            assert seq.length <= len(seq.table) * self.block_size
            for blk in seq.table:
                counts[blk] += 1
        assert counts == self.ref, (counts, self.ref)
        assert all(r >= 0 for r in self.ref)
        free = set(self._free)
        cached = set(self._cached)
        referenced = {b for b, r in enumerate(self.ref) if r > 0}
        assert not (free & referenced), "block both free and referenced"
        assert not (cached & referenced), "block both cached-free and referenced"
        assert not (free & cached), "block both free and cached-free"
        assert len(free) + len(cached) + len(referenced) == self.num_blocks
        for key, blk in self._hash.items():
            assert self._block_key.get(blk) == key
        assert len(self._hash) == len(self._block_key)
        for blk in self._block_key:
            assert self.ref[blk] > 0 or blk in cached


# --- device wrapper -------------------------------------------------------------------


def _seg_map(kind: str, fn, *segs):
    """tree.map `fn` over one segment's cache leaves.  All engine-supported
    kinds (dense/moe/pair) carry pure KV leaves with batch at axis 1; the
    hybrid/ssm layouts never reach the paged pool (engine._check_supported)."""
    if kind in ("ssm", "hybrid_super"):
        raise NotImplementedError(f"paged pool: {kind} caches unsupported")
    return jax.tree.map(fn, *segs)


def make_block_programs(cfg: ModelConfig, max_blocks: int, block_size: int):
    """The three jitted device programs of the paged pool.

    gather(pool, table)            -> contiguous (1, max_blocks*bs) cache
    scatter(pool, contig, wtable)  -> pool with wtable's blocks rewritten
    copy(pool, src, dst)           -> pool with block dst := block src (COW)

    `table`/`wtable` are (max_blocks,) physical ids; entries the caller wants
    untouched point at the reserved garbage block, whose content is never
    read (per-row lengths mask it everywhere downstream).  Pools are donated
    so scatter/copy update the buffers in place.
    """
    kinds = [kind for kind, _ in stack_plan(cfg)]

    def gather(pool_caches, table):
        def one(leaf):
            # (n, nb, bs, ...) -[table]-> (n, max_nb, bs, ...) -> (n, 1, s, ...)
            g = jnp.take(leaf, table, axis=1)
            shp = g.shape
            return g.reshape(shp[0], 1, max_blocks * block_size, *shp[3:])
        return [_seg_map(k, one, seg) for k, seg in zip(kinds, pool_caches)]

    def scatter(pool_caches, contig_caches, wtable):
        def one(pool_leaf, contig_leaf):
            shp = pool_leaf.shape  # (n, nb, bs, ...)
            blocks = contig_leaf.astype(pool_leaf.dtype).reshape(
                shp[0], max_blocks, block_size, *shp[3:])
            return pool_leaf.at[:, wtable].set(
                blocks, mode="drop", unique_indices=False)
        return [_seg_map(k, one, p, c)
                for k, p, c in zip(kinds, pool_caches, contig_caches)]

    def copy(pool_caches, src, dst):
        def one(leaf):
            return leaf.at[:, dst].set(leaf[:, src])
        return [_seg_map(k, one, seg) for k, seg in zip(kinds, pool_caches)]

    return (jax.jit(gather),
            jax.jit(scatter, donate_argnums=(0,)),
            jax.jit(copy, donate_argnums=(0,)))


class PagedPool:
    """Device-facing paged KV pool: BlockPool host bookkeeping + the block
    cache pytree + a fixed lattice of decode rows.

    The decode batch stays a bucketed constant (`num_rows` — the sublane dim
    of every decode GEMM), but each row's KV now lives in `seq_max //
    block_size` physical blocks named by a block table instead of one
    contiguous slot.  Capacity is `num_rows * seq_max / block_size` blocks —
    the SlotPool byte budget — plus one reserved garbage block (device index
    `num_blocks`) that dead rows point at and nothing ever reads, so prefix
    sharing strictly adds headroom for the cached-free pool.
    """

    def __init__(self, cfg: ModelConfig, num_rows: int, seq_max: int,
                 dtype=jnp.bfloat16, *, block_size: int,
                 num_blocks: Optional[int] = None):
        assert seq_max % block_size == 0, (seq_max, block_size)
        self.cfg = cfg
        self.num_rows = num_rows
        self.seq_max = seq_max
        self.block_size = block_size
        self.max_blocks = seq_max // block_size
        nb = num_blocks or num_rows * self.max_blocks
        self.blocks = BlockPool(nb, block_size)
        self.garbage = nb                      # reserved device block id
        self.caches = init_caches(cfg, nb + 1, block_size, dtype)
        self._gather, self._scatter, self._copy = make_block_programs(
            cfg, self.max_blocks, block_size)
        self.row_seq: List[Optional[BlockSeq]] = [None] * num_rows
        self._free_rows: List[int] = list(range(num_rows - 1, -1, -1))

    # -- SlotPool-compatible row interface (Scheduler speaks this) ------------

    @property
    def num_free(self) -> int:
        return len(self._free_rows)

    @property
    def num_active(self) -> int:
        return self.num_rows - len(self._free_rows)

    @property
    def lengths(self) -> List[int]:
        return [0 if s is None else s.length for s in self.row_seq]

    def alloc(self) -> Optional[int]:
        return self._free_rows.pop() if self._free_rows else None

    def can_admit(self, prompt_len: int) -> bool:
        """Conservative admissibility: a free row AND enough allocatable
        (free + evictable cached) blocks to cover the whole prompt cold.
        Prefix hits only reduce the real need, so True here means
        `alloc_sequence` succeeds barring a concurrent COW burst (the
        engine's bounded admission retry covers that residue)."""
        if not self._free_rows:
            return False
        need = -(-max(prompt_len, 1) // self.block_size)
        bp = self.blocks
        return bp.num_free_blocks + bp.num_cached_blocks >= need

    def release(self, row: int) -> None:
        seq = self.row_seq[row]
        if seq is not None:
            self.blocks.release(seq)
            self.row_seq[row] = None
        self._free_rows.append(row)

    def advance(self, row: int) -> None:
        self.blocks.advance(self.row_seq[row])

    # -- block-table machinery ------------------------------------------------

    def _apply_cows(self, cows: List[CowCopy]) -> None:
        for cow in cows:
            self.caches = self._copy(self.caches,
                                     jnp.asarray(cow.src, jnp.int32),
                                     jnp.asarray(cow.dst, jnp.int32))

    def alloc_sequence(self, row: int, tokens: Sequence[int]) -> BlockSeq:
        """Bind a prompt to `row`: block table + prefix-cache hits, with any
        COW obligation applied on device.  seq.num_cached tokens of KV are
        already live; the engine prefills only the suffix."""
        seq, cows = self.blocks.alloc_sequence(tokens)
        self._apply_cows(cows)
        self.row_seq[row] = seq
        return seq

    def prepare_append(self, row: int) -> None:
        """Make the next decode write position of `row` physically writable
        (tail-block allocation / COW), mirroring copies on device."""
        cow = self.blocks.prepare_append(self.row_seq[row])
        if cow is not None:
            self._apply_cows([cow])

    def commit(self, row: int, tokens: Sequence[int]) -> None:
        self.blocks.commit(self.row_seq[row], tokens)

    def _padded_table(self, seq: Optional[BlockSeq]) -> List[int]:
        tab = [] if seq is None else seq.table
        return tab + [self.garbage] * (self.max_blocks - len(tab))

    def tables(self) -> np.ndarray:
        """(num_rows, max_blocks) int32 device block ids; dead rows and
        unallocated tail entries point at the garbage block."""
        return np.asarray([self._padded_table(s) for s in self.row_seq],
                          np.int32)

    def gather(self, row: int):
        """Contiguous (1, seq_max) cache view of `row` (a copy)."""
        table = jnp.asarray(self._padded_table(self.row_seq[row]), jnp.int32)
        return self._gather(self.caches, table)

    def scatter(self, row: int, contig_caches, start_block: int) -> None:
        """Write blocks [start_block:] of the contiguous view back into the
        row's physical blocks.  Earlier entries are shared prefix blocks and
        must never be touched: their write-table slots alias the garbage
        block instead."""
        seq = self.row_seq[row]
        wtable = self._padded_table(seq)
        for j in range(min(start_block, len(seq.table))):
            wtable[j] = self.garbage
        self.caches = self._scatter(self.caches, contig_caches,
                                    jnp.asarray(wtable, jnp.int32))
