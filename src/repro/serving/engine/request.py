"""Request / completion dataclasses and latency accounting for the engine.

A `Request` is what a client submits: prompt tokens, a generation budget,
sampling parameters, optional latency contracts (`deadline_s`,
`max_queue_wait_s`), and (for offline replay) an arrival time on the
engine's clock.  The engine hands back a `Completion` carrying the generated
tokens, a `finish_reason` naming how the request ended, and the per-request
latency trace the serving benchmarks aggregate: TTFT (arrival -> first
generated token) and the inter-token gaps.

Failure semantics: `Engine.run` never raises for a per-request problem.
Every submitted request gets exactly one `Completion`; the finish_reason
says what happened:

  stop                      eos_id generated (normal)
  length                    max_new_tokens generated (normal)
  rejected                  failed validation (oversized / garbage prompt,
                            prompt that can never fit the pool)
  shed                      dropped by admission control (queue depth,
                            predicted-TTFT SLO, max_queue_wait_s, or pool
                            exhaustion at admission after bounded retries)
  timeout                   deadline_s expired (tokens generated so far are
                            returned — a timeout after the first token is a
                            partial result, not an empty one)
  preempted-retry-exhausted preempted for KV backpressure more times than
                            the engine's retry budget; partial tokens
                            returned

`OK_REASONS` (stop, length) are the only reasons counted into TTFT /
inter-token percentiles; rejected and shed completions carry no tokens and
no first-token time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

FINISH_REASONS = ("stop", "length", "rejected", "shed", "timeout",
                  "preempted-retry-exhausted")
OK_REASONS = ("stop", "length")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """temperature == 0 -> greedy argmax; > 0 -> softmax sampling with a
    per-request PRNG stream (seeded by `seed`, folded with the step index,
    so outputs are reproducible regardless of slot placement)."""
    temperature: float = 0.0
    seed: int = 0


GREEDY = SamplingParams()


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    sampling: SamplingParams = GREEDY
    arrival_s: float = 0.0        # seconds on the engine clock (0 = at start)
    eos_id: Optional[int] = None
    # latency contracts (None = unbounded).  Both are relative to arrival_s:
    # deadline_s bounds total completion time (the engine returns whatever
    # tokens exist when it expires, finish_reason="timeout");
    # max_queue_wait_s bounds time spent queued before admission (exceeding
    # it sheds the request, finish_reason="shed").
    deadline_s: Optional[float] = None
    max_queue_wait_s: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.tokens))


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: List[int]             # generated tokens (first token included)
    arrival_s: float
    # engine-clock time of the first token; None when the request never
    # produced one (rejected / shed / timed out while queued)
    first_token_s: Optional[float]
    done_s: float

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    itl_s: List[float] = dataclasses.field(default_factory=list)
    # prompt tokens whose KV came from the prefix cache (block-table engine;
    # 0 on the slot pool / a cold prompt) — these skipped prefill entirely
    cached_tokens: int = 0
    finish_reason: str = "length"
    detail: str = ""              # human-readable cause for non-ok reasons
    preemptions: int = 0          # KV-backpressure preemptions survived

    @property
    def ok(self) -> bool:
        return self.finish_reason in OK_REASONS


def _pct(xs: Sequence[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), p)) if len(xs) else 0.0


def _pct_or_none(xs: Sequence[float], p: float) -> Optional[float]:
    """Percentile of a class split that may legitimately be empty (e.g. no
    cache-hit requests in the run): None, not a fake 0.0 that would read as
    'instant TTFT' in reports and comparisons."""
    return _pct(xs, p) if len(xs) else None


@dataclasses.dataclass
class EngineStats:
    """Aggregate + percentile view over a batch of completions."""
    wall_s: float
    total_generated: int
    num_requests: int
    decode_steps: int
    prefills: int
    tok_s: float
    ttft_p50_s: float
    ttft_p99_s: float
    itl_p50_s: float
    itl_p99_s: float
    # prefix-cache accounting (block-table engine; all zero on the slot pool)
    cache_hit_requests: int = 0   # requests with >= 1 cached prompt token
    cached_tokens: int = 0        # prompt tokens served from the cache
    prompt_tokens: int = 0
    cache_hit_rate: float = 0.0   # cached_tokens / prompt_tokens
    # TTFT split: cache-hit vs cold requests.  None when the class is empty
    # (no hits / no colds) — a 0.0 here would masquerade as a real latency
    ttft_hit_p50_s: Optional[float] = None
    ttft_cold_p50_s: Optional[float] = None
    # failure-class accounting (see module docstring): every request lands in
    # exactly one finish_reason bucket; goodput = ok / admitted, where
    # admitted excludes rejected and shed requests (they never held a slot)
    finish_reasons: Dict[str, int] = dataclasses.field(default_factory=dict)
    num_ok: int = 0
    num_rejected: int = 0
    num_shed: int = 0
    num_timeout: int = 0
    num_preempt_exhausted: int = 0
    preemptions: int = 0          # preemption events (not completions)
    resumes: int = 0              # preempted requests successfully resumed
    goodput: float = 1.0          # ok / admitted (1.0 when nothing admitted)

    @classmethod
    def collect(cls, completions: Sequence[Completion], wall_s: float,
                decode_steps: int = 0, prefills: int = 0,
                preemptions: int = 0, resumes: int = 0) -> "EngineStats":
        gen = sum(len(c.tokens) for c in completions)
        # latency percentiles are over requests that actually produced
        # tokens; rejected/shed completions have no first-token time
        ttfts = [c.ttft_s for c in completions if c.ttft_s is not None]
        itls = [d for c in completions for d in c.itl_s]
        cached = sum(c.cached_tokens for c in completions)
        prompt = sum(c.prompt_len for c in completions)
        hit_ttfts = [c.ttft_s for c in completions
                     if c.cached_tokens > 0 and c.ttft_s is not None]
        cold_ttfts = [c.ttft_s for c in completions
                      if c.cached_tokens == 0 and c.ttft_s is not None]
        reasons: Dict[str, int] = {}
        for c in completions:
            reasons[c.finish_reason] = reasons.get(c.finish_reason, 0) + 1
        num_ok = sum(1 for c in completions if c.ok)
        num_rejected = reasons.get("rejected", 0)
        num_shed = reasons.get("shed", 0)
        admitted = len(completions) - num_rejected - num_shed
        return cls(
            wall_s=wall_s, total_generated=gen,
            num_requests=len(completions), decode_steps=decode_steps,
            prefills=prefills,
            tok_s=gen / wall_s if wall_s > 0 else 0.0,
            ttft_p50_s=_pct(ttfts, 50), ttft_p99_s=_pct(ttfts, 99),
            itl_p50_s=_pct(itls, 50), itl_p99_s=_pct(itls, 99),
            cache_hit_requests=len(hit_ttfts), cached_tokens=cached,
            prompt_tokens=prompt,
            cache_hit_rate=cached / prompt if prompt else 0.0,
            ttft_hit_p50_s=_pct_or_none(hit_ttfts, 50),
            ttft_cold_p50_s=_pct_or_none(cold_ttfts, 50),
            finish_reasons=dict(sorted(reasons.items())),
            num_ok=num_ok, num_rejected=num_rejected, num_shed=num_shed,
            num_timeout=reasons.get("timeout", 0),
            num_preempt_exhausted=reasons.get("preempted-retry-exhausted", 0),
            preemptions=preemptions, resumes=resumes,
            goodput=num_ok / admitted if admitted > 0 else 1.0)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)
