"""Synthetic serving workloads: arrival patterns x prompt-length mixes.

Shared by `launch/serve.py --engine` and `benchmarks/serve_engine.py` so the
CLI and the benchmark replay identical request streams.  Deterministic in
the seed; arrival times are expressed in units of `step_s` (a caller-side
estimate of one decode-step wall time) so the same abstract pattern stresses
the scheduler identically across machines.

Patterns:
  * burst    — everything arrives at t=0 (queueing only)
  * uniform  — constant inter-arrival gap (steady trickle)
  * bursty   — clustered arrivals: groups land together, gaps between groups
  * longtail — uniform arrivals, but prompt lengths drawn Zipf-ish so a few
               long prompts ride among many short ones (bucket stress)
"""
from __future__ import annotations

from typing import List

import numpy as np

from .request import Request, SamplingParams

PATTERNS = ("burst", "uniform", "bursty", "longtail")


def synthetic_requests(num: int, *, pattern: str = "uniform",
                       min_prompt: int = 4, max_prompt: int = 48,
                       min_new: int = 4, max_new: int = 24,
                       vocab: int = 256, step_s: float = 0.0,
                       arrival_gap_steps: float = 1.0,
                       burst_size: int = 4,
                       temperature: float = 0.0,
                       prefix_share: float = 0.0,
                       shared_prefix_len: int = 0,
                       seed: int = 0) -> List[Request]:
    """Build `num` requests following `pattern` (see module docstring).

    prefix_share: fraction of requests that open with a common system-prompt
    prefix of `shared_prefix_len` tokens (default: half of max_prompt) —
    the realistic serving mix the prefix-cache benchmarks replay.  Sharing
    requests draw their *tail* from the usual length distribution, so
    total prompt lengths still exercise the bucket lattice; the remaining
    (1 - prefix_share) of requests are fully cold.
    """
    if pattern not in PATTERNS:
        raise ValueError(f"pattern {pattern!r}; have {PATTERNS}")
    assert 0.0 <= prefix_share <= 1.0, prefix_share
    rng = np.random.RandomState(seed)
    shared_len = 0
    shared: np.ndarray = np.zeros(0, np.int32)
    if prefix_share > 0.0:
        shared_len = shared_prefix_len or max(max_prompt // 2, 1)
        assert shared_len < max_prompt, (shared_len, max_prompt)
        shared = rng.randint(0, vocab, size=shared_len).astype(np.int32)
    reqs: List[Request] = []
    for i in range(num):
        if pattern == "longtail":
            # Zipf-flavored: mostly near min_prompt, occasional long ones
            u = rng.rand()
            plen = min_prompt + int((max_prompt - min_prompt) * u ** 3)
        else:
            plen = int(rng.randint(min_prompt, max_prompt + 1))
        gen = int(rng.randint(min_new, max_new + 1))
        if pattern == "burst":
            arrival = 0.0
        elif pattern == "bursty":
            arrival = (i // burst_size) * arrival_gap_steps * burst_size * step_s
        else:  # uniform, longtail
            arrival = i * arrival_gap_steps * step_s
        shares = prefix_share > 0.0 and rng.rand() < prefix_share
        if shares:
            tail = max(plen - shared_len, 1)
            tokens = np.concatenate(
                [shared, rng.randint(0, vocab, size=tail).astype(np.int32)])
        else:
            tokens = rng.randint(0, vocab, size=plen).astype(np.int32)
        reqs.append(Request(
            rid=i, tokens=tokens, max_new_tokens=gen,
            sampling=SamplingParams(temperature=temperature, seed=1000 + i),
            arrival_s=float(arrival)))
    return reqs
