"""Admission queue + slot scheduler for the continuous-batching engine.

Two policies share the machinery:

  * "continuous" — the engine's normal mode: any free slot is refilled the
    moment a request has arrived, so prefill and decode interleave and a
    finished sequence's slot goes straight back to work;
  * "static" — the baseline the benchmarks compare against: a new batch is
    admitted only once the pool has fully drained, i.e. classic static
    batching where early finishers leave dead slots until the whole batch
    completes (exactly the `launch/serve.py` greedy-loop behavior, expressed
    through the same engine so the comparison isolates the scheduling
    policy).

Admission control (`ShedPolicy`) rides on top of both: before a ready
request is admitted, the scheduler can *shed* it — drop it with a
finish_reason instead of letting the engine melt down under overload:

  * per-request contracts: `Request.max_queue_wait_s` (shed once queueing
    exceeds it) and `Request.deadline_s` (time out once even an immediate
    admission could no longer deliver the first token in time, using the
    advisor-calibrated decode-step time as the TTFT predictor);
  * policy-level bounds: `max_queue_depth` (newest ready requests beyond
    the bound are shed — FIFO seniority is preserved) and `ttft_slo_s`
    (shed when predicted TTFT = queue wait so far + one calibrated step
    would already violate the SLO).

Admission itself scans a bounded FIFO *lookahead window* (default 4): a
head request the pool cannot currently fit (e.g. a block-pool-filling long
prompt) no longer head-of-line-blocks admissible requests right behind it.
Within the window the earliest admissible request wins, so FIFO order is
preserved among requests that fit.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Tuple

from .kv_pool import SlotPool
from .request import Request


@dataclasses.dataclass(frozen=True)
class ShedPolicy:
    """Admission-control knobs.  The default policy sheds nothing (all
    thresholds None) but still applies the lookahead window.

    step_s is the calibrated pool decode-step time
    (`Engine.calibrate_step_s`) used as the one-step TTFT predictor; 0.0
    (uncalibrated) degrades every prediction to "queue wait so far".
    """
    max_queue_depth: Optional[int] = None   # ready requests beyond: shed
    ttft_slo_s: Optional[float] = None      # predicted TTFT beyond: shed
    step_s: float = 0.0                     # calibrated decode-step seconds
    lookahead: int = 4                      # FIFO admission window


@dataclasses.dataclass(frozen=True)
class Shed:
    """A request dropped by admission control, with the finish_reason the
    engine should stamp on its Completion."""
    req: Request
    reason: str                             # "shed" | "timeout"
    detail: str


class RequestQueue:
    """Arrival-ordered queue; `pop_ready` respects the engine clock."""

    def __init__(self, requests=()):
        self._q: List[Request] = sorted(
            requests, key=lambda r: (r.arrival_s, r.rid))

    def push(self, req: Request) -> None:
        # keep the arrival-order invariant pop_ready/next_arrival_s rely on
        bisect.insort(self._q, req, key=lambda r: (r.arrival_s, r.rid))

    def __len__(self) -> int:
        return len(self._q)

    def next_arrival_s(self) -> Optional[float]:
        return self._q[0].arrival_s if self._q else None

    def ready_count(self, now_s: float) -> int:
        """Requests whose arrival time has passed (the live queue depth —
        future replay arrivals don't count as waiting)."""
        return bisect.bisect_right(self._q, now_s, key=lambda r: r.arrival_s)

    def peek(self, i: int) -> Request:
        return self._q[i]

    def pop_index(self, i: int) -> Request:
        return self._q.pop(i)

    def pop_ready(self, now_s: float) -> Optional[Request]:
        if self._q and self._q[0].arrival_s <= now_s:
            return self._q.pop(0)
        return None

    def pop_newest_ready(self, now_s: float) -> Optional[Request]:
        """Drop the most recently arrived ready request (depth shedding
        keeps FIFO seniority: the newest arrival is the one to go)."""
        n = self.ready_count(now_s)
        return self._q.pop(n - 1) if n else None


class Scheduler:
    """Decides which queued requests enter which slots at each engine tick,
    and which get shed by admission control."""

    def __init__(self, queue: RequestQueue, pool: SlotPool,
                 policy: str = "continuous",
                 shed: Optional[ShedPolicy] = None):
        assert policy in ("continuous", "static"), policy
        self.queue = queue
        self.pool = pool
        self.policy = policy
        self.shed = shed or ShedPolicy()

    # -- admission-control verdicts -------------------------------------------

    def _verdict(self, req: Request, now_s: float
                 ) -> Optional[Tuple[str, str]]:
        """(finish_reason, detail) to drop `req` right now, or None to keep
        it.  Predicted TTFT = time already queued + one calibrated decode
        step (the earliest a first token could land if admitted this tick).
        """
        waited = now_s - req.arrival_s
        predicted_ttft = waited + self.shed.step_s
        if req.deadline_s is not None and predicted_ttft > req.deadline_s:
            return ("timeout",
                    f"deadline {req.deadline_s:.3f}s unreachable: predicted "
                    f"TTFT {predicted_ttft:.3f}s")
        if (req.max_queue_wait_s is not None
                and waited > req.max_queue_wait_s):
            return ("shed",
                    f"queued {waited:.3f}s > max_queue_wait_s "
                    f"{req.max_queue_wait_s:.3f}s")
        if (self.shed.ttft_slo_s is not None
                and predicted_ttft > self.shed.ttft_slo_s):
            return ("shed",
                    f"predicted TTFT {predicted_ttft:.3f}s > SLO "
                    f"{self.shed.ttft_slo_s:.3f}s")
        return None

    # -- the per-tick decision ------------------------------------------------

    def admissions(self, now_s: float
                   ) -> Tuple[List[Tuple[Request, int]], List[Shed]]:
        """((request, slot) pairs to prefill right now, requests shed)."""
        if self.policy == "static" and self.pool.num_active:
            return [], []
        sheds: List[Shed] = []
        # 1. expire: drop ready requests whose contract is already blown —
        #    before admission, so a doomed head never eats a slot
        i = 0
        while i < self.queue.ready_count(now_s):
            verdict = self._verdict(self.queue.peek(i), now_s)
            if verdict is None:
                i += 1
            else:
                sheds.append(Shed(self.queue.pop_index(i), *verdict))
        # 2. admit: earliest admissible request within the lookahead window
        #    (FIFO among those that fit; a too-big head doesn't block)
        out: List[Tuple[Request, int]] = []
        while self.pool.num_free:
            window = min(max(self.shed.lookahead, 1),
                         self.queue.ready_count(now_s))
            picked = None
            for j in range(window):
                if self.pool.can_admit(self.queue.peek(j).prompt_len):
                    picked = self.queue.pop_index(j)
                    break
            if picked is None:
                break
            out.append((picked, self.pool.alloc()))
        # 3. depth-shed: whatever is still ready beyond the bound goes,
        #    newest first (admission already took its share, so this only
        #    drops requests that would wait at least another tick)
        if self.shed.max_queue_depth is not None:
            while self.queue.ready_count(now_s) > self.shed.max_queue_depth:
                req = self.queue.pop_newest_ready(now_s)
                sheds.append(Shed(
                    req, "shed",
                    f"queue depth > {self.shed.max_queue_depth}"))
        return out, sheds

    @property
    def drained(self) -> bool:
        return not len(self.queue) and not self.pool.num_active
