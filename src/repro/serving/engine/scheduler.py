"""Admission queue + slot scheduler for the continuous-batching engine.

Two policies share the machinery:

  * "continuous" — the engine's normal mode: any free slot is refilled the
    moment a request has arrived, so prefill and decode interleave and a
    finished sequence's slot goes straight back to work;
  * "static" — the baseline the benchmarks compare against: a new batch is
    admitted only once the pool has fully drained, i.e. classic static
    batching where early finishers leave dead slots until the whole batch
    completes (exactly the `launch/serve.py` greedy-loop behavior, expressed
    through the same engine so the comparison isolates the scheduling
    policy).
"""
from __future__ import annotations

import bisect
from typing import List, Optional, Tuple

from .kv_pool import SlotPool
from .request import Request


class RequestQueue:
    """Arrival-ordered queue; `pop_ready` respects the engine clock."""

    def __init__(self, requests=()):
        self._q: List[Request] = sorted(
            requests, key=lambda r: (r.arrival_s, r.rid))

    def push(self, req: Request) -> None:
        # keep the arrival-order invariant pop_ready/next_arrival_s rely on
        bisect.insort(self._q, req, key=lambda r: (r.arrival_s, r.rid))

    def __len__(self) -> int:
        return len(self._q)

    def next_arrival_s(self) -> Optional[float]:
        return self._q[0].arrival_s if self._q else None

    def pop_ready(self, now_s: float) -> Optional[Request]:
        if self._q and self._q[0].arrival_s <= now_s:
            return self._q.pop(0)
        return None


class Scheduler:
    """Decides which queued requests enter which slots at each engine tick."""

    def __init__(self, queue: RequestQueue, pool: SlotPool,
                 policy: str = "continuous"):
        assert policy in ("continuous", "static"), policy
        self.queue = queue
        self.pool = pool
        self.policy = policy

    def admissions(self, now_s: float) -> List[Tuple[Request, int]]:
        """(request, slot) pairs to prefill right now."""
        if self.policy == "static" and self.pool.num_active:
            return []
        out: List[Tuple[Request, int]] = []
        while self.pool.num_free:
            req = self.queue.pop_ready(now_s)
            if req is None:
                break
            slot = self.pool.alloc()
            out.append((req, slot))
        return out

    @property
    def drained(self) -> bool:
        return not len(self.queue) and not self.pool.num_active
