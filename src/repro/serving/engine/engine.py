"""Continuous-batching serving engine over a tile-aligned KV slot pool.

One `Engine` owns: the bucket policy (shapes snapped to the hardware tile
lattice — `buckets`), a fixed `SlotPool` of KV cache slots, and a bounded
set of jitted programs:

  * one prefill program per prompt bucket — a single request, right-padded
    to the bucket, cache written at positions 0..bucket (the pad tail is
    dead weight masked by the slot length everywhere downstream);
  * ONE decode program for the whole pool — every step advances all slots
    one token with per-slot write positions (vector cache_index) and
    per-slot causal masks; dead slots ride along masked;
  * a sampling program (greedy + temperature with per-request PRNG streams).

The host loop interleaves admission (prefill into freed slots) with pool
decode steps — continuous batching.  `policy="static"` runs the same
machinery but only refills the pool once it has fully drained, which is the
static-batch baseline the benchmarks compare against.

Per-request timing (TTFT, inter-token gaps) is recorded on the engine clock
and aggregated by `request.EngineStats`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...configs.base import ModelConfig
from ...core.hardware import Hardware, get_hardware
from ...models import apply_lm, init_caches
from ...models.layers import compute_dtype
from .buckets import BucketPolicy, make_policy
from .kv_pool import PagedPool, SlotPool
from .request import Completion, EngineStats, Request
from .scheduler import RequestQueue, Scheduler


def _check_supported(cfg: ModelConfig) -> None:
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"engine v1 serves attention-based decoders (dense/moe); "
            f"got family={cfg.family!r}")
    if cfg.attn_type != "gqa":
        raise NotImplementedError("engine v1 requires attn_type='gqa' "
                                  "(MLA latent caches: future work)")
    if cfg.pos_emb != "rotary":
        raise NotImplementedError("engine v1 requires rotary positions")
    if cfg.is_encoder_decoder or cfg.num_patches:
        raise NotImplementedError("engine v1 serves text-only decoders")


def _make_prefill(cfg: ModelConfig, s_max: int):
    """(params, tokens (1, bucket), true_len) -> (logits (1, v), caches).

    Logits are gathered at the last *real* prompt position; cache entries
    past true_len hold pad garbage that per-slot lengths mask downstream.
    """

    def prefill(params, tokens, true_len):
        caches = init_caches(cfg, 1, s_max, compute_dtype(cfg.dtype))
        logits, caches, _ = apply_lm(params, tokens, cfg, caches=caches,
                                     cache_index=0)
        last = jax.lax.dynamic_slice_in_dim(logits, true_len - 1, 1, axis=1)
        return last[:, 0], caches

    return jax.jit(prefill)


def _make_decode(cfg: ModelConfig):
    """(params, tok (slots, 1), caches, pos (slots,)) -> (logits, caches).

    pos is the per-slot write position (== live kv length); the KV pool is
    donated so every step updates the cache buffers in place.
    """

    def decode(params, tok, caches, pos):
        logits, caches, _ = apply_lm(params, tok, cfg, caches=caches,
                                     cache_index=pos, decode=True)
        return logits[:, -1], caches

    return jax.jit(decode, donate_argnums=(2,))


def _make_prefix_prefill(cfg: ModelConfig):
    """Cache-backed suffix prefill for the paged engine.

    (params, tokens (1, bucket), true_len, start, contig) -> (logits, contig)

    `contig` is the row's gathered contiguous (1, seq_max) cache view:
    positions [0, start) hold live prefix-cache KV, and the suffix tokens are
    prefilled at cache_index = start (positions start..start+bucket).  A cold
    prompt is just start = 0 over a garbage view — one program covers both.
    The view is donated (updated in place, then scattered back to blocks).
    """

    def prefill(params, tokens, true_len, start, caches):
        logits, caches, _ = apply_lm(params, tokens, cfg, caches=caches,
                                     cache_index=start)
        last = jax.lax.dynamic_slice_in_dim(logits, true_len - 1, 1, axis=1)
        return last[:, 0], caches

    return jax.jit(prefill, donate_argnums=(4,))


def _make_decode_bt(cfg: ModelConfig):
    """Block-table decode: like `_make_decode` but the caches are a physical
    block pool and each row's KV is gathered through (tables, pos)."""

    def decode(params, tok, caches, pos, tables):
        logits, caches, _ = apply_lm(params, tok, cfg, caches=caches,
                                     cache_index=pos, decode=True,
                                     block_tables=tables)
        return logits[:, -1], caches

    return jax.jit(decode, donate_argnums=(2,))


def _make_sampler():
    """(logits (n, v), temps, seeds, steps) -> tokens (n,) int32.

    temperature 0 -> argmax; else categorical with key fold_in(seed, step),
    so a request's sample stream is independent of slot placement and step
    timing (reproducible across scheduling policies).
    """

    def sample(logits, temps, seeds, steps):
        greedy = jnp.argmax(logits, -1).astype(jnp.int32)

        def one(lg, t, sd, st):
            key = jax.random.fold_in(jax.random.PRNGKey(sd), st)
            return jax.random.categorical(
                key, lg / jnp.maximum(t, 1e-6)).astype(jnp.int32)

        sampled = jax.vmap(one)(logits, temps, seeds, steps)
        return jnp.where(temps > 0, sampled, greedy)

    return jax.jit(sample)


@dataclasses.dataclass
class _SlotState:
    req: Request
    generated: List[int]
    last_t_s: float            # engine-clock time of the latest token
    first_token_s: float
    itl_s: List[float]
    cached_tokens: int = 0     # prompt KV served from the prefix cache


class Engine:
    """Continuous-batching engine; see module docstring."""

    def __init__(self, params, cfg: ModelConfig, *,
                 max_batch: int = 8, max_prompt: int = 64,
                 max_new: int = 64, hw: Optional[Hardware] = None,
                 policy: Optional[BucketPolicy] = None,
                 use_paged_kernel: bool = False,
                 grow_batch: bool = False,
                 prefix_cache: bool = False,
                 block_size: Optional[int] = None,
                 kv_dtype: str = "auto"):
        _check_supported(cfg)
        if use_paged_kernel:
            cfg = dataclasses.replace(cfg, attn_impl="paged")
        from ...models.blocks import KV_DTYPES
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"unknown kv_dtype {kv_dtype!r}; valid: {list(KV_DTYPES)}")
        if kv_dtype != "auto":
            # int8 pool: k/v leaves store 1 byte/elem + f32 per-(token, head)
            # scale leaves; everything downstream (pools, prefill/decode
            # programs, paged kernels) keys off cfg.kv_dtype
            cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
        self.params = params
        self.cfg = cfg
        hw = hw or get_hardware()
        self.hw = hw
        self.drift: Optional[obs.DriftMonitor] = None
        self.policy = policy or make_policy(
            cfg, hw, max_batch=max_batch, max_prompt=max_prompt,
            max_seq=max_prompt + max_new, grow_batch=grow_batch)
        self.prefix_cache = prefix_cache
        if prefix_cache:
            bs = block_size or self._pick_block_size(hw)
            self.pool = PagedPool(cfg, self.policy.num_slots,
                                  self.policy.seq_max,
                                  compute_dtype(cfg.dtype), block_size=bs)
            # every admission is a cache-backed *suffix* prefill (a cold
            # prompt is a suffix at start=0); bucketed on the suffix length
            pf = _make_prefix_prefill(cfg)
            self._prefills = {b: pf for b in self.policy.prompt_buckets}
            self._decode = _make_decode_bt(cfg)
        else:
            self.pool = SlotPool(cfg, self.policy.num_slots,
                                 self.policy.seq_max,
                                 compute_dtype(cfg.dtype))
            self._prefills = {b: _make_prefill(cfg, self.policy.seq_max)
                              for b in self.policy.prompt_buckets}
            self._decode = _make_decode(cfg)
        self._sample = _make_sampler()
        # per-slot device-facing state (dead slots: token 0, temp 0)
        n = self.policy.num_slots
        self._last_tok = np.zeros(n, np.int32)
        self._temps = np.zeros(n, np.float32)
        self._seeds = np.zeros(n, np.int32)
        self._steps = np.zeros(n, np.int32)
        self.decode_steps = 0
        self.prefills = 0

    def _pick_block_size(self, hw: Hardware) -> int:
        """Physical KV block size: a tile-lattice choice, taken from the
        `paged_decode_blocktable_pool` tuning-cache entry for this pool
        geometry when one exists (see
        `tuning.search.autotune_paged_decode_blocktable`), else the smallest
        lattice divisor of seq_max >= 16 — fine-grained enough to share
        prefixes, still a whole number of register tiles."""
        from ...tuning.cache import lookup
        from ...tuning.candidates import bucket_steps, sublane_granule
        cfg = self.cfg
        n, s_max = self.policy.num_slots, self.policy.seq_max
        dt = jnp.dtype(compute_dtype(cfg.dtype))
        entry = lookup(
            "paged_decode_blocktable_pool",
            (n, n, s_max, cfg.num_kv_heads, cfg.num_heads, cfg.head_dim),
            dt.name, hw.name)
        if entry is not None and s_max % entry.blocks["block_size"] == 0:
            return int(entry.blocks["block_size"])
        sub = sublane_granule(hw, dt.itemsize)
        divisors = [b for b in bucket_steps(s_max, sub) if s_max % b == 0]
        for b in divisors:
            if b >= 16:
                return b
        return divisors[-1] if divisors else s_max

    def reset_stats(self) -> None:
        """Zero the step counters.  run() does this itself on entry, so the
        counters (and EngineStats) are always per-run; kept public for
        callers that read the counters between partial workloads."""
        self.decode_steps = 0
        self.prefills = 0

    def calibrate_step_s(self) -> float:
        """Warm every bucket's prefill + the pool decode program, then time
        one decode step (used to express arrival patterns in machine-relative
        units).  First run pays the compiles; the second is the timer."""
        from .request import Request as _Req
        # gen budget clamped so bucket-wide warm prompts still fit the pool;
        # distinct token fill per bucket so the prefix cache can't dedupe the
        # warm prompts — every bucket must compile its full-width (cold)
        # suffix prefill, not ride an earlier bucket's cached prefix
        warm = [_Req(rid=i, tokens=np.full(b, 1 + i, np.int32),
                     max_new_tokens=min(4, max(self.policy.seq_max - b, 1)))
                for i, b in enumerate(self.policy.prompt_buckets)]
        self.run(warm)
        _, stats = self.run(warm)
        return stats.wall_s / max(stats.decode_steps, 1)

    # -- admission -----------------------------------------------------------

    def _validate(self, req: Request) -> int:
        """Bucket lookup + depth check; raises ValueError on an inadmissible
        request.  Called before a slot is committed so a bad request can
        never leak a slot."""
        bucket = self.policy.prompt_bucket(req.prompt_len)
        if req.prompt_len + req.max_new_tokens > self.policy.seq_max:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + gen "
                f"{req.max_new_tokens} exceeds pool depth "
                f"{self.policy.seq_max}")
        return bucket

    def _admit(self, req: Request, slot: int,
               states: Dict[int, _SlotState],
               done: List[Completion]) -> None:
        try:
            bucket = self._validate(req)
        except ValueError:
            self.pool.release(slot)
            raise
        with obs.span("admit", rid=req.rid, slot=slot,
                      prompt_len=req.prompt_len, bucket=bucket):
            if self.prefix_cache:
                logits, cached = self._prefill_paged(req, slot)
            else:
                cached = 0
                padded = np.zeros((1, bucket), np.int32)
                padded[0, :req.prompt_len] = req.tokens
                with obs.span("prefill", bucket=bucket, rid=req.rid,
                              cached_tokens=0) as psp:
                    logits, caches = self._prefills[bucket](
                        self.params, jnp.asarray(padded),
                        jnp.asarray(req.prompt_len, jnp.int32))
                    if obs.enabled():
                        jax.block_until_ready(logits)
                if self.drift is not None:
                    self.drift.observe(f"prefill_{bucket}", psp.dur_s)
                self.pool.write(slot, caches, req.prompt_len)
            sp = req.sampling
            with obs.span("sample", cat="sample", batch=1):
                tok = self._sample(
                    logits, jnp.asarray([sp.temperature], jnp.float32),
                    jnp.asarray([sp.seed or req.rid], jnp.int32),
                    jnp.asarray([0], jnp.int32))
                tok0 = int(np.asarray(tok)[0])
        self.prefills += 1
        if obs.enabled():
            obs.counter("engine.prefills").inc()
            obs.counter("engine.tokens_generated").inc()
            obs.counter("engine.prompt_tokens_cached").inc(cached)
        t = self._now()
        self._last_tok[slot] = tok0
        self._temps[slot] = sp.temperature
        self._seeds[slot] = sp.seed or req.rid
        self._steps[slot] = 1
        st = _SlotState(req=req, generated=[tok0], last_t_s=t,
                        first_token_s=t, itl_s=[], cached_tokens=cached)
        if self._finished(st):
            self._complete(slot, st, states, done)
        else:
            states[slot] = st

    def _prefill_paged(self, req: Request, slot: int) -> Tuple[jax.Array, int]:
        """Paged admission: bind a block table (sharing every cached full
        prefix block), prefill only the uncached suffix, scatter the new
        blocks back, and register the prompt's full blocks for future hits.
        Returns (last-token logits (1, v), cached token count)."""
        pool: PagedPool = self.pool
        seq = pool.alloc_sequence(slot, req.tokens)
        p = seq.num_cached
        suffix = np.asarray(req.tokens[p:], np.int32)
        bucket = self.policy.prompt_bucket(len(suffix))
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :len(suffix)] = suffix
        contig = pool.gather(slot)
        with obs.span("prefill", bucket=bucket, rid=req.rid,
                      cached_tokens=p) as psp:
            logits, contig = self._prefills[bucket](
                self.params, jnp.asarray(padded),
                jnp.asarray(len(suffix), jnp.int32),
                jnp.asarray(p, jnp.int32), contig)
            if obs.enabled():
                jax.block_until_ready(logits)
        if self.drift is not None and obs.enabled():
            self.drift.observe(f"prefill_{bucket}", psp.dur_s)
        pool.scatter(slot, contig, p // pool.block_size)
        pool.commit(slot, req.tokens)
        if obs.enabled():
            obs.counter("kv.prefix_hit_tokens").inc(p)
            self._kv_gauges()
        return logits, p

    def _finished(self, st: _SlotState) -> bool:
        if len(st.generated) >= st.req.max_new_tokens:
            return True
        eos = st.req.eos_id
        return eos is not None and st.generated[-1] == eos

    def _complete(self, slot: int, st: _SlotState,
                  states: Dict[int, _SlotState],
                  done: List[Completion]) -> None:
        done.append(Completion(
            rid=st.req.rid, prompt_len=st.req.prompt_len,
            tokens=st.generated, arrival_s=st.req.arrival_s,
            first_token_s=st.first_token_s, done_s=self._now(),
            itl_s=st.itl_s, cached_tokens=st.cached_tokens))
        states.pop(slot, None)
        self._temps[slot] = 0.0
        self.pool.release(slot)
        if obs.enabled():
            obs.counter("engine.requests_completed").inc()
            obs.instant("complete", rid=st.req.rid, slot=slot,
                        tokens=len(st.generated))

    # -- main loop -----------------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _kv_gauges(self) -> None:
        """Publish pool occupancy; block-level detail on the paged pool."""
        obs.gauge("engine.live_slots").set(self.pool.num_active)
        obs.gauge("engine.free_slots").set(self.pool.num_free)
        if self.prefix_cache:
            bp = self.pool.blocks
            obs.gauge("kv.free_blocks").set(bp.num_free_blocks)
            obs.gauge("kv.cached_blocks").set(bp.num_cached_blocks)
            obs.gauge("kv.referenced_blocks").set(bp.num_referenced_blocks)

    def run(self, requests: List[Request], *,
            policy: str = "continuous") -> Tuple[List[Completion],
                                                 EngineStats]:
        """Serve `requests` to completion; returns (completions sorted by
        request id, aggregate stats).  policy="static" = drain-then-refill
        baseline (see scheduler.Scheduler)."""
        for req in requests:
            self._validate(req)  # fail fast, before any slot is committed
        self.reset_stats()  # counters (and stats) are per-run
        if obs.enabled() and self.drift is None:
            self.drift = obs.DriftMonitor.for_engine(self.cfg, self.policy,
                                                     self.hw)
        self._t0 = time.perf_counter()
        queue = RequestQueue(requests)
        sched = Scheduler(queue, self.pool, policy)
        states: Dict[int, _SlotState] = {}
        done: List[Completion] = []

        while not sched.drained:
            for req, slot in sched.admissions(self._now()):
                self._admit(req, slot, states, done)
            if obs.enabled():
                obs.gauge("engine.queue_depth").set(len(queue))
                self._kv_gauges()
            if not states:
                nxt = queue.next_arrival_s()
                if nxt is not None:
                    time.sleep(max(nxt - self._now(), 0.0) + 1e-4)
                continue
            self._step(states, done)

        wall = self._now()
        done.sort(key=lambda c: c.rid)
        return done, EngineStats.collect(done, wall,
                                         decode_steps=self.decode_steps,
                                         prefills=self.prefills)

    def _step(self, states: Dict[int, _SlotState],
              done: List[Completion]) -> None:
        """One pool-wide decode step: every live slot advances one token."""
        pos = np.asarray(self.pool.lengths, np.int32)
        with obs.span("decode_step", step=self.decode_steps,
                      live=len(states),
                      batch=self.policy.num_slots) as dsp:
            if self.prefix_cache:
                # make each live row's write position physically writable
                # (tail-block alloc / copy-on-write) before the device step
                with obs.span("prepare_append", cat="kv", live=len(states)):
                    for slot in states:
                        self.pool.prepare_append(slot)
                logits, caches = self._decode(
                    self.params, jnp.asarray(self._last_tok[:, None]),
                    self.pool.caches, jnp.asarray(pos),
                    jnp.asarray(self.pool.tables()))
            else:
                logits, caches = self._decode(
                    self.params, jnp.asarray(self._last_tok[:, None]),
                    self.pool.caches, jnp.asarray(pos))
            self.pool.caches = caches
            with obs.span("sample", cat="sample",
                          batch=self.policy.num_slots):
                toks = np.asarray(self._sample(
                    logits, jnp.asarray(self._temps),
                    jnp.asarray(self._seeds), jnp.asarray(self._steps)))
        if self.drift is not None and obs.enabled():
            self.drift.observe("decode_step", dsp.dur_s)
        if obs.enabled():
            obs.counter("engine.decode_steps").inc()
            obs.counter("engine.tokens_generated").inc(len(states))
            obs.histogram("engine.decode_step_s").observe(dsp.dur_s)
        self.decode_steps += 1
        t = self._now()
        for slot in list(states):
            st = states[slot]
            tok = int(toks[slot])
            self.pool.advance(slot)
            self._last_tok[slot] = tok
            self._steps[slot] += 1
            st.generated.append(tok)
            st.itl_s.append(t - st.last_t_s)
            st.last_t_s = t
            if self._finished(st):
                self._complete(slot, st, states, done)
