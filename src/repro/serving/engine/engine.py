"""Continuous-batching serving engine over a tile-aligned KV slot pool.

One `Engine` owns: the bucket policy (shapes snapped to the hardware tile
lattice — `buckets`), a fixed `SlotPool` of KV cache slots, and a bounded
set of jitted programs:

  * one prefill program per prompt bucket — a single request, right-padded
    to the bucket, cache written at positions 0..bucket (the pad tail is
    dead weight masked by the slot length everywhere downstream);
  * ONE decode program for the whole pool — every step advances all slots
    one token with per-slot write positions (vector cache_index) and
    per-slot causal masks; dead slots ride along masked;
  * a sampling program (greedy + temperature with per-request PRNG streams).

The host loop interleaves admission (prefill into freed slots) with pool
decode steps — continuous batching.  `policy="static"` runs the same
machinery but only refills the pool once it has fully drained, which is the
static-batch baseline the benchmarks compare against.

Failure semantics (see `request` module docstring for the finish_reason
catalog): `run()` never raises for a per-request problem.  Invalid requests
become `rejected` completions before they touch a slot; admission control
(`scheduler.ShedPolicy`) sheds under overload; per-request deadlines time
out with partial results; and KV backpressure mid-decode (block-pool
exhaustion during COW/tail growth on the paged pool) preempts the youngest
sequence with exact rollback — its full KV blocks are committed to the
prefix cache, the request re-queues, and on re-admission only the (≤ one
block) uncached tail is re-prefilled, so outputs stay token-identical.
Retries are bounded; a request that exhausts them completes as
`preempted-retry-exhausted` with whatever tokens it has.

Per-request timing (TTFT, inter-token gaps) is recorded on the engine clock
and aggregated by `request.EngineStats`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...configs.base import ModelConfig
from ...core.hardware import Hardware, get_hardware
from ...models import apply_lm, init_caches
from ...models.layers import compute_dtype
from .buckets import BucketPolicy, make_policy
from .kv_pool import PagedPool, PoolExhausted, SlotPool
from .request import Completion, EngineStats, Request
from .scheduler import RequestQueue, Scheduler, ShedPolicy


def _check_supported(cfg: ModelConfig) -> None:
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"engine v1 serves attention-based decoders (dense/moe); "
            f"got family={cfg.family!r}")
    if cfg.attn_type != "gqa":
        raise NotImplementedError("engine v1 requires attn_type='gqa' "
                                  "(MLA latent caches: future work)")
    if cfg.pos_emb != "rotary":
        raise NotImplementedError("engine v1 requires rotary positions")
    if cfg.is_encoder_decoder or cfg.num_patches:
        raise NotImplementedError("engine v1 serves text-only decoders")


def _make_prefill(cfg: ModelConfig, s_max: int):
    """(params, tokens (1, bucket), true_len) -> (logits (1, v), caches).

    Logits are gathered at the last *real* prompt position; cache entries
    past true_len hold pad garbage that per-slot lengths mask downstream.
    """

    def prefill(params, tokens, true_len):
        caches = init_caches(cfg, 1, s_max, compute_dtype(cfg.dtype))
        logits, caches, _ = apply_lm(params, tokens, cfg, caches=caches,
                                     cache_index=0)
        last = jax.lax.dynamic_slice_in_dim(logits, true_len - 1, 1, axis=1)
        return last[:, 0], caches

    return jax.jit(prefill)


def _make_decode(cfg: ModelConfig):
    """(params, tok (slots, 1), caches, pos (slots,)) -> (logits, caches).

    pos is the per-slot write position (== live kv length); the KV pool is
    donated so every step updates the cache buffers in place.
    """

    def decode(params, tok, caches, pos):
        logits, caches, _ = apply_lm(params, tok, cfg, caches=caches,
                                     cache_index=pos, decode=True)
        return logits[:, -1], caches

    return jax.jit(decode, donate_argnums=(2,))


def _make_prefix_prefill(cfg: ModelConfig):
    """Cache-backed suffix prefill for the paged engine.

    (params, tokens (1, bucket), true_len, start, contig) -> (logits, contig)

    `contig` is the row's gathered contiguous (1, seq_max) cache view:
    positions [0, start) hold live prefix-cache KV, and the suffix tokens are
    prefilled at cache_index = start (positions start..start+bucket).  A cold
    prompt is just start = 0 over a garbage view — one program covers both.
    The view is donated (updated in place, then scattered back to blocks).
    """

    def prefill(params, tokens, true_len, start, caches):
        logits, caches, _ = apply_lm(params, tokens, cfg, caches=caches,
                                     cache_index=start)
        last = jax.lax.dynamic_slice_in_dim(logits, true_len - 1, 1, axis=1)
        return last[:, 0], caches

    return jax.jit(prefill, donate_argnums=(4,))


def _make_decode_bt(cfg: ModelConfig):
    """Block-table decode: like `_make_decode` but the caches are a physical
    block pool and each row's KV is gathered through (tables, pos)."""

    def decode(params, tok, caches, pos, tables):
        logits, caches, _ = apply_lm(params, tok, cfg, caches=caches,
                                     cache_index=pos, decode=True,
                                     block_tables=tables)
        return logits[:, -1], caches

    return jax.jit(decode, donate_argnums=(2,))


def _make_sampler():
    """(logits (n, v), temps, seeds, steps) -> tokens (n,) int32.

    temperature 0 -> argmax; else categorical with key fold_in(seed, step),
    so a request's sample stream is independent of slot placement and step
    timing (reproducible across scheduling policies — and across
    preemption/resume, which re-enters the stream at the same step index).
    """

    def sample(logits, temps, seeds, steps):
        greedy = jnp.argmax(logits, -1).astype(jnp.int32)

        def one(lg, t, sd, st):
            key = jax.random.fold_in(jax.random.PRNGKey(sd), st)
            return jax.random.categorical(
                key, lg / jnp.maximum(t, 1e-6)).astype(jnp.int32)

        sampled = jax.vmap(one)(logits, temps, seeds, steps)
        return jnp.where(temps > 0, sampled, greedy)

    return jax.jit(sample)


@dataclasses.dataclass
class _SlotState:
    req: Request
    generated: List[int]
    last_t_s: float            # engine-clock time of the latest token
    first_token_s: float
    itl_s: List[float]
    cached_tokens: int = 0     # prompt KV served from the prefix cache
    preemptions: int = 0       # times this request has been preempted
    admit_seq: int = 0         # monotonic admission index (youngest = max)


@dataclasses.dataclass
class _ResumeState:
    """Rolled-back progress of a preempted request awaiting re-admission.

    `generated` are the tokens already produced; all KV up to the last full
    block was committed to the prefix cache at preemption, so re-admission
    re-prefills at most one block of tail."""
    generated: List[int]
    first_token_s: float
    last_t_s: float
    itl_s: List[float]
    cached_tokens: int
    attempts: int              # preemptions + failed re-admissions so far


class Engine:
    """Continuous-batching engine; see module docstring."""

    def __init__(self, params, cfg: ModelConfig, *,
                 max_batch: int = 8, max_prompt: int = 64,
                 max_new: int = 64, hw: Optional[Hardware] = None,
                 policy: Optional[BucketPolicy] = None,
                 use_paged_kernel: bool = False,
                 grow_batch: bool = False,
                 prefix_cache: bool = False,
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 kv_dtype: str = "auto",
                 preempt_retries: int = 4):
        _check_supported(cfg)
        if use_paged_kernel:
            cfg = dataclasses.replace(cfg, attn_impl="paged")
        from ...models.blocks import KV_DTYPES
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"unknown kv_dtype {kv_dtype!r}; valid: {list(KV_DTYPES)}")
        if kv_dtype != "auto":
            # int8 pool: k/v leaves store 1 byte/elem + f32 per-(token, head)
            # scale leaves; everything downstream (pools, prefill/decode
            # programs, paged kernels) keys off cfg.kv_dtype
            cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
        self.params = params
        self.cfg = cfg
        hw = hw or get_hardware()
        self.hw = hw
        self.drift: Optional[obs.DriftMonitor] = None
        self.policy = policy or make_policy(
            cfg, hw, max_batch=max_batch, max_prompt=max_prompt,
            max_seq=max_prompt + max_new, grow_batch=grow_batch)
        self.prefix_cache = prefix_cache
        self.preempt_retries = preempt_retries
        if prefix_cache:
            bs = block_size or self._pick_block_size(hw)
            self.pool = PagedPool(cfg, self.policy.num_slots,
                                  self.policy.seq_max,
                                  compute_dtype(cfg.dtype), block_size=bs,
                                  num_blocks=num_blocks)
            # every admission is a cache-backed *suffix* prefill (a cold
            # prompt is a suffix at start=0); bucketed on the suffix length
            pf = _make_prefix_prefill(cfg)
            self._prefills = {b: pf for b in self.policy.prompt_buckets}
            self._decode = _make_decode_bt(cfg)
        else:
            assert num_blocks is None, \
                "num_blocks applies to the prefix_cache (block-table) pool"
            self.pool = SlotPool(cfg, self.policy.num_slots,
                                 self.policy.seq_max,
                                 compute_dtype(cfg.dtype))
            self._prefills = {b: _make_prefill(cfg, self.policy.seq_max)
                              for b in self.policy.prompt_buckets}
            self._decode = _make_decode(cfg)
        self._sample = _make_sampler()
        # per-slot device-facing state (dead slots: token 0, temp 0)
        n = self.policy.num_slots
        self._last_tok = np.zeros(n, np.int32)
        self._temps = np.zeros(n, np.float32)
        self._seeds = np.zeros(n, np.int32)
        self._steps = np.zeros(n, np.int32)
        self.decode_steps = 0
        self.prefills = 0
        self.preemptions = 0
        self.resumes = 0
        self.step_s_estimate = 0.0      # set by calibrate_step_s
        self._resume: Dict[int, _ResumeState] = {}
        self._admit_attempts: Dict[int, int] = {}
        self._admit_counter = 0
        self._queue: Optional[RequestQueue] = None
        self._faults = None

    def _pick_block_size(self, hw: Hardware) -> int:
        """Physical KV block size: a tile-lattice choice, taken from the
        `paged_decode_blocktable_pool` tuning-cache entry for this pool
        geometry when one exists (see
        `tuning.search.autotune_paged_decode_blocktable`), else the smallest
        lattice divisor of seq_max >= 16 — fine-grained enough to share
        prefixes, still a whole number of register tiles."""
        from ...tuning.cache import lookup
        from ...tuning.candidates import bucket_steps, sublane_granule
        cfg = self.cfg
        n, s_max = self.policy.num_slots, self.policy.seq_max
        dt = jnp.dtype(compute_dtype(cfg.dtype))
        entry = lookup(
            "paged_decode_blocktable_pool",
            (n, n, s_max, cfg.num_kv_heads, cfg.num_heads, cfg.head_dim),
            dt.name, hw.name)
        if entry is not None and s_max % entry.blocks["block_size"] == 0:
            return int(entry.blocks["block_size"])
        sub = sublane_granule(hw, dt.itemsize)
        divisors = [b for b in bucket_steps(s_max, sub) if s_max % b == 0]
        for b in divisors:
            if b >= 16:
                return b
        return divisors[-1] if divisors else s_max

    def reset_stats(self) -> None:
        """Zero the step counters.  run() does this itself on entry, so the
        counters (and EngineStats) are always per-run; kept public for
        callers that read the counters between partial workloads."""
        self.decode_steps = 0
        self.prefills = 0
        self.preemptions = 0
        self.resumes = 0

    def calibrate_step_s(self) -> float:
        """Warm every bucket's prefill + the pool decode program, then time
        one decode step (used to express arrival patterns in machine-relative
        units, and as the TTFT predictor of `ShedPolicy`).  First run pays
        the compiles; the second is the timer."""
        from .request import Request as _Req
        # gen budget clamped so bucket-wide warm prompts still fit the pool;
        # distinct token fill per bucket so the prefix cache can't dedupe the
        # warm prompts — every bucket must compile its full-width (cold)
        # suffix prefill, not ride an earlier bucket's cached prefix
        warm = [_Req(rid=i, tokens=np.full(b, 1 + i, np.int32),
                     max_new_tokens=min(4, max(self.policy.seq_max - b, 1)))
                for i, b in enumerate(self.policy.prompt_buckets)]
        self.run(warm)
        _, stats = self.run(warm)
        self.step_s_estimate = stats.wall_s / max(stats.decode_steps, 1)
        return self.step_s_estimate

    # -- admission -----------------------------------------------------------

    def _admission_error(self, req: Request) -> Optional[str]:
        """Why `req` can never be served (None when it can).  Checked before
        a request enters the queue, so a bad request never touches a slot —
        and never takes down the batch it arrived with."""
        if req.prompt_len < 1:
            return "empty prompt"
        if req.max_new_tokens < 1:
            return f"max_new_tokens {req.max_new_tokens} < 1"
        toks = np.asarray(req.tokens)
        if not np.issubdtype(toks.dtype, np.integer):
            return f"prompt tokens must be integers, got {toks.dtype}"
        lo, hi = int(toks.min()), int(toks.max())
        if lo < 0 or hi >= self.cfg.padded_vocab_size:
            return (f"prompt token ids [{lo}, {hi}] outside "
                    f"[0, {self.cfg.padded_vocab_size})")
        try:
            self.policy.prompt_bucket(req.prompt_len)
        except ValueError as e:
            return str(e)
        if req.prompt_len + req.max_new_tokens > self.policy.seq_max:
            return (f"prompt {req.prompt_len} + gen {req.max_new_tokens} "
                    f"exceeds pool depth {self.policy.seq_max}")
        if self.prefix_cache:
            need = -(-req.prompt_len // self.pool.block_size)
            if need > self.pool.blocks.num_blocks:
                return (f"prompt needs {need} KV blocks; the pool only has "
                        f"{self.pool.blocks.num_blocks}")
        return None

    def _reject(self, req: Request, detail: str,
                done: List[Completion]) -> None:
        done.append(Completion(
            rid=req.rid, prompt_len=req.prompt_len, tokens=[],
            arrival_s=req.arrival_s, first_token_s=None, done_s=self._now(),
            finish_reason="rejected", detail=detail))
        if obs.enabled():
            obs.counter("engine.rejected").inc()
            obs.instant("reject", rid=req.rid, detail=detail)

    def _drop(self, req: Request, reason: str, detail: str,
              done: List[Completion]) -> None:
        """Finalize a request dropped before (re-)admission: shed / timeout
        from the scheduler, or a dead-end re-admission.  A preempted request
        keeps its partial tokens; its reason stays `timeout` when the
        deadline fired, else becomes `preempted-retry-exhausted` (it *was*
        being served — "shed" would misreport it as never admitted)."""
        res = self._resume.pop(req.rid, None)
        if res is None:
            done.append(Completion(
                rid=req.rid, prompt_len=req.prompt_len, tokens=[],
                arrival_s=req.arrival_s, first_token_s=None,
                done_s=self._now(), finish_reason=reason, detail=detail))
        else:
            reason = reason if reason == "timeout" else \
                "preempted-retry-exhausted"
            done.append(Completion(
                rid=req.rid, prompt_len=req.prompt_len,
                tokens=res.generated, arrival_s=req.arrival_s,
                first_token_s=res.first_token_s, done_s=self._now(),
                itl_s=res.itl_s, cached_tokens=res.cached_tokens,
                finish_reason=reason, detail=detail,
                preemptions=res.attempts))
        if obs.enabled():
            obs.counter(f"engine.{reason.split('-')[0]}").inc()
            obs.instant("drop", rid=req.rid, reason=reason, detail=detail)

    def _admit(self, req: Request, slot: int,
               states: Dict[int, _SlotState],
               done: List[Completion]) -> None:
        res = self._resume.pop(req.rid, None)
        bucket = self.policy.prompt_bucket(req.prompt_len)
        with obs.span("admit", rid=req.rid, slot=slot,
                      prompt_len=req.prompt_len, bucket=bucket,
                      resume=res is not None):
            try:
                if self.prefix_cache:
                    logits, cached = self._prefill_paged(req, slot, res)
                else:
                    cached = 0
                    padded = np.zeros((1, bucket), np.int32)
                    padded[0, :req.prompt_len] = req.tokens
                    with obs.span("prefill", bucket=bucket, rid=req.rid,
                                  cached_tokens=0) as psp:
                        logits, caches = self._prefills[bucket](
                            self.params, jnp.asarray(padded),
                            jnp.asarray(req.prompt_len, jnp.int32))
                        if obs.enabled():
                            jax.block_until_ready(logits)
                    if self.drift is not None:
                        self.drift.observe(f"prefill_{bucket}", psp.dur_s)
                    self.pool.write(slot, caches, req.prompt_len)
            except PoolExhausted as e:
                # admission raced a COW burst / held blocks: the slot is
                # returned, the request re-queued with a bounded retry budget
                self.pool.release(slot)
                self._retry_admission(req, res, f"pool exhausted: {e}", done)
                return
            except ValueError as e:
                # a resumed request whose warm blocks were evicted can
                # outgrow the prompt-bucket lattice — a dead end, not a bug
                self.pool.release(slot)
                self._drop_or_requeue_dead_end(req, res, str(e), done)
                return
            sp = req.sampling
            m = len(res.generated) if res is not None else 0
            with obs.span("sample", cat="sample", batch=1):
                tok = self._sample(
                    logits, jnp.asarray([sp.temperature], jnp.float32),
                    jnp.asarray([sp.seed or req.rid], jnp.int32),
                    jnp.asarray([m], jnp.int32))
                tok0 = int(np.asarray(tok)[0])
        self.prefills += 1
        self._admit_counter += 1
        self._admit_attempts.pop(req.rid, None)
        if obs.enabled():
            obs.counter("engine.prefills").inc()
            obs.counter("engine.tokens_generated").inc()
            obs.counter("engine.prompt_tokens_cached").inc(cached)
        t = self._now()
        self._last_tok[slot] = tok0
        self._temps[slot] = sp.temperature
        self._seeds[slot] = sp.seed or req.rid
        self._steps[slot] = m + 1
        if res is None:
            st = _SlotState(req=req, generated=[tok0], last_t_s=t,
                            first_token_s=t, itl_s=[], cached_tokens=cached,
                            admit_seq=self._admit_counter)
        else:
            # resume: sampling re-entered the request's PRNG stream at step
            # m, so the continuation is what the uninterrupted run would
            # have produced; the preemption stall lands in the ITL trace
            self.resumes += 1
            if obs.enabled():
                obs.counter("engine.resumes").inc()
            st = _SlotState(req=req, generated=res.generated + [tok0],
                            last_t_s=t, first_token_s=res.first_token_s,
                            itl_s=res.itl_s + [t - res.last_t_s],
                            cached_tokens=res.cached_tokens,
                            preemptions=res.attempts,
                            admit_seq=self._admit_counter)
        if self._finished(st):
            self._complete(slot, st, states, done)
        elif (st.req.deadline_s is not None
              and t > st.req.arrival_s + st.req.deadline_s):
            self._complete(slot, st, states, done, reason="timeout",
                           detail=f"deadline {st.req.deadline_s:.3f}s "
                                  f"expired after first token")
        else:
            states[slot] = st

    def _retry_admission(self, req: Request, res: Optional[_ResumeState],
                         detail: str, done: List[Completion]) -> None:
        attempts = (res.attempts if res is not None
                    else self._admit_attempts.get(req.rid, 0)) + 1
        if attempts > self.preempt_retries:
            if res is not None:
                self._resume[req.rid] = res   # _drop consumes it
                self._drop(req, "preempted-retry-exhausted",
                           f"{detail} ({attempts} attempts)", done)
            else:
                self._drop(req, "shed",
                           f"{detail} ({attempts} admission attempts)", done)
            return
        if res is not None:
            res.attempts = attempts
            self._resume[req.rid] = res
        else:
            self._admit_attempts[req.rid] = attempts
        self._queue.push(req)
        if obs.enabled():
            obs.counter("engine.admission_retries").inc()

    def _drop_or_requeue_dead_end(self, req: Request,
                                  res: Optional[_ResumeState], detail: str,
                                  done: List[Completion]) -> None:
        if res is not None:
            self._resume[req.rid] = res
            self._drop(req, "preempted-retry-exhausted", detail, done)
        else:
            self._reject(req, detail, done)

    def _prefill_paged(self, req: Request, slot: int,
                       res: Optional[_ResumeState]
                       ) -> Tuple[jax.Array, int]:
        """Paged admission: bind a block table (sharing every cached full
        prefix block), prefill only the uncached suffix, scatter the new
        blocks back, and register the prompt's full blocks for future hits.
        A resumed request prefills prompt + generated-so-far; its full
        blocks were committed at preemption, so the suffix is at most one
        block plus the un-advanced last token.
        Returns (last-token logits (1, v), cached token count)."""
        pool: PagedPool = self.pool
        if res is None:
            tokens = np.asarray(req.tokens, np.int32)
        else:
            tokens = np.concatenate(
                [np.asarray(req.tokens, np.int32),
                 np.asarray(res.generated, np.int32)])
        seq = pool.alloc_sequence(slot, tokens)
        p = seq.num_cached
        suffix = np.asarray(tokens[p:], np.int32)
        # a resume whose warm blocks were evicted may present a suffix wider
        # than the prompt lattice: prompt_bucket raises and _admit converts
        bucket = self.policy.prompt_bucket(len(suffix))
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :len(suffix)] = suffix
        contig = pool.gather(slot)
        with obs.span("prefill", bucket=bucket, rid=req.rid,
                      cached_tokens=p) as psp:
            logits, contig = self._prefills[bucket](
                self.params, jnp.asarray(padded),
                jnp.asarray(len(suffix), jnp.int32),
                jnp.asarray(p, jnp.int32), contig)
            if obs.enabled():
                jax.block_until_ready(logits)
        if self.drift is not None and obs.enabled():
            self.drift.observe(f"prefill_{bucket}", psp.dur_s)
        pool.scatter(slot, contig, p // pool.block_size)
        pool.commit(slot, tokens)
        if obs.enabled():
            obs.counter("kv.prefix_hit_tokens").inc(p)
            self._kv_gauges()
        cached = p if res is None else res.cached_tokens
        return logits, cached

    def _finished(self, st: _SlotState) -> bool:
        if len(st.generated) >= st.req.max_new_tokens:
            return True
        eos = st.req.eos_id
        return eos is not None and st.generated[-1] == eos

    def _complete(self, slot: int, st: _SlotState,
                  states: Dict[int, _SlotState],
                  done: List[Completion], *, reason: Optional[str] = None,
                  detail: str = "") -> None:
        if reason is None:
            eos = st.req.eos_id
            reason = ("stop" if eos is not None and st.generated
                      and st.generated[-1] == eos else "length")
        done.append(Completion(
            rid=st.req.rid, prompt_len=st.req.prompt_len,
            tokens=st.generated, arrival_s=st.req.arrival_s,
            first_token_s=st.first_token_s, done_s=self._now(),
            itl_s=st.itl_s, cached_tokens=st.cached_tokens,
            finish_reason=reason, detail=detail,
            preemptions=st.preemptions))
        states.pop(slot, None)
        self._temps[slot] = 0.0
        self.pool.release(slot)
        if obs.enabled():
            obs.counter("engine.requests_completed").inc()
            if reason == "timeout":
                obs.counter("engine.timeout").inc()
            obs.instant("complete", rid=st.req.rid, slot=slot,
                        tokens=len(st.generated), reason=reason)

    # -- preemption ----------------------------------------------------------

    def _pick_victim(self, states: Dict[int, _SlotState]) -> int:
        """Youngest live sequence (most recent admission): it has the least
        progress to roll back and the fewest tokens to re-prefill."""
        return max(states, key=lambda s: states[s].admit_seq)

    def _preempt(self, slot: int, states: Dict[int, _SlotState],
                 done: List[Completion]) -> None:
        """Exact rollback of `slot` under KV backpressure: commit every full
        block of its written KV to the prefix cache (so re-admission only
        re-prefills the tail), release the row, and re-queue the request at
        its original arrival position.  Out of retry budget -> complete as
        preempted-retry-exhausted with the tokens generated so far."""
        st = states.pop(slot)
        self.preemptions += 1
        self._temps[slot] = 0.0
        attempts = st.preemptions + 1
        if obs.enabled():
            obs.counter("engine.preemptions").inc()
            obs.instant("preempt", rid=st.req.rid, slot=slot,
                        generated=len(st.generated), attempts=attempts)
        if attempts > self.preempt_retries:
            self.pool.release(slot)
            done.append(Completion(
                rid=st.req.rid, prompt_len=st.req.prompt_len,
                tokens=st.generated, arrival_s=st.req.arrival_s,
                first_token_s=st.first_token_s, done_s=self._now(),
                itl_s=st.itl_s, cached_tokens=st.cached_tokens,
                finish_reason="preempted-retry-exhausted",
                detail=f"preempted {attempts}x; retry budget "
                       f"{self.preempt_retries}",
                preemptions=attempts))
            return
        # KV in the pool covers prompt + generated[:-1] (the newest token
        # has not been fed to decode yet); registering those full blocks is
        # what makes the rollback exact-and-cheap instead of a full refill
        written = np.concatenate(
            [np.asarray(st.req.tokens, np.int32),
             np.asarray(st.generated[:-1], np.int32)])
        self.pool.commit(slot, written)
        self.pool.release(slot)
        self._resume[st.req.rid] = _ResumeState(
            generated=st.generated, first_token_s=st.first_token_s,
            last_t_s=st.last_t_s, itl_s=st.itl_s,
            cached_tokens=st.cached_tokens, attempts=attempts)
        self._queue.push(st.req)

    # -- main loop -----------------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _kv_gauges(self) -> None:
        """Publish pool occupancy; block-level detail on the paged pool."""
        obs.gauge("engine.live_slots").set(self.pool.num_active)
        obs.gauge("engine.free_slots").set(self.pool.num_free)
        if self.prefix_cache:
            bp = self.pool.blocks
            obs.gauge("kv.free_blocks").set(bp.num_free_blocks)
            obs.gauge("kv.cached_blocks").set(bp.num_cached_blocks)
            obs.gauge("kv.referenced_blocks").set(bp.num_referenced_blocks)

    def run(self, requests: List[Request], *,
            policy: str = "continuous",
            shed: Optional[ShedPolicy] = None,
            faults=None,
            check_invariants: bool = False) -> Tuple[List[Completion],
                                                     EngineStats]:
        """Serve `requests`; returns (completions sorted by request id,
        aggregate stats).  Every request gets exactly one Completion — no
        per-request condition raises out of this loop (see module
        docstring).  policy="static" = drain-then-refill baseline;
        `shed` = admission control (scheduler.ShedPolicy); `faults` = a
        faults.FaultPlan injecting deterministic failures at step
        boundaries; check_invariants asserts the block-pool invariants
        after every decode step (chaos/CI mode)."""
        self.reset_stats()  # counters (and stats) are per-run
        self._resume = {}
        self._admit_attempts = {}
        self._admit_counter = 0
        self._faults = faults
        if faults is not None:
            faults.reset()
        if obs.enabled() and self.drift is None:
            self.drift = obs.DriftMonitor.for_engine(self.cfg, self.policy,
                                                     self.hw)
        self._t0 = time.perf_counter()
        done: List[Completion] = []
        valid: List[Request] = []
        for req in requests:
            err = self._admission_error(req)
            if err is None:
                valid.append(req)
            else:
                self._reject(req, err, done)
        queue = RequestQueue(valid)
        self._queue = queue
        sched = Scheduler(queue, self.pool, policy, shed=shed)
        states: Dict[int, _SlotState] = {}

        while not sched.drained:
            admits, sheds = sched.admissions(self._now())
            for s in sheds:
                self._drop(s.req, s.reason, s.detail, done)
            for req, slot in admits:
                self._admit(req, slot, states, done)
            if obs.enabled():
                obs.gauge("engine.queue_depth").set(len(queue))
                self._kv_gauges()
            if not states:
                if admits or sheds:
                    continue    # progress was made; re-evaluate immediately
                nxt = queue.next_arrival_s()
                now = self._now()
                if nxt is not None and nxt > now:
                    time.sleep(nxt - now + 1e-4)
                elif len(queue):
                    # ready requests, an idle pool, and still no admission:
                    # nothing left that could free capacity.  Give injected
                    # holds a chance to drain, else fail the head request
                    # rather than spin forever.
                    if faults is not None and faults.drain_holds(self):
                        continue
                    req = queue.pop_ready(now)
                    if req is not None:
                        self._drop_or_requeue_dead_end(
                            req, self._resume.pop(req.rid, None),
                            "unadmittable with an idle pool "
                            "(exceeds usable capacity)", done)
                continue
            self._step(states, done)
            if check_invariants and self.prefix_cache:
                self.pool.blocks.check()

        if faults is not None:
            faults.drain_holds(self)
        if check_invariants and self.prefix_cache:
            self.pool.blocks.check()
        self._faults = None
        self._queue = None
        wall = self._now()
        done.sort(key=lambda c: c.rid)
        return done, EngineStats.collect(done, wall,
                                         decode_steps=self.decode_steps,
                                         prefills=self.prefills,
                                         preemptions=self.preemptions,
                                         resumes=self.resumes)

    def _step(self, states: Dict[int, _SlotState],
              done: List[Completion]) -> None:
        """One pool-wide decode step: every live slot advances one token.
        On the paged pool, KV backpressure (block exhaustion while making
        write positions appendable) preempts youngest-first instead of
        raising; preempted rows ride through the step masked-dead."""
        if self._faults is not None:
            self._faults.on_step(self, self.decode_steps)
        with obs.span("decode_step", step=self.decode_steps,
                      live=len(states),
                      batch=self.policy.num_slots) as dsp:
            if self.prefix_cache:
                # make each live row's write position physically writable
                # (tail-block alloc / copy-on-write) before the device step
                with obs.span("prepare_append", cat="kv", live=len(states)):
                    for slot in list(states):
                        if slot not in states:
                            continue    # already preempted as a victim
                        while slot in states:
                            try:
                                self.pool.prepare_append(slot)
                                break
                            except PoolExhausted:
                                self._preempt(self._pick_victim(states),
                                              states, done)
                if not states:
                    return      # every row was preempted: nothing to decode
                pos = np.asarray(self.pool.lengths, np.int32)
                logits, caches = self._decode(
                    self.params, jnp.asarray(self._last_tok[:, None]),
                    self.pool.caches, jnp.asarray(pos),
                    jnp.asarray(self.pool.tables()))
            else:
                pos = np.asarray(self.pool.lengths, np.int32)
                logits, caches = self._decode(
                    self.params, jnp.asarray(self._last_tok[:, None]),
                    self.pool.caches, jnp.asarray(pos))
            self.pool.caches = caches
            with obs.span("sample", cat="sample",
                          batch=self.policy.num_slots):
                toks = np.asarray(self._sample(
                    logits, jnp.asarray(self._temps),
                    jnp.asarray(self._seeds), jnp.asarray(self._steps)))
        if self.drift is not None and obs.enabled():
            self.drift.observe("decode_step", dsp.dur_s)
        if obs.enabled():
            obs.counter("engine.decode_steps").inc()
            obs.counter("engine.tokens_generated").inc(len(states))
            obs.histogram("engine.decode_step_s").observe(dsp.dur_s)
        self.decode_steps += 1
        t = self._now()
        for slot in list(states):
            st = states[slot]
            tok = int(toks[slot])
            self.pool.advance(slot)
            self._last_tok[slot] = tok
            self._steps[slot] += 1
            st.generated.append(tok)
            st.itl_s.append(t - st.last_t_s)
            st.last_t_s = t
            if self._finished(st):
                self._complete(slot, st, states, done)
            elif (st.req.deadline_s is not None
                  and t > st.req.arrival_s + st.req.deadline_s):
                self._complete(
                    slot, st, states, done, reason="timeout",
                    detail=f"deadline {st.req.deadline_s:.3f}s expired "
                           f"after {len(st.generated)} tokens")
