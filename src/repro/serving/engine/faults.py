"""Deterministic fault injection for the serving engine (chaos harness).

A `FaultPlan` is a *seeded, replayable* schedule of failures:

  request-level corruptions (applied to the workload before submission):
    oversized_prompt    prompt longer than the pool depth -> must be rejected
    garbage_prompt      negative token ids -> must be rejected
    deadline_pressure   deadline_s = 0 -> must time out, never hold a slot

  step-indexed events (applied at decode-step boundaries via `on_step`):
    steal_blocks        BlockPool.reserve(n): simulate external memory
                        pressure by holding n physical KV blocks for
                        `hold_steps` steps (evicts warm cache, then starves
                        tail-growth -> exercises admission retry and
                        youngest-first preemption)
    cow_storm           fork every live row's block sequence (refcounts
                        jump, so each row's next append copy-on-writes) and
                        hold the forks -> block demand spikes mid-decode

The two step-level faults are *semantically transparent*: they squeeze
memory but never corrupt live KV, so every surviving request must still
produce exactly the tokens a fault-free run produces — the preemption
rollback is exact, COW preserves content, eviction only loses warmth.  Only
the request-level corruptions change outcomes, and those rids are recorded
in `affected_rids`.

`chaos_soak` runs a workload twice — fault-free baseline, then under the
plan with `BlockPool.check()` asserted after every step — and verifies:
zero exceptions escape, zero invariant violations, and for every request
NOT in `affected_rids` the chaos tokens equal the baseline tokens (prefix
thereof when the request legitimately ended early: timeout or retry budget
exhausted).  Same seed -> same plan -> same failures: a chaos run is a
regression test, not a dice roll.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np

from .request import Completion, EngineStats, Request

FAULT_KINDS = ("oversized_prompt", "garbage_prompt", "deadline_pressure",
               "steal_blocks", "cow_storm")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One step-indexed injection: at decode step `step`, do `kind`."""
    step: int
    kind: str               # "steal_blocks" | "cow_storm"
    blocks: int = 0         # steal_blocks: how many to grab


@dataclasses.dataclass
class FaultPlan:
    """A replayable fault schedule; build by hand or with `generate`."""
    seed: int = 0
    request_faults: Dict[int, str] = dataclasses.field(default_factory=dict)
    events: List[FaultEvent] = dataclasses.field(default_factory=list)
    hold_steps: int = 8     # how long stolen blocks / forks stay held

    def __post_init__(self):
        for kind in self.request_faults.values():
            assert kind in ("oversized_prompt", "garbage_prompt",
                            "deadline_pressure"), kind
        for ev in self.events:
            assert ev.kind in ("steal_blocks", "cow_storm"), ev.kind
        self._holds: List[Tuple[int, object]] = []   # (expire_step, BlockSeq)
        self._fired: Set[int] = set()

    @property
    def affected_rids(self) -> Set[int]:
        """Requests whose *outcome* the plan changes.  Step-level faults are
        excluded by design: they must not change any output."""
        return set(self.request_faults)

    @property
    def kinds_used(self) -> Set[str]:
        return (set(self.request_faults.values())
                | {ev.kind for ev in self.events})

    @classmethod
    def generate(cls, seed: int, rids: Sequence[int], *,
                 num_steps: int = 48, oversized: int = 2, garbage: int = 2,
                 deadline: int = 2, steals: int = 2, storms: int = 2,
                 steal_blocks: int = 8, hold_steps: int = 8) -> "FaultPlan":
        """Seeded plan over a workload: pick victim rids and step indices
        with an isolated PRNG, so the same (seed, rids) always yields the
        same plan."""
        rng = np.random.default_rng(seed)
        victims = rng.choice(np.asarray(sorted(rids)),
                             size=min(oversized + garbage + deadline,
                                      len(rids)),
                             replace=False).tolist()
        faults: Dict[int, str] = {}
        for kind, count in (("oversized_prompt", oversized),
                            ("garbage_prompt", garbage),
                            ("deadline_pressure", deadline)):
            for _ in range(count):
                if not victims:
                    break
                faults[int(victims.pop())] = kind
        events = []
        steps = sorted(rng.choice(np.arange(1, max(num_steps, 2)),
                                  size=min(steals + storms, num_steps - 1),
                                  replace=False).tolist())
        for i, step in enumerate(steps):
            if i < steals:
                events.append(FaultEvent(step=int(step), kind="steal_blocks",
                                         blocks=steal_blocks))
            else:
                events.append(FaultEvent(step=int(step), kind="cow_storm"))
        return cls(seed=seed, request_faults=faults, events=events,
                   hold_steps=hold_steps)

    # -- workload corruption ---------------------------------------------------

    def apply_to_requests(self, requests: Sequence[Request],
                          seq_max: int) -> List[Request]:
        """Return the workload with the planned request-level corruptions
        applied (untouched requests pass through by reference)."""
        out: List[Request] = []
        for req in requests:
            kind = self.request_faults.get(req.rid)
            if kind == "oversized_prompt":
                req = dataclasses.replace(
                    req, tokens=np.ones(2 * seq_max, np.int32))
            elif kind == "garbage_prompt":
                req = dataclasses.replace(
                    req, tokens=np.full(req.prompt_len or 1, -7, np.int32))
            elif kind == "deadline_pressure":
                req = dataclasses.replace(req, deadline_s=0.0)
            out.append(req)
        return out

    # -- engine hooks ----------------------------------------------------------

    def reset(self) -> None:
        """Called by Engine.run on entry; forget fired events and holds from
        a previous run so the same plan object replays identically."""
        self._holds = []
        self._fired = set()

    def on_step(self, engine, step: int) -> None:
        """Engine hook, called at the top of every decode step (before
        prepare_append): first expire due holds, then fire due events."""
        self._release_expired(engine, step)
        if not engine.prefix_cache:
            return              # block-level faults need the paged pool
        for i, ev in enumerate(self.events):
            if ev.step != step or i in self._fired:
                continue
            self._fired.add(i)
            if ev.kind == "steal_blocks":
                held = engine.pool.blocks.reserve(ev.blocks)
                self._holds.append((step + self.hold_steps, held))
            elif ev.kind == "cow_storm":
                # fork every live row: refcounts jump, the rows' next
                # appends all COW, and the forks pin blocks until released
                for seq in engine.pool.row_seq:
                    if seq is not None:
                        child = engine.pool.blocks.fork(seq)
                        self._holds.append((step + self.hold_steps, child))

    def _release_expired(self, engine, step: int) -> None:
        live = []
        for expire, seq in self._holds:
            if expire <= step:
                engine.pool.blocks.release(seq)
            else:
                live.append((expire, seq))
        self._holds = live

    def drain_holds(self, engine) -> bool:
        """Release every held sequence now (end of run, or the engine's
        deadlock breaker asking for capacity back).  True if anything was
        actually freed."""
        released = bool(self._holds)
        for _, seq in self._holds:
            engine.pool.blocks.release(seq)
        self._holds = []
        return released


# -- the soak driver -----------------------------------------------------------


@dataclasses.dataclass
class SoakResult:
    """Outcome of `chaos_soak`: empty `violations` == pass."""
    violations: List[str]
    baseline_stats: EngineStats
    chaos_stats: EngineStats
    chaos_completions: List[Completion]
    affected_rids: Set[int]

    @property
    def ok(self) -> bool:
        return not self.violations


def chaos_soak(engine, requests: Sequence[Request], plan: FaultPlan, *,
               shed=None) -> SoakResult:
    """Run `requests` fault-free, then under `plan` with block-pool
    invariants asserted after every step, and diff the outcomes.

    Checks (collected into `violations`, not raised, so a failing soak
    reports everything at once):
      * every submitted rid gets exactly one Completion in both runs;
      * every request NOT in plan.affected_rids is token-identical to its
        baseline when it finished ok, and a strict prefix of baseline when
        it legitimately ended early (timeout / preempted-retry-exhausted);
      * corrupted requests actually failed the way the plan intended
        (oversized/garbage -> rejected; deadline_pressure -> timeout).

    `BlockPool.check()` violations and any engine exception propagate —
    those are crashes, the exact thing the harness exists to rule out."""
    baseline, base_stats = engine.run(list(requests))
    chaos_reqs = plan.apply_to_requests(requests, engine.policy.seq_max)
    completions, stats = engine.run(chaos_reqs, shed=shed, faults=plan,
                                    check_invariants=True)
    base_by_rid = {c.rid: c for c in baseline}
    violations: List[str] = []
    want_rids = {r.rid for r in requests}
    got_rids = [c.rid for c in completions]
    if sorted(got_rids) != sorted(want_rids):
        violations.append(
            f"completion set mismatch: missing={want_rids - set(got_rids)} "
            f"extra={set(got_rids) - want_rids} dupes="
            f"{[r for r in set(got_rids) if got_rids.count(r) > 1]}")
    for c in completions:
        kind = plan.request_faults.get(c.rid)
        if kind in ("oversized_prompt", "garbage_prompt"):
            if c.finish_reason != "rejected":
                violations.append(
                    f"rid {c.rid}: {kind} finished {c.finish_reason!r}, "
                    f"expected rejected")
            continue
        if kind == "deadline_pressure":
            if c.finish_reason != "timeout":
                violations.append(
                    f"rid {c.rid}: deadline_pressure finished "
                    f"{c.finish_reason!r}, expected timeout")
            continue
        b = base_by_rid.get(c.rid)
        if b is None:
            continue            # already counted in the set mismatch
        if c.ok:
            if c.tokens != b.tokens:
                violations.append(
                    f"rid {c.rid}: tokens diverged under faults "
                    f"(chaos {c.tokens[:8]}... vs baseline "
                    f"{b.tokens[:8]}..., reason={c.finish_reason})")
        elif c.finish_reason in ("timeout", "preempted-retry-exhausted"):
            if c.tokens != b.tokens[:len(c.tokens)]:
                violations.append(
                    f"rid {c.rid}: partial tokens are not a baseline "
                    f"prefix (reason={c.finish_reason})")
        elif shed is None:
            # with no admission control, an uncorrupted request must not
            # be shed or rejected by fault side-effects alone
            violations.append(
                f"rid {c.rid}: unexpectedly finished {c.finish_reason!r} "
                f"({c.detail})")
    return SoakResult(violations=violations, baseline_stats=base_stats,
                      chaos_stats=stats, chaos_completions=completions,
                      affected_rids=plan.affected_rids)
