"""Tile-aligned bucket policy: the co-design advisor applied to serving.

The paper's thesis — shapes snapped to the hardware tile lattice run faster —
applied to the *dynamic* dimensions a serving engine controls:

  * the decode batch (pool slot count) is the sublane dim of every decode
    GEMM (b tokens x (h, ...) weights), so it is snapped to the sublane
    granule at the model dtype;
  * prompt lengths are padded up to a small lattice of sublane-aligned
    buckets, so prefill only ever lowers a bounded set of (1, bucket)
    programs instead of re-jitting per prompt length;
  * the KV pool depth (skv of every decode attention) is lane-aligned.

The lattice is *shared with the autotuner* (`tuning.candidates.bucket_steps`)
— a tuned kernel entry measured for a bucket shape is exactly the shape the
engine lowers.  `choose_batch_bucket` additionally asks the advisor's
(measurement-calibrated, via the PR-1 tuning cache) cost model whether the
next bucket up amortizes decode bandwidth enough to be worth the extra slots.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from ...configs.base import ModelConfig, ShapeConfig
from ...core.advisor import step_time
from ...core.gemm_model import MeasuredProfile
from ...core.hardware import Hardware, get_hardware
from ...core.quantization import round_up
from ...models.layers import compute_dtype
from ...tuning.candidates import bucket_steps, lane_granule, sublane_granule

# Take a bigger decode batch bucket only when the calibrated model predicts
# at least this much per-token speedup (bandwidth amortization has to pay
# for the extra slot memory + per-request latency).
GROW_THRESHOLD = 1.10


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """The engine's shape contract: every lowered program's dynamic dims
    come from this (bounded, tile-aligned) set."""

    num_slots: int                  # decode batch bucket == KV pool slots
    prompt_buckets: Tuple[int, ...]  # ascending prompt-length buckets
    seq_max: int                    # KV pool depth (max prompt + max gen)

    def prompt_bucket(self, prompt_len: int) -> int:
        """Smallest bucket that fits `prompt_len` (prompts are right-padded
        up to it; the pad tail is masked out by per-slot lengths)."""
        for b in self.prompt_buckets:
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt_len {prompt_len} exceeds the largest bucket "
            f"{self.prompt_buckets[-1]}")

    @property
    def num_programs(self) -> int:
        """Upper bound on lowered programs: one decode + one prefill per
        prompt bucket (the recompile bound the bucket lattice buys)."""
        return 1 + len(self.prompt_buckets)


def _dtype_bytes(cfg: ModelConfig) -> int:
    return jnp.dtype(compute_dtype(cfg.dtype)).itemsize


def choose_batch_bucket(cfg: ModelConfig, hw: Hardware, requested: int,
                        seq_max: int, granule: int,
                        profile: Optional[MeasuredProfile] = None) -> int:
    """Snap `requested` up to the lattice, then let the (tuning-cache
    calibrated) cost model decide whether doubling the bucket is worth it:
    decode is bandwidth-bound, so per-token time usually improves with batch
    until the token GEMMs leave the skinny regime."""
    base = round_up(max(requested, 1), granule)
    shape = ShapeConfig("engine_decode", seq_max, base, "decode")

    def per_token(b: int) -> float:
        return step_time(cfg, shape, hw, microbatch=b, profile=profile) / b

    if per_token(base) / per_token(2 * base) >= GROW_THRESHOLD:
        return 2 * base
    return base


def make_policy(cfg: ModelConfig, hw: Optional[Hardware] = None, *,
                max_batch: int = 8, max_prompt: int = 64,
                max_seq: int = 0,
                profile: Optional[MeasuredProfile] = None,
                grow_batch: bool = True) -> BucketPolicy:
    """Build the engine's bucket policy for `cfg` on `hw`.

    max_seq is the deepest KV any request may reach (prompt + generation);
    defaults to 2 * max_prompt.  `profile=None` builds one from the default
    tuning cache (graceful no-op when the cache is empty)."""
    hw = hw or get_hardware()
    db = _dtype_bytes(cfg)
    sub = sublane_granule(hw, db)
    lane = lane_granule(hw)
    max_seq = max_seq or 2 * max_prompt
    top = round_up(max_prompt, sub)
    steps = [b for b in bucket_steps(max_prompt, sub) if b <= top]
    if not steps or steps[-1] < top:
        steps.append(top)  # lattice must cover the largest allowed prompt
    # the pool must fit the padded top bucket plus the generation headroom
    # the caller asked for (a prompt of exactly `top` tokens is admissible)
    gen_headroom = max(max_seq - max_prompt, 1)
    seq_max = round_up(max(max_seq, top + gen_headroom), lane)
    if profile is None:
        profile = MeasuredProfile.from_cache(None, hw.name)
    if grow_batch:
        num_slots = choose_batch_bucket(cfg, hw, max_batch, seq_max, sub,
                                        profile)
    else:
        num_slots = round_up(max(max_batch, 1), sub)
    return BucketPolicy(num_slots=num_slots, prompt_buckets=tuple(steps),
                        seq_max=seq_max)
