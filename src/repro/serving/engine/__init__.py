"""Continuous-batching serving engine with tile-aligned bucketed KV caches.

Public surface:
  Engine                  — the serving loop (engine.py)
  Request / SamplingParams / Completion / EngineStats — request API
  FINISH_REASONS / OK_REASONS — the finish_reason catalog (request.py)
  BucketPolicy / make_policy — tile-aligned shape policy (buckets.py)
  SlotPool                — fixed KV slot pool (kv_pool.py)
  BlockPool / PagedPool   — block-table KV pool with prefix caching + COW
  ShedPolicy / Shed       — admission control / overload shedding
  FaultPlan / chaos_soak  — deterministic fault injection (faults.py)
  synthetic_requests      — workload generator shared with benchmarks
"""
from .buckets import BucketPolicy, make_policy
from .engine import Engine
from .faults import FaultEvent, FaultPlan, SoakResult, chaos_soak
from .kv_pool import BlockPool, BlockSeq, CowCopy, PagedPool, PoolExhausted, SlotPool
from .request import (FINISH_REASONS, OK_REASONS, Completion, EngineStats,
                      Request, SamplingParams)
from .scheduler import RequestQueue, Scheduler, Shed, ShedPolicy
from .workload import PATTERNS, synthetic_requests

__all__ = [
    "Engine", "Request", "SamplingParams", "Completion", "EngineStats",
    "FINISH_REASONS", "OK_REASONS",
    "BucketPolicy", "make_policy", "SlotPool", "BlockPool", "BlockSeq",
    "CowCopy", "PagedPool", "PoolExhausted", "RequestQueue", "Scheduler",
    "Shed", "ShedPolicy", "FaultEvent", "FaultPlan", "SoakResult",
    "chaos_soak", "PATTERNS", "synthetic_requests",
]
