"""Continuous-batching serving engine with tile-aligned bucketed KV caches.

Public surface:
  Engine                  — the serving loop (engine.py)
  Request / SamplingParams / Completion / EngineStats — request API
  BucketPolicy / make_policy — tile-aligned shape policy (buckets.py)
  SlotPool                — fixed KV slot pool (kv_pool.py)
  BlockPool / PagedPool   — block-table KV pool with prefix caching + COW
  synthetic_requests      — workload generator shared with benchmarks
"""
from .buckets import BucketPolicy, make_policy
from .engine import Engine
from .kv_pool import BlockPool, BlockSeq, CowCopy, PagedPool, PoolExhausted, SlotPool
from .request import Completion, EngineStats, Request, SamplingParams
from .scheduler import RequestQueue, Scheduler
from .workload import PATTERNS, synthetic_requests

__all__ = [
    "Engine", "Request", "SamplingParams", "Completion", "EngineStats",
    "BucketPolicy", "make_policy", "SlotPool", "BlockPool", "BlockSeq",
    "CowCopy", "PagedPool", "PoolExhausted", "RequestQueue", "Scheduler",
    "PATTERNS", "synthetic_requests",
]
