"""Serving: prefill + single-token decode steps with sharded caches.

`decode_step` is what the decode_32k / long_500k dry-run cells lower: one new
token against a seq_len-deep cache.  KV caches are sequence-sharded on the
`model` axis (flash-decode-style distributed softmax — see
parallel/sharding.cache_specs); SSM states are head-sharded.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import apply_lm, init_caches
from ..models.layers import compute_dtype


def make_prefill_step(cfg: ModelConfig, s_max: int):
    """prefill(params, tokens[, patch_embeds, encoder_frames]) ->
    (next_token_logits, caches)."""

    def prefill(params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        caches = init_caches(cfg, b, s_max, compute_dtype(cfg.dtype))
        logits, caches, _ = apply_lm(
            params, tokens, cfg, caches=caches, cache_index=0,
            patch_embeds=batch.get("patch_embeds"),
            encoder_frames=batch.get("encoder_frames"))
        return logits[:, -1], caches

    return prefill


def make_decode_step(cfg: ModelConfig):
    """decode(params, token, caches, index) -> (logits, new_caches).

    token: (b, 1); index: scalar int32 — the cache write position (and the
    rotary position of the new token).
    """

    def decode(params, token, caches, index, enc_out=None):
        logits, new_caches, _ = apply_lm(
            params, token, cfg, caches=caches, cache_index=index,
            decode=True, enc_out=enc_out)
        return logits[:, -1], new_caches

    return decode


def greedy_generate(params, cfg: ModelConfig, prompt: jax.Array,
                    num_tokens: int, s_max: int = 0):
    """Reference end-to-end generation loop (examples / tests)."""
    b, s0 = prompt.shape
    s_max = s_max or (s0 + num_tokens)
    prefill = jax.jit(make_prefill_step(cfg, s_max))
    # donate the caches: without it every decode step copies the whole KV
    # cache (launch/serve.py already donated; this loop had not)
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
    logits, caches = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    idx = jnp.asarray(s0, jnp.int32)
    for _ in range(num_tokens - 1):
        logits, caches = decode(params, tok, caches, idx)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
        idx = idx + 1
    return jnp.concatenate(out, axis=1)
