"""Production mesh construction.

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run driver
sets XLA_FLAGS for 512 host devices *before* any jax initialization.
"""
from __future__ import annotations

import jax

from ..configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(data=16, model=16, pod=2 if multi_pod else 1)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU-device-count tests (requires >= data*model devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
