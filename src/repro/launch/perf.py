import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimbing driver: run named treatments of a (arch x shape) cell
through the dry-run and compare the three roofline terms.

Each treatment is hypothesis -> change; the measurement is the re-lowered
HLO's roofline terms; EXPERIMENTS.md §Perf records
hypothesis/before/after/verdict.

    PYTHONPATH=src python -m repro.launch.perf --cell qwen --out perf.jsonl
"""
import argparse
import json

from .dryrun import run_cell

# treatment := (tag, cfg_patch, tc_patch, hypothesis)
CELLS = {
    # §Perf pair 1: most representative of the paper's technique
    # (GPT-family dense; memory-bound baseline from naive Table-II attention)
    "qwen": ("qwen1.5-4b", "train_4k", [
        ("baseline", {}, {},
         "paper-faithful Table II decomposition; expect memory-dominant"),
        ("blocked_attn", {"attn_impl": "blocked", "attn_block_kv": 1024}, {},
         "streaming softmax removes resident s^2 scores; memory term down"),
        ("blocked+mb4", {"attn_impl": "blocked"}, {"microbatch_per_device": 4},
         "4x fewer grad-accum rounds => 4x fewer FSDP weight re-gathers and "
         "larger GEMMs; memory & collective terms down"),
        ("blocked+mb8", {"attn_impl": "blocked"}, {"microbatch_per_device": 8},
         "push accumulation further; check for diminishing returns"),
        ("blocked+mb4+dots", {"attn_impl": "blocked"},
         {"microbatch_per_device": 4, "remat": "dots"},
         "checkpoint only matmul outputs: less recompute, compute term down"),
        ("advisor_heads", {"attn_impl": "blocked", "num_heads": 32,
                           "num_kv_heads": 32, "head_dim": 80},
         {"microbatch_per_device": 4},
         "co-design check: qwen a=20 does not divide tp=16 (shard "
         "quantization); a=32 divides but head_dim falls 128->80 "
         "(tile quantization) — measure which effect dominates"),
        ("advisor_naive", {"num_heads": 32, "num_kv_heads": 32,
                           "head_dim": 80}, {},
         "a=32 with naive attention so the s^2 census (and the Pallas "
         "flash substitution) composes with the divisibility fix"),
        ("advisor_naive+mb4", {"num_heads": 32, "num_kv_heads": 32,
                               "head_dim": 80}, {"microbatch_per_device": 4},
         "stack divisibility fix + 4x fewer FSDP gather rounds + flash "
         "kernel substitution: the beyond-paper optimized candidate"),
        ("advisor_naive+mb4+sp", {"num_heads": 32, "num_kv_heads": 32,
                                  "head_dim": 80, "seq_parallel": True},
         {"microbatch_per_device": 4},
         "Megatron sequence parallelism: residual-stream norms/adds run "
         "1/16 seq-sharded; activation memory traffic between TP blocks "
         "drops ~t-fold"),
    ]),
    # §Perf pair 2: most collective-bound (MoE + MLA + FSDP)
    "deepseek": ("deepseek-v3-671b", "train_4k", [
        ("baseline", {}, {},
         "EP dispatch + per-microbatch FSDP gathers; expect collective-dominant"),
        ("mb4", {}, {"microbatch_per_device": 4},
         "4x fewer microbatches => 4x fewer param all-gather rounds; "
         "collective term down ~proportionally"),
        ("mb4+blocked", {"attn_impl": "blocked"}, {"microbatch_per_device": 4},
         "MLA s^2 scores also memory-heavy at s=4096; memory term down"),
        ("mb4+cap1.0", {"moe_capacity_factor": 1.0},
         {"microbatch_per_device": 4},
         "tighter expert capacity: 20% less dispatch all-to-all traffic"),
        ("mb16_runner", {}, {"microbatch_per_device": 16},
         "extreme accumulation: collective floor test (activation memory "
         "would rise on real HW; dry-run bounds the collective win)"),
        ("mb4+a2a_dispatch", {"moe_dispatch": "shard_map"},
         {"microbatch_per_device": 4},
         "explicit EP schedule (shard_map): tokens are replicated over the "
         "EP axis, so dispatch is fully local and the combine is ONE bf16 "
         "psum of (t_loc, h) per layer — replaces XLA's multi-pass f32 "
         "gather/all-reduce combine (11 TB/chip measured)"),
    ]),
    # bonus serving cell: decode latency is bound by weight streaming; FSDP
    # param sharding (right for training) re-gathers weights every token
    "command_r_decode": ("command-r-plus-104b", "decode_32k", [
        ("baseline", {}, {},
         "serving with training-style FSDP params: expect per-token weight "
         "all-gathers to dominate the collective term"),
        ("tp_only_params", {}, {"serve_tp_only": True},
         "TP-only param sharding (104B bf16 / 16 = 13 GB/chip, fits without "
         "optimizer state): collectives collapse; memory term becomes the "
         "physics floor params/HBM_bw ~ 16 ms/token"),
    ]),
    # bonus cell: the most compute-bound arch — remat policy is the lever
    "nemotron": ("nemotron-4-340b", "train_4k", [
        ("baseline", {}, {},
         "full remat: fwd recomputed in bwd => ~4/3 of minimal GEMM flops"),
        ("dots", {}, {"remat": "dots"},
         "checkpoint matmul outputs only: recompute drops, compute term "
         "down ~20-25%; memory term may rise (saved dot outputs)"),
        ("dots+mb4", {}, {"remat": "dots", "microbatch_per_device": 4},
         "larger per-chip GEMMs on top"),
    ]),
    # §Perf pair 3: worst train-cell roofline fraction (tiny model on a big
    # mesh: per-shard widths fall under the 128-lane tile at tp=16)
    "whisper": ("whisper-small", "train_4k", [
        ("baseline", {}, {},
         "d_model/tp = 48 < 128 lanes: shard-quantization-bound"),
        ("blocked_attn", {"attn_impl": "blocked"}, {},
         "remove s^2 score traffic first"),
        ("no_tp", {}, {"no_tp": True},
         "advisor hidden_shard_alignment fix: drop TP entirely (params "
         "replicate over model axis; whisper is 0.24B so they fit), all TP "
         "collectives disappear, every GEMM regains full-width shards"),
        ("no_tp+blocked", {"attn_impl": "blocked"}, {"no_tp": True},
         "compose both fixes"),
        ("no_tp+blocked+mb4", {"attn_impl": "blocked"},
         {"no_tp": True, "microbatch_per_device": 4},
         "fewer accumulation rounds on top"),
        ("no_tp+naive+mb4", {}, {"no_tp": True, "microbatch_per_device": 4},
         "naive attention so the flash-kernel substitution applies on top "
         "of the no-TP fix: the beyond-paper optimized candidate"),
    ]),
}


def flash_kernel_bytes(arch: str, shape_name: str, mb: int) -> float:
    """Analytic per-chip HBM traffic of the Pallas flash kernel replacing the
    naive attention (kernels/flash_attention, block_q=128, causal): q/o
    streamed once, k/v re-read per q block over the causal half, x3 for
    fwd+bwd.  Used to report the TPU-deployed (kernel-substituted) roofline:
    the XLA twin cannot express VMEM-resident tiles, so its measured traffic
    stays ~s^2 (see EXPERIMENTS.md §Perf)."""
    from ..configs.base import SHAPES
    from ..configs.registry import get_config
    cfg = get_config(arch)
    sh_ = SHAPES[shape_name]
    if not cfg.num_heads:
        return 0.0
    tp, dp = 16, 16
    s = sh_.seq_len
    r = mb  # rows per chip per microbatch
    n_micro = max(sh_.global_batch // (dp * mb), 1)
    a_pc = max(cfg.num_heads // tp, 1)
    kv_pc = max(cfg.num_kv_heads // tp, 1)
    hd = cfg.head_dim
    head_stream = r * s * hd * 2  # one (rows, s, hd) tensor in bf16
    nqb = max(s // 128, 1)  # q blocks (kernel block_q = 128)
    per_layer = (a_pc * 2 * head_stream                 # q + o streamed once
                 + (nqb / 2) * kv_pc * 2 * head_stream)  # k+v per q block, causal half
    total = cfg.num_layers * n_micro * 3.0 * per_layer  # fwd + bwd + remat
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--out", default=None)
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    arch, shape, treatments = CELLS[args.cell]
    out_f = open(args.out, "a") if args.out else None
    for tag, cfg_patch, tc_patch, hypothesis in treatments:
        if args.only and args.only != tag:
            continue
        row = run_cell(arch, shape, False, cfg_patch=dict(cfg_patch),
                       tc_patch=dict(tc_patch), tag=tag)
        row["hypothesis"] = hypothesis
        # kernel-substituted memory term (TPU deployment view)
        if row.get("status") == "ok" and row.get("s2_bytes"):
            mb = dict(tc_patch).get("microbatch_per_device", 1)
            fb = flash_kernel_bytes(arch, shape, mb)
            sub_bytes = row["hlo_bytes"] - row["s2_bytes"] + fb
            row["flash_sub_memory_s"] = sub_bytes / 819e9
            row["flash_sub_roofline_fraction"] = (
                row["model_flops_per_chip"]
                / max(row["compute_s"], row["flash_sub_memory_s"],
                      row["collective_s"]) / 197e12)
        if out_f:
            out_f.write(json.dumps(row) + "\n")
            out_f.flush()
        brief = {k: row.get(k) for k in
                 ("tag", "status", "compute_s", "memory_s", "collective_s",
                  "dominant", "roofline_fraction", "error")}
        print(json.dumps(brief), flush=True)
    if out_f:
        out_f.close()


if __name__ == "__main__":
    main()
