"""Batched serving driver: prefill a batch of prompts, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config, get_smoke_config
from ..data.pipeline import synthetic_tokens
from ..models import init_lm
from ..serving.serve_step import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    s_max = args.prompt_len + args.gen

    prompts = jnp.asarray(synthetic_tokens(args.seed, 0, args.batch,
                                           args.prompt_len, cfg.vocab_size))
    batch = {"tokens": prompts}
    if cfg.is_encoder_decoder:
        batch["encoder_frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    prefill = jax.jit(make_prefill_step(cfg, s_max))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    idx = jnp.asarray(args.prompt_len, jnp.int32)
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, caches = decode(params, tok, caches, idx)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
        idx = idx + 1
    toks = jnp.concatenate(out, axis=1)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0

    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms")
    print(f"decode:  {args.gen - 1} steps x batch {args.batch} in "
          f"{t_decode*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):,.0f} tok/s)")
    print("sample:", np.asarray(toks[0, :16]))


if __name__ == "__main__":
    main()
