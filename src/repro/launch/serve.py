"""Serving drivers: static batch (baseline) and the continuous-batching
engine (`repro.serving.engine`).

Static batch (the PR-1 behavior, kept as the baseline):

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Continuous-batching engine — admits a synthetic request stream into a
tile-aligned KV slot pool, reporting aggregate tok/s plus per-request TTFT
and inter-token latency percentiles:

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --engine --smoke --requests 12 --arrival uniform --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..configs.registry import get_config, get_smoke_config
from ..data.pipeline import synthetic_tokens
from ..models import init_lm
from ..serving.serve_step import make_decode_step, make_prefill_step


def run_static(cfg, params, args) -> None:
    """Legacy static-batch greedy loop: one jit per (batch, s_max), slots
    idle once a sequence finishes — the baseline the engine improves on."""
    s_max = args.prompt_len + args.gen
    prompts = jnp.asarray(synthetic_tokens(args.seed, 0, args.batch,
                                           args.prompt_len, cfg.vocab_size))
    batch = {"tokens": prompts}
    if cfg.is_encoder_decoder:
        batch["encoder_frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    prefill = jax.jit(make_prefill_step(cfg, s_max))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    idx = jnp.asarray(args.prompt_len, jnp.int32)
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, caches = decode(params, tok, caches, idx)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
        idx = idx + 1
    toks = jnp.concatenate(out, axis=1)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0

    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms")
    print(f"decode:  {args.gen - 1} steps x batch {args.batch} in "
          f"{t_decode*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):,.0f} tok/s)")
    print("sample:", np.asarray(toks[0, :16]))


def parse_shed_policy(spec: str, step_s: float):
    """`--shed-policy depth=16,slo=0.25,lookahead=4` -> ShedPolicy.
    `step_s` is the calibrated decode-step time (the TTFT predictor)."""
    from ..serving.engine import ShedPolicy

    kw = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        key, _, val = part.partition("=")
        if key == "depth":
            kw["max_queue_depth"] = int(val)
        elif key == "slo":
            kw["ttft_slo_s"] = float(val)
        elif key == "lookahead":
            kw["lookahead"] = int(val)
        else:
            raise SystemExit(f"--shed-policy: unknown key {key!r} "
                             f"(valid: depth, slo, lookahead)")
    return ShedPolicy(step_s=step_s, **kw)


def run_engine(cfg, params, args) -> None:
    """Continuous-batching engine over a synthetic request stream."""
    import dataclasses

    from ..serving.engine import Engine, FaultPlan, synthetic_requests

    if args.obs_dump:
        obs.enable()
    watch = None
    if args.watchdog:
        watch = obs.CompileWatch().install()

    eng = Engine(params, cfg, max_batch=args.batch,
                 max_prompt=args.prompt_len, max_new=args.gen,
                 use_paged_kernel=args.paged, grow_batch=args.grow_batch,
                 prefix_cache=args.prefix_cache, kv_dtype=args.kv_dtype)
    pol = eng.policy
    print(f"bucket policy: {pol.num_slots} slots x {pol.seq_max} kv depth, "
          f"prompt buckets {list(pol.prompt_buckets)} "
          f"(<= {pol.num_programs} lowered programs)")

    # compile warmup + one decode-step timing, so arrival patterns are
    # expressed in machine-relative units
    step_s = eng.calibrate_step_s()
    if watch is not None:
        # every program is now compiled; steady-state serving must not re-jit
        print(f"watchdog: {len(watch.records)} compiles during warmup; "
              f"arming — any further compile fails the run")
        watch.arm()

    reqs = synthetic_requests(
        args.requests, pattern=args.arrival, min_prompt=4,
        max_prompt=args.prompt_len, min_new=max(args.gen // 4, 1),
        max_new=args.gen, vocab=cfg.vocab_size, step_s=step_s,
        temperature=args.temperature, seed=args.seed)
    if args.deadline_s is not None:
        reqs = [dataclasses.replace(r, deadline_s=args.deadline_s)
                for r in reqs]
    shed = (parse_shed_policy(args.shed_policy, step_s)
            if args.shed_policy else None)
    faults = None
    if args.chaos_seed is not None:
        faults = FaultPlan.generate(args.chaos_seed, [r.rid for r in reqs],
                                    num_steps=max(args.gen * 2, 8))
        reqs = faults.apply_to_requests(reqs, eng.policy.seq_max)
        print(f"chaos: seed {args.chaos_seed}, request faults "
              f"{faults.request_faults}, {len(faults.events)} step events")
    done, stats = eng.run(reqs, shed=shed, faults=faults,
                          check_invariants=faults is not None)

    if watch is not None:
        watch.check()
        watch.disarm()
        print("watchdog: zero unexpected compiles in steady state")
    print(f"served {stats.num_requests} requests "
          f"({stats.total_generated} tokens) in {stats.wall_s*1e3:.0f} ms "
          f"| {stats.prefills} prefills, {stats.decode_steps} decode steps")
    print(f"aggregate: {stats.tok_s:,.1f} tok/s")
    print(f"TTFT:       p50 {stats.ttft_p50_s*1e3:8.1f} ms   "
          f"p99 {stats.ttft_p99_s*1e3:8.1f} ms")
    print(f"inter-token p50 {stats.itl_p50_s*1e3:8.1f} ms   "
          f"p99 {stats.itl_p99_s*1e3:8.1f} ms")
    if stats.num_ok != stats.num_requests:
        parts = "  ".join(f"{k}={v}" for k, v in stats.finish_reasons.items())
        print(f"outcomes:   {parts}  | goodput {stats.goodput:.3f} "
              f"(preemptions {stats.preemptions}, resumes {stats.resumes})")
    first_ok = next((c for c in done if c.ok), None)
    if first_ok is not None:
        print("sample:", first_ok.tokens[:16])

    if args.obs_dump:
        paths = obs.export_all(args.obs_dump, drift=eng.drift, watch=watch)
        print(f"obs dump: {sorted(paths.values())}")
        print(f"summarize with: python -m repro.obs.view {args.obs_dump}")
    if watch is not None:
        watch.uninstall()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine instead of the static "
                         "batch loop")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # engine-only knobs
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--arrival", default="uniform",
                    choices=("burst", "uniform", "bursty", "longtail"))
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--paged", action="store_true",
                    help="decode attention via the Pallas paged kernel")
    ap.add_argument("--kv-dtype", default="auto", choices=["auto", "int8"],
                    help="KV-cache storage dtype: int8 halves pool bytes "
                         "(vs bf16) with per-(token, head) f32 scales")
    ap.add_argument("--grow-batch", action="store_true",
                    help="let the advisor grow the slot bucket when the "
                         "calibrated model predicts enough amortization")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="block-table KV pool with content-addressed prefix "
                         "sharing")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request completion deadline in seconds; "
                         "expiry returns the partial result as "
                         "finish_reason=timeout")
    ap.add_argument("--shed-policy", default=None, metavar="SPEC",
                    help="admission control, e.g. 'depth=16,slo=0.25"
                         "[,lookahead=4]': shed beyond a ready-queue depth "
                         "and/or a predicted-TTFT SLO")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="inject a seeded FaultPlan (bad prompts, deadline "
                         "pressure, block steals, COW storms) and assert "
                         "pool invariants every step")
    ap.add_argument("--obs-dump", default=None, metavar="DIR",
                    help="enable observability and write trace/metrics/drift "
                         "dumps to DIR (see `python -m repro.obs.view DIR`)")
    ap.add_argument("--watchdog", action="store_true",
                    help="record every XLA compile, arm after calibration, "
                         "and FAIL on any steady-state recompile")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    if args.engine:
        run_engine(cfg, params, args)
    else:
        run_static(cfg, params, args)


if __name__ == "__main__":
    main()
