"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero allocation.  This is what the dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig, TrainConfig
from ..data.pipeline import batch_shapes
from ..models import init_lm, init_caches
from ..models.layers import compute_dtype
from ..optim.adamw import init_opt


def param_structs(cfg: ModelConfig, dtype=None) -> Any:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    shapes = jax.eval_shape(functools.partial(init_lm, cfg=cfg),
                            jax.random.PRNGKey(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype), shapes)
    return shapes


def opt_structs(cfg: ModelConfig, tc: TrainConfig) -> Any:
    params = param_structs(cfg)
    return jax.eval_shape(functools.partial(init_opt, tc=tc), params)


def cache_structs(cfg: ModelConfig, batch: int, s_max: int) -> Any:
    return jax.eval_shape(
        lambda: init_caches(cfg, batch, s_max, compute_dtype(cfg.dtype)))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one cell: train/prefill get the full batch; decode
    gets (token, caches, index)."""
    if shape.mode in ("train", "prefill"):
        return batch_shapes(cfg, shape)
    b = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "caches": cache_structs(cfg, b, shape.seq_len),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }
