import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract the roofline terms.

The two lines above MUST run before any jax import (jax locks the device
count at first init); 512 placeholder host devices back the 2x16x16 mesh.

Per cell this driver:
  1. builds the production mesh (16x16 or 2x16x16),
  2. builds ShapeDtypeStruct stand-ins for params / optimizer / batch / caches,
  3. jit-lowers train_step (train cells) or prefill/decode steps (serving
     cells) with explicit in/out shardings,
  4. .lower().compile() — any sharding mismatch / unsupported collective /
     compile-OOM here is a bug in the system,
  5. records memory_analysis(), cost_analysis(), and the HLO collective
     byte census into a JSON row for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k [--multipod]
  python -m repro.launch.dryrun --all [--multipod] --out results.jsonl
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig, SHAPES, TrainConfig
from ..configs.registry import get_config
from ..core import advisor, hlo_analysis, roofline
from ..core.hardware import get_hardware
from ..launch.input_specs import input_specs, opt_structs, param_structs
from ..launch.mesh import make_production_mesh, production_mesh_config
from ..optim.adamw import OptState
from ..parallel import sharding as sh
from ..serving.serve_step import make_decode_step, make_prefill_step
from ..train.train_step import make_train_step

ASSIGNED = [
    "zamba2-2.7b", "qwen1.5-4b", "nemotron-4-340b", "internlm2-1.8b",
    "command-r-plus-104b", "deepseek-v3-671b", "llama4-maverick-400b-a17b",
    "internvl2-76b", "whisper-small", "mamba2-780m",
]

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def cell_supported(cfg: ModelConfig, shape: ShapeConfig):
    """(runnable, reason-if-skipped) — skips documented in DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: O(s^2) at 524k skipped per task spec"
    return True, ""


def _train_config(cfg: ModelConfig) -> TrainConfig:
    big = cfg.param_count() > 60e9
    return TrainConfig(optimizer="adamw8bit" if big else "adamw",
                       remat="full", microbatch_per_device=1)


def _fix_small_batch(spec_tree, gb: int, mesh):
    """b < dp (long_500k b=1): strip the batch-axis sharding."""
    dp_names = {"pod", "data"}

    def fix(p):
        if not isinstance(p, P):
            return p
        parts = []
        for e in p:
            if e in dp_names or (isinstance(e, tuple) and set(e) & dp_names):
                parts.append(None)
            else:
                parts.append(e)
        return P(*parts)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def _opt_specs(opt_struct: OptState, cfg, mesh, tc: TrainConfig):
    if tc.optimizer == "adamw8bit":
        # shape-preserving int8 state: codes take the parameter's spec,
        # the per-row scale takes it minus the last axis (ZeRO-compatible —
        # see optim/adamw.py docstring for the mis-sharding we measured)
        def q_specs(quant_tree):
            codes = jax.tree.map(lambda d: d["codes"], quant_tree,
                                 is_leaf=lambda x: isinstance(x, dict)
                                 and "codes" in x)
            cspecs = sh.param_specs(codes, cfg, mesh)
            return jax.tree.map(
                lambda spec: {"codes": spec,
                              "scale": P(*tuple(spec)[:-1], None)
                              if len(spec) else P()},
                cspecs, is_leaf=lambda x: isinstance(x, P))
        m = q_specs(opt_struct.m)
        v = q_specs(opt_struct.v)
    else:
        m = sh.param_specs(opt_struct.m, cfg, mesh)
        v = sh.param_specs(opt_struct.v, cfg, mesh)
    return OptState(P(), m, v)


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_patch: dict | None = None, tc_patch: dict | None = None):
    """Build + lower one cell.  Returns (lowered, meta dict).

    cfg_patch / tc_patch: dataclasses.replace overrides — the §Perf hillclimb
    hook (e.g. {"attn_impl": "blocked"} or {"microbatch_per_device": 4}).
    """
    cfg = get_config(arch)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_cfg = production_mesh_config(multi_pod=multi_pod)
    sh.set_activation_context(("pod", "data") if multi_pod else ("data",),
                              mesh=mesh)
    warnings = [f"{f.rule}: {f.message}"
                for f in advisor.check_alignment(cfg, tp=mesh_cfg.model,
                                                 global_batch=shape.global_batch)
                if f.severity != "ok"]
    warnings += sh.validate_divisibility(cfg, mesh_cfg, shape.global_batch)

    dp = mesh_cfg.dp
    small_batch = shape.global_batch < dp

    no_tp = bool(tc_patch.pop("no_tp", False)) if tc_patch else False
    if no_tp:
        # re-purpose the model axis as extra data parallelism (pure DP):
        # weights replicate over `model`, batch shards over BOTH axes.
        # Leaving the model axis idle would replicate compute 16x (measured
        # — EXPERIMENTS.md §Perf whisper no_tp v1).
        dp = mesh_cfg.num_devices
        dp_axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        sh.set_activation_context(dp_axes)
        small_batch = shape.global_batch < dp

    if shape.mode == "train":
        tc = _train_config(cfg)
        if tc_patch:
            tc = dataclasses.replace(tc, **tc_patch)
        n_micro = max(shape.global_batch // (dp * tc.microbatch_per_device), 1) \
            if not small_batch else 1
        pspecs = sh.param_specs(param_structs(cfg), cfg, mesh)
        ostructs = opt_structs(cfg, tc)
        ospecs = _opt_specs(ostructs, cfg, mesh, tc)
        if no_tp:
            pspecs = sh.strip_axis(pspecs, "model")
            ospecs = OptState(ospecs.step, sh.strip_axis(ospecs.m, "model"),
                              sh.strip_axis(ospecs.v, "model"))
        bspecs = {k: v for k, v in sh.batch_specs(cfg, mesh).items()
                  if k in input_specs(cfg, shape)}
        if no_tp:
            dp_axes = ("pod", "data", "model") if multi_pod else ("data", "model")
            bspecs = jax.tree.map(
                lambda p: P(dp_axes, *tuple(p)[1:]), bspecs,
                is_leaf=lambda x: isinstance(x, P))
        if small_batch:
            bspecs = _fix_small_batch(bspecs, shape.global_batch, mesh)
        step = make_train_step(cfg, tc, n_micro=n_micro, batch_spec=bspecs)
        jitted = jax.jit(step,
                         in_shardings=(_named(pspecs, mesh),
                                       _named(ospecs, mesh),
                                       _named(bspecs, mesh)),
                         donate_argnums=(0, 1))
        args = (param_structs(cfg), ostructs, input_specs(cfg, shape))
        with mesh:
            lowered = jitted.lower(*args)
        flops_mult = 3.0  # fwd + bwd
        meta = {"n_micro": n_micro, "optimizer": tc.optimizer}

    elif shape.mode == "prefill":
        pstructs = param_structs(cfg, dtype=jnp.bfloat16)
        pspecs = sh.param_specs(pstructs, cfg, mesh)
        bspecs = {k: v for k, v in sh.batch_specs(cfg, mesh).items()
                  if k in input_specs(cfg, shape)}
        cspecs = sh.cache_specs(cfg, mesh)
        dpax = ("pod", "data") if multi_pod else ("data",)
        out_specs = (P(dpax, "model"), cspecs)
        if small_batch:
            bspecs, out_specs = (_fix_small_batch(t, shape.global_batch, mesh)
                                 for t in (bspecs, out_specs))
        step = make_prefill_step(cfg, shape.seq_len)
        jitted = jax.jit(step,
                         in_shardings=(_named(pspecs, mesh), _named(bspecs, mesh)),
                         out_shardings=_named(out_specs, mesh))
        with mesh:
            lowered = jitted.lower(pstructs, input_specs(cfg, shape))
        flops_mult = 1.0
        meta = {}

    else:  # decode
        serve_tp_only = bool(tc_patch.pop("serve_tp_only", False)) if tc_patch else False
        pstructs = param_structs(cfg, dtype=jnp.bfloat16)
        pspecs = sh.param_specs(pstructs, cfg, mesh)
        if serve_tp_only:
            # inference has no optimizer state: replicate params over `data`
            # instead of FSDP-sharding them (which re-gathers every token)
            pspecs = sh.strip_axis(pspecs, "data")
        ins = input_specs(cfg, shape)
        cspecs = sh.cache_specs(cfg, mesh)
        dpax = ("pod", "data") if multi_pod else ("data",)
        tok_spec = P(dpax, None)
        out_specs = (P(dpax, "model"), cspecs)
        if small_batch:
            tok_spec, cspecs, out_specs = (
                _fix_small_batch(t, shape.global_batch, mesh)
                for t in (tok_spec, cspecs, out_specs))
        base_decode = make_decode_step(cfg)
        if cfg.is_encoder_decoder:
            enc_struct = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            enc_spec = _fix_small_batch(P(dpax, None, None),
                                        shape.global_batch, mesh) \
                if small_batch else P(dpax, None, None)
            step = lambda p, t, c, i, e: base_decode(p, t, c, i, enc_out=e)
            jitted = jax.jit(step,
                             in_shardings=(_named(pspecs, mesh), _named(tok_spec, mesh),
                                           _named(cspecs, mesh), None,
                                           _named(enc_spec, mesh)),
                             out_shardings=_named(out_specs, mesh),
                             donate_argnums=(2,))
            with mesh:
                lowered = jitted.lower(pstructs, ins["token"], ins["caches"],
                                       ins["index"], enc_struct)
        else:
            jitted = jax.jit(base_decode,
                             in_shardings=(_named(pspecs, mesh), _named(tok_spec, mesh),
                                           _named(cspecs, mesh), None),
                             out_shardings=_named(out_specs, mesh),
                             donate_argnums=(2,))
            with mesh:
                lowered = jitted.lower(pstructs, ins["token"], ins["caches"],
                                       ins["index"])
        flops_mult = 1.0
        meta = {}

    meta.update({"warnings": warnings, "flops_mult": flops_mult,
                 "num_chips": mesh_cfg.num_devices})
    return lowered, meta


def model_flops_total(cfg: ModelConfig, shape: ShapeConfig, flops_mult: float) -> float:
    """Useful FLOPs per step: 6·N_active·D (train) / 2·N_active·D (serve)."""
    n = cfg.active_param_count()
    if shape.mode == "decode":
        d = shape.global_batch  # one token per sequence
    else:
        d = shape.global_batch * shape.seq_len
    return (2.0 * flops_mult) * n * d


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False, cfg_patch: dict | None = None,
             tc_patch: dict | None = None, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if tag:
        row["tag"] = tag
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        row.update({"status": "skipped", "reason": reason})
        return row
    try:
        t0 = time.time()
        lowered, meta = lower_cell(arch, shape_name, multi_pod,
                                   cfg_patch=cfg_patch, tc_patch=tc_patch)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        try:
            mem = compiled.memory_analysis()
            mem_row = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception:
            mem_row = {}
        hlo = compiled.as_text()
        counts = hlo_analysis.analyze_hlo(hlo)
        bytes_per_dev = None
        if mem_row.get("argument_bytes"):
            bytes_per_dev = (mem_row.get("argument_bytes", 0) or 0) + \
                            (mem_row.get("temp_bytes", 0) or 0)
        coll = dict(counts.coll)
        coll["total"] = counts.coll_total
        rep = roofline.build_report(
            arch, shape_name, mesh_name, meta["num_chips"],
            counts.flops, counts.bytes, coll,
            model_flops_total(cfg, shape, meta["flops_mult"]),
            hw=get_hardware("tpu_v5e"), bytes_per_device=bytes_per_dev)
        row.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            "cost_analysis_flops_raw": float(cost.get("flops", 0.0)),
            "loops": counts.loops,
            "s2_bytes": counts.s2_bytes,
            "hlo_flops": rep.hlo_flops,
            "hlo_bytes": rep.hlo_bytes,
            "coll_bytes": rep.coll_bytes,
            "coll_breakdown": rep.coll_breakdown,
            "compute_s": rep.compute_s,
            "memory_s": rep.memory_s,
            "collective_s": rep.collective_s,
            "dominant": rep.dominant,
            "model_flops_per_chip": rep.model_flops,
            "useful_ratio": rep.useful_ratio,
            "roofline_fraction": rep.roofline_fraction,
            "mem": mem_row,
            "warnings": meta.get("warnings", []),
            "n_micro": meta.get("n_micro"),
        })
        if keep_hlo:
            row["hlo_len"] = len(hlo)
        del hlo, compiled, lowered
    except Exception as e:
        row.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=SHAPE_ORDER + [None])
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = SHAPE_ORDER if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    out_f = open(args.out, "a") if args.out else None
    n_ok = n_err = n_skip = 0
    for a, s, mp in cells:
        row = run_cell(a, s, mp)
        line = json.dumps(row)
        if out_f:
            out_f.write(line + "\n")
            out_f.flush()
        status = row["status"]
        n_ok += status == "ok"
        n_err += status == "error"
        n_skip += status == "skipped"
        brief = {k: row.get(k) for k in
                 ("arch", "shape", "mesh", "status", "dominant",
                  "roofline_fraction", "compile_s", "error")}
        print(json.dumps(brief), flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors", flush=True)
    if out_f:
        out_f.close()
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
