"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
        --steps 100 --global-batch 32 --seq-len 256 --data 1 --model 1

Fault-tolerance behavior (DESIGN.md §5):
  * checkpoints every `--checkpoint-every` steps (async host write),
  * `--resume` restores the latest checkpoint and continues from its step —
    because the data pipeline is a pure function of (seed, step), a restart
    (or a replacement node) regenerates exactly the batches it would have
    seen, with no data-state handoff,
  * the mesh is rebuilt from the *current* device topology at startup, and
    restore reshards the loaded leaves onto it (elastic restart).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from .. import obs
from ..configs.base import MeshConfig, ShapeConfig, TrainConfig
from ..configs.registry import get_config, get_smoke_config
from ..checkpoint.ckpt import Checkpointer
from ..core import advisor
from ..data.pipeline import make_batch
from ..models import init_lm
from ..optim.adamw import init_opt
from ..parallel import sharding as sh
from ..train.train_step import make_train_step, num_microbatches


def build(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=args.attn_impl)
    if args.linear_impl:
        cfg = dataclasses.replace(cfg, linear_impl=args.linear_impl)
    mesh_cfg = MeshConfig(data=args.data, model=args.model)
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    tc = TrainConfig(total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
                     learning_rate=args.lr, optimizer=args.optimizer,
                     remat=args.remat, checkpoint_every=args.checkpoint_every,
                     checkpoint_dir=args.checkpoint_dir, seed=args.seed)
    return cfg, mesh_cfg, shape, tc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adamw8bit"])
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--attn-impl", default=None,
                    choices=[None, "naive", "blocked", "flash"])
    ap.add_argument("--linear-impl", default=None,
                    choices=[None, "jnp", "pallas", "tuned", "fused"],
                    help="dispatch for every dense projection GEMM "
                         "(repro.models.linear); fused = Pallas fused "
                         "SwiGLU/MLP kernel + tuned matmuls")
    ap.add_argument("--microbatch", type=int, default=0, help="per-device rows; 0=no accumulation")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, mesh_cfg, shape, tc = build(args)

    # shape-rule report (the paper's contribution, surfaced at launch)
    findings = advisor.check_alignment(cfg, tp=mesh_cfg.model,
                                       global_batch=shape.global_batch)
    for f in findings:
        if f.severity != "ok":
            print(f"[advisor:{f.severity}] {f.rule}: {f.message}")

    use_mesh = mesh_cfg.num_devices > 1
    if use_mesh:
        assert len(jax.devices()) >= mesh_cfg.num_devices, (
            f"need {mesh_cfg.num_devices} devices, have {len(jax.devices())}")
        mesh = sh.make_mesh(mesh_cfg)
        sh.set_activation_context(("data",))
    else:
        mesh = None

    if args.microbatch:
        tc = dataclasses.replace(tc, microbatch_per_device=args.microbatch)
        n_micro = num_microbatches(shape, mesh_cfg, tc)
    else:
        n_micro = 1

    key = jax.random.PRNGKey(tc.seed)
    params = init_lm(key, cfg)
    opt = init_opt(params, tc)
    start_step = 0
    ck = Checkpointer(tc.checkpoint_dir, keep=3)
    if args.resume and ck.latest_step() is not None:
        params_np, opt_np, start_step = ck.restore(params, opt)
        params = jax.tree.map(jnp.asarray, params_np)
        opt = jax.tree.map(jnp.asarray, opt_np)
        print(f"resumed from step {start_step}")

    bspec = None
    if use_mesh:
        pspecs = sh.param_specs(params, cfg, mesh)
        params = jax.device_put(params, sh.to_shardings(pspecs, mesh))
        ospecs_m = sh.param_specs(opt.m, cfg, mesh)
        ospecs_v = sh.param_specs(opt.v, cfg, mesh)
        opt = type(opt)(jax.device_put(opt.step),
                        jax.device_put(opt.m, sh.to_shardings(ospecs_m, mesh)),
                        jax.device_put(opt.v, sh.to_shardings(ospecs_v, mesh)))
        bspec = sh.batch_specs(cfg, mesh)

    step_fn = make_train_step(cfg, tc, n_micro=n_micro, batch_spec=bspec)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    ctx = mesh if use_mesh else _null()
    t0 = time.time()
    tokens_done = 0
    with ctx:
        for step in range(start_step, tc.total_steps):
            with obs.span("train_step", cat="train", step=step):
                batch = {k: jnp.asarray(v)
                         for k, v in make_batch(cfg, shape, step,
                                                tc.seed).items()}
                params, opt, metrics = step_fn(params, opt, batch)
                if obs.enabled():
                    jax.block_until_ready(metrics["loss"])
            if obs.enabled():
                obs.counter("train.steps").inc()
                obs.counter("train.tokens").inc(
                    shape.global_batch * shape.seq_len)
            tokens_done += shape.global_batch * shape.seq_len
            if step % args.log_every == 0 or step == tc.total_steps - 1:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"grad_norm {float(metrics['grad_norm']):.3f}  "
                      f"tok/s {tokens_done/max(dt,1e-6):,.0f}", flush=True)
            if tc.checkpoint_every and step and step % tc.checkpoint_every == 0:
                ck.save(step, params, opt, meta={"arch": cfg.name}, blocking=False)
    ck.save(tc.total_steps, params, opt, meta={"arch": cfg.name})
    ck.wait()
    print("done")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
