"""CLI: ``python -m repro.analysis [PATHS] --fail-on {warn,error}``.

Exit status: 1 when any finding at or above the ``--fail-on`` threshold
survives suppression, else 0 — this is the CI gate.  ``--format json``
emits the machine report (uploaded as a CI artifact); ``--list-rules``
prints the rule catalog.
"""
from __future__ import annotations

import argparse
import sys

from .engine import analyze
from .findings import count_by_severity, severity_at_least
from .reporters import render_json, render_text
from .rules import RULES


def _list_rules(stream) -> None:
    by_pass = {}
    for r in RULES.values():
        by_pass.setdefault(r.pass_name, []).append(r)
    for pass_name in ("shape", "kernel", "jit", "engine"):
        stream.write(f"[{pass_name}]\n")
        for r in sorted(by_pass.get(pass_name, []),
                        key=lambda r: r.rule_id):
            stream.write(f"  {r.rule_id}  {r.name:<28} "
                         f"{r.default_severity:<5} {r.doc}\n")
    stream.write("\nsuppress with: `# repro: noqa[RULE]` "
                 "(comma-separate for several; bare noqa = all)\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="codesign lint: shape efficiency, Pallas kernel "
                    "contract, jit/obs hygiene")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to scan (default: src)")
    ap.add_argument("--fail-on", choices=("warn", "error"), default="error",
                    help="exit 1 when a finding at/above this severity "
                         "survives (default: error)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--output", default=None,
                    help="write the report here instead of stdout")
    ap.add_argument("--hw", default="tpu_v5e",
                    help="hardware target for the shape audit "
                         "(default: tpu_v5e)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree for the shape audit")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule IDs to run (default: all)")
    ap.add_argument("--no-registry-audit", action="store_true",
                    help="skip the SHP config-registry audit")
    ap.add_argument("--no-smoke", action="store_true",
                    help="exclude smoke configs from the shape audit")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        _list_rules(sys.stdout)
        return 0

    rules = None
    if args.rules:
        rules = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = rules - set(RULES)
        if unknown:
            ap.error(f"unknown rule id(s): {sorted(unknown)}")

    paths = args.paths or ["src"]
    result = analyze(paths, registry_audit=not args.no_registry_audit,
                     hw_name=args.hw, tp=args.tp,
                     include_smoke=not args.no_smoke, rules=rules)

    stream = open(args.output, "w") if args.output else sys.stdout
    try:
        if args.format == "json":
            render_json(result.findings, stream, meta={
                "paths": paths, "hw": args.hw, "tp": args.tp,
                "fail_on": args.fail_on,
                "files_scanned": result.files_scanned})
        else:
            render_text(result.findings, stream)
    finally:
        if args.output:
            stream.close()

    gating = [f for f in result.findings
              if severity_at_least(f.severity, args.fail_on)]
    if gating:
        counts = count_by_severity(gating)
        sys.stderr.write(
            f"FAIL: {len(gating)} finding(s) at severity >= "
            f"{args.fail_on} ({counts['error']} error, "
            f"{counts['warn']} warn)\n")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
