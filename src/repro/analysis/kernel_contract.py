"""Pallas kernel contract checker (KRN rules) — an AST pass over kernels.

Two layers:

**Per-file checks** (any file that issues a `pl.pallas_call`):

  * KRN101 — VMEM scratch accumulators must be float32 (bf16 accumulation
    loses mantissa every MXU pass).
  * KRN102 — every `dot`/`dot_general` in a kernel file must request
    `preferred_element_type=jnp.float32`.
  * KRN103 — every `BlockSpec` index map's parameter count must equal the
    grid rank (plus `num_scalar_prefetch` for `PrefetchScalarGridSpec`
    contexts — scalar-prefetch refs are prepended to the index-map args).

**Cross-module tuned-op contract** (runs when the analyzed set contains an
`autotune_*` entry point, i.e. `tuning/search.py` is in scope):

  Every tuning-cache *lookup* (`lookup(op, shape, ...)` in `kernels/*/ops.py`
  or the serving engine) is matched against the `TunedConfig(op=...,
  shape=...)` entries the autotuners *write*:

  * KRN104 — a looked-up op that nothing writes (tuned=True silently never
    hits);
  * KRN105 — lookup/write shape-key arity mismatch (the key never matches);
  * KRN106 — an autotune entry point with no `*_candidates` lattice sweep,
    or a candidates lattice with no `*_vmem_bytes` feasibility model;
  * KRN107 — a written op that nothing in the analyzed tree consults.

  Op names are resolved statically through constants, conditional
  expressions, local assignments, and helper functions returning string
  literals or constant-prefix f-strings (`fused_mlp_{mlp_type}` matches as
  the prefix pattern ``fused_mlp_*``).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .findings import Finding
from .source import SourceFile

_LOW_PRECISION_FLOATS = {"bfloat16", "float16", "half"}
_DOT_FUNCS = {"dot", "dot_general"}
_LOOKUP_NAMES = {"lookup", "_tuning_lookup"}


# -- small AST helpers --------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_name(call: ast.Call) -> str:
    return _dotted(call.func) or ""


def _last_attr(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def _kw(call: ast.Call, name: str) -> Optional[ast.expr]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _is_f32(node: Optional[ast.expr]) -> bool:
    if node is None:
        return False
    d = _dotted(node)
    return d is not None and _last_attr(d) in ("float32", "f32")


def _is_wide_accum(node: Optional[ast.expr]) -> bool:
    """f32 or i32: int8 GEMMs accumulate exactly in int32 (the MXU's native
    int8 path), so an i32 preferred_element_type is as safe as f32."""
    if node is None:
        return False
    d = _dotted(node)
    return d is not None and _last_attr(d) in ("float32", "f32",
                                               "int32", "i32")


def _int_const(node: Optional[ast.expr]) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return None


def _enclosing_function(tree: ast.AST, target: ast.AST) -> Optional[ast.AST]:
    """Innermost FunctionDef containing `target` (by position)."""
    best = None
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if (node.lineno <= target.lineno
                    and target.lineno <= max(getattr(node, "end_lineno",
                                                     node.lineno),
                                             node.lineno)):
                if best is None or node.lineno > best.lineno:
                    best = node
    return best


def _resolve_name_assignment(scope: Optional[ast.AST], name: str,
                             before_line: int) -> Optional[ast.expr]:
    """Last `name = <expr>` in `scope` before `before_line`."""
    if scope is None:
        return None
    found = None
    for node in ast.walk(scope):
        if isinstance(node, ast.Assign) and node.lineno < before_line:
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    found = node.value
    return found


# -- per-file checks ----------------------------------------------------------


def _has_pallas_call(tree: ast.AST) -> bool:
    return any(isinstance(n, ast.Call)
               and _last_attr(_call_name(n)) == "pallas_call"
               for n in ast.walk(tree))


def _check_vmem_dtypes(sf: SourceFile) -> List[Finding]:
    out = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and _last_attr(_call_name(node)) == "VMEM"):
            continue
        dtype = node.args[1] if len(node.args) > 1 else _kw(node, "dtype")
        d = _dotted(dtype) if dtype is not None else None
        if d is not None and _last_attr(d) in _LOW_PRECISION_FLOATS:
            out.append(Finding(
                sf.path, node.lineno, "KRN101", "error",
                f"VMEM scratch declared as {_last_attr(d)}; Pallas "
                f"accumulators must be float32",
                fix_hint="declare the scratch as jnp.float32 and cast on "
                         "the final store (o_ref[...] = acc.astype(...))"))
    return out


def _check_dot_accum(sf: SourceFile) -> List[Finding]:
    out = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if _last_attr(name) not in _DOT_FUNCS:
            continue
        root = name.split(".", 1)[0]
        if root not in ("jnp", "jax", "lax", "pl", "np", "numpy"):
            continue
        pet = _kw(node, "preferred_element_type")
        if pet is None or not _is_wide_accum(pet):
            what = ("missing" if pet is None
                    else f"set to {_dotted(pet) or '?'}")
            out.append(Finding(
                sf.path, node.lineno, "KRN102", "error",
                f"{name} in a Pallas kernel file: preferred_element_type "
                f"{what}; the MXU would accumulate at the input dtype",
                fix_hint="pass preferred_element_type=jnp.float32 "
                         "(or jnp.int32 for an int8 GEMM)"))
    return out


@dataclasses.dataclass
class _SpecContext:
    """One pallas_call / PrefetchScalarGridSpec with its grid + specs."""

    call: ast.Call
    grid_rank: Optional[int]
    extra_index_args: int  # num_scalar_prefetch
    specs: List[Tuple[ast.Call, Optional[ast.expr]]]  # (BlockSpec, index_map)


def _resolve_blockspec(expr: ast.expr, tree: ast.AST,
                       scope: Optional[ast.AST]) -> Optional[ast.Call]:
    """Resolve an in_specs/out_specs element to its pl.BlockSpec(...) call:
    direct call, a local variable, or a local helper function returning
    one."""
    if isinstance(expr, ast.Call):
        if _last_attr(_call_name(expr)) == "BlockSpec":
            return expr
        # helper function returning a BlockSpec (paged.py kv_spec pattern)
        callee = _call_name(expr)
        if callee and "." not in callee:
            for node in ast.walk(scope or tree):
                if (isinstance(node, ast.FunctionDef)
                        and node.name == callee):
                    for sub in ast.walk(node):
                        if (isinstance(sub, ast.Return)
                                and isinstance(sub.value, ast.Call)
                                and _last_attr(_call_name(sub.value))
                                == "BlockSpec"):
                            return sub.value
        return None
    if isinstance(expr, ast.Name):
        val = _resolve_name_assignment(scope, expr.id, expr.lineno)
        if isinstance(val, ast.Call) and _last_attr(
                _call_name(val)) == "BlockSpec":
            return val
    return None


def _grid_rank_of(expr: Optional[ast.expr],
                  scope: Optional[ast.AST]) -> Optional[int]:
    if expr is None:
        return None
    if isinstance(expr, ast.Name):
        expr = _resolve_name_assignment(scope, expr.id, 10 ** 9)
        if expr is None:
            return None
    if isinstance(expr, (ast.Tuple, ast.List)):
        return len(expr.elts)
    if _int_const(expr) is not None:
        return 1
    return None


def _collect_spec_contexts(sf: SourceFile) -> List[_SpecContext]:
    out: List[_SpecContext] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        tail = _last_attr(_call_name(node))
        if tail not in ("pallas_call", "PrefetchScalarGridSpec"):
            continue
        scope = _enclosing_function(sf.tree, node)
        grid = _kw(node, "grid")
        extra = 0
        if tail == "PrefetchScalarGridSpec":
            extra = _int_const(_kw(node, "num_scalar_prefetch")) or 0
        rank = _grid_rank_of(grid, scope)
        specs: List[Tuple[ast.Call, Optional[ast.expr]]] = []
        spec_exprs: List[ast.expr] = []
        in_specs = _kw(node, "in_specs")
        if isinstance(in_specs, (ast.List, ast.Tuple)):
            spec_exprs.extend(in_specs.elts)
        out_specs = _kw(node, "out_specs")
        if isinstance(out_specs, (ast.List, ast.Tuple)):
            spec_exprs.extend(out_specs.elts)
        elif out_specs is not None:
            spec_exprs.append(out_specs)
        for e in spec_exprs:
            bs = _resolve_blockspec(e, sf.tree, scope)
            if bs is None:
                continue
            index_map = (bs.args[1] if len(bs.args) > 1
                         else _kw(bs, "index_map"))
            specs.append((bs, index_map))
        if grid is not None or specs:
            out.append(_SpecContext(node, rank, extra, specs))
    return out


def _lambda_arity(expr: ast.expr, scope: Optional[ast.AST],
                  tree: ast.AST) -> Optional[int]:
    """Required-parameter count of an index map (defaults like `g=g` are
    trace-time captures, not grid indices — excluded)."""
    if isinstance(expr, ast.Name):
        val = _resolve_name_assignment(scope, expr.id, expr.lineno)
        if val is not None:
            expr = val
    if isinstance(expr, ast.Lambda):
        a = expr.args
        return len(a.args) - len(a.defaults)
    return None


def _check_blockspec_arity(sf: SourceFile) -> List[Finding]:
    out = []
    for ctx in _collect_spec_contexts(sf):
        if ctx.grid_rank is None:
            continue
        want = ctx.grid_rank + ctx.extra_index_args
        for bs, index_map in ctx.specs:
            if index_map is None:
                continue
            scope = _enclosing_function(sf.tree, bs)
            arity = _lambda_arity(index_map, scope, sf.tree)
            if arity is None:
                continue
            if arity != want:
                extra = (f" + {ctx.extra_index_args} scalar-prefetch refs"
                         if ctx.extra_index_args else "")
                out.append(Finding(
                    sf.path, bs.lineno, "KRN103", "error",
                    f"BlockSpec index map takes {arity} args but the grid "
                    f"rank is {ctx.grid_rank}{extra} (= {want} expected)",
                    fix_hint="one index-map parameter per grid axis (plus "
                             "one leading ref per scalar-prefetch operand)"))
    return out


# -- cross-module tuned-op contract -------------------------------------------


@dataclasses.dataclass
class _OpRef:
    ops: List[str]  # resolved names; trailing '*' = prefix pattern
    arity: Optional[int]
    file: str
    line: int
    context: str  # enclosing function name


def _resolve_op_names(expr: ast.expr, scope: Optional[ast.AST],
                      def_index: Dict[str, ast.FunctionDef],
                      depth: int = 0) -> List[str]:
    if depth > 4 or expr is None:
        return []
    if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
        return [expr.value]
    if isinstance(expr, ast.IfExp):
        return (_resolve_op_names(expr.body, scope, def_index, depth + 1)
                + _resolve_op_names(expr.orelse, scope, def_index,
                                    depth + 1))
    if isinstance(expr, ast.JoinedStr):
        if expr.values and isinstance(expr.values[0], ast.Constant):
            return [str(expr.values[0].value) + "*"]
        return ["*"]
    if isinstance(expr, ast.Name):
        val = _resolve_name_assignment(scope, expr.id, expr.lineno)
        if val is not None:
            return _resolve_op_names(val, scope, def_index, depth + 1)
        return []
    if isinstance(expr, ast.Call):
        callee = _last_attr(_call_name(expr))
        fn = def_index.get(callee)
        if fn is None:
            return []
        names: List[str] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Return) and node.value is not None:
                names.extend(_resolve_op_names(node.value, fn, def_index,
                                               depth + 1))
        return names
    return []


def _tuple_arity(expr: Optional[ast.expr],
                 scope: Optional[ast.AST]) -> Optional[int]:
    if expr is None:
        return None
    if isinstance(expr, ast.Name):
        expr = _resolve_name_assignment(scope, expr.id, expr.lineno)
    if isinstance(expr, (ast.Tuple, ast.List)):
        return len(expr.elts)
    return None


def _op_matches(lookup_op: str, writer_op: str) -> bool:
    for a, b in ((lookup_op, writer_op), (writer_op, lookup_op)):
        if a.endswith("*") and b.startswith(a[:-1]):
            return True
    return lookup_op == writer_op


def _build_def_index(files: Sequence[SourceFile]) -> Dict[str,
                                                          ast.FunctionDef]:
    index: Dict[str, ast.FunctionDef] = {}
    for sf in files:
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.FunctionDef):
                index.setdefault(node.name, node)
    return index


def _names_referenced(fn: ast.AST) -> set:
    return {n.id for n in ast.walk(fn) if isinstance(n, ast.Name)} | {
        _last_attr(_dotted(n)) for n in ast.walk(fn)
        if isinstance(n, ast.Attribute) and _dotted(n)}


def check_tuned_contract(files: Sequence[SourceFile]) -> List[Finding]:
    """The cross-module contract registry check (KRN104-107)."""
    parsed = [sf for sf in files if sf.tree is not None]
    def_index = _build_def_index(parsed)
    autotune_defs = {n: f for n, f in def_index.items()
                     if n.startswith("autotune_")}
    if not autotune_defs:
        return []  # search module not in scope; nothing to cross-check

    findings: List[Finding] = []

    # writers: TunedConfig(op=..., shape=...) inside the analyzed set
    writers: List[_OpRef] = []
    for sf in parsed:
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call)
                    and _last_attr(_call_name(node)) == "TunedConfig"):
                continue
            scope = _enclosing_function(sf.tree, node)
            ops = _resolve_op_names(_kw(node, "op"), scope, def_index)
            arity = _tuple_arity(_kw(node, "shape"), scope)
            if ops:
                writers.append(_OpRef(ops, arity, sf.path, node.lineno,
                                      getattr(scope, "name", "<module>")))

    # lookups: lookup/_tuning_lookup(op, shape, dtype, hw)
    lookups: List[_OpRef] = []
    for sf in parsed:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            if _last_attr(_call_name(node)) not in _LOOKUP_NAMES:
                continue
            if len(node.args) < 2:
                continue
            scope = _enclosing_function(sf.tree, node)
            ops = _resolve_op_names(node.args[0], scope, def_index)
            arity = _tuple_arity(node.args[1], scope)
            if ops:
                lookups.append(_OpRef(ops, arity, sf.path, node.lineno,
                                      getattr(scope, "name", "<module>")))

    # KRN104/KRN105: every lookup must have a writer at the same arity
    for ref in lookups:
        for op in ref.ops:
            matches = [w for w in writers
                       if any(_op_matches(op, wop) for wop in w.ops)]
            if not matches:
                findings.append(Finding(
                    ref.file, ref.line, "KRN104", "error",
                    f"tuning-cache lookup for op {op!r} "
                    f"(in {ref.context}) has no autotune entry point "
                    f"writing it — tuned=True can never hit",
                    fix_hint="add an autotune_* entry in tuning/search.py "
                             "persisting TunedConfig(op=...) for this op"))
                continue
            if ref.arity is not None and not any(
                    w.arity == ref.arity for w in matches
                    if w.arity is not None):
                warities = sorted({w.arity for w in matches
                                   if w.arity is not None})
                findings.append(Finding(
                    ref.file, ref.line, "KRN105", "error",
                    f"lookup key for op {op!r} has {ref.arity} shape "
                    f"elements but the autotuner persists "
                    f"{warities or '?'} — the key never matches and "
                    f"tuned=True silently falls back",
                    fix_hint="make the ops.py lookup tuple and the "
                             "TunedConfig shape tuple the same arity"))

    # KRN107: writers nothing consults
    for w in writers:
        for op in w.ops:
            if not any(_op_matches(lop, op)
                       for ref in lookups for lop in ref.ops):
                findings.append(Finding(
                    w.file, w.line, "KRN107", "warn",
                    f"autotuner persists op {op!r} (in {w.context}) but "
                    f"nothing in the analyzed tree looks it up",
                    fix_hint="consult it via tuned=True, or drop the "
                             "entry"))

    # KRN106: every autotune entry sweeps a candidates lattice with a VMEM
    # feasibility model
    vmem_helpers = {n for n in def_index if n.endswith("_vmem_bytes")}

    def refs_vmem(fn: ast.AST, depth: int = 0) -> bool:
        names = _names_referenced(fn)
        if names & vmem_helpers:
            return True
        if depth >= 2:
            return False
        return any(refs_vmem(def_index[n], depth + 1) for n in names
                   if n in def_index and n not in vmem_helpers
                   and not n.startswith("autotune_"))

    for name, fn in autotune_defs.items():
        sf_path, line = _def_location(parsed, fn)
        cand_names = sorted(n for n in _names_referenced(fn)
                            if n.endswith("_candidates"))
        writes = any(isinstance(n, ast.Call)
                     and _last_attr(_call_name(n)) == "TunedConfig"
                     for n in ast.walk(fn))
        if not writes:
            continue
        if not cand_names:
            findings.append(Finding(
                sf_path, line, "KRN106", "error",
                f"{name} persists tuned entries without sweeping a "
                f"*_candidates lattice — block shapes would bypass the "
                f"tile-alignment/VMEM feasibility model",
                fix_hint="enumerate candidates via tuning/candidates.py "
                         "and measure each"))
            continue
        for cn in cand_names:
            cfn = def_index.get(cn)
            if cfn is not None and not refs_vmem(cfn):
                findings.append(Finding(
                    sf_path, line, "KRN106", "error",
                    f"{name}: candidates lattice {cn} has no "
                    f"*_vmem_bytes feasibility model — candidates could "
                    f"exceed on-chip memory",
                    fix_hint=f"bound {cn} by a VMEM working-set helper "
                             f"(see tuning/candidates.py)"))
    return findings


def _def_location(files: Sequence[SourceFile],
                  fn: ast.FunctionDef) -> Tuple[str, int]:
    for sf in files:
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if node is fn:
                return sf.path, fn.lineno
    return "<unknown>", fn.lineno


# -- entry point --------------------------------------------------------------


def check_file(sf: SourceFile) -> List[Finding]:
    """Per-file KRN checks; only files that issue a pallas_call are kernel
    files (ops.py wrappers and jnp ref oracles are exempt by construction)."""
    if sf.tree is None or not _has_pallas_call(sf.tree):
        return []
    out: List[Finding] = []
    out.extend(_check_vmem_dtypes(sf))
    out.extend(_check_dot_accum(sf))
    out.extend(_check_blockspec_arity(sf))
    return out
