"""repro.analysis — the codesign lint engine.

Static checks that turn the paper's co-design guidelines and this repo's
kernel/obs contracts into a CI gate:

  * **shape audit** (SHP1xx): every registered config's vocab / head_dim /
    d_ff / expert / SSM shapes against the target hardware's tile geometry,
    violations priced through the analytic GEMM model;
  * **kernel contract** (KRN1xx): AST checks over the Pallas kernels —
    f32 accumulators, BlockSpec index-map arity vs grid rank, and the
    cross-module tuned-op contract (ops lookup <-> autotuner <-> candidates
    lattice <-> VMEM budget);
  * **jit hygiene** (JIT2xx): obs instrumentation, host RNG/clocks, mutable
    defaults and mutated-global capture inside traced code.

Run it:  ``python -m repro.analysis src/ --fail-on error``
Suppress: ``# repro: noqa[RULE]`` on the offending line.
Catalog:  ``python -m repro.analysis --list-rules`` or
          docs/static-analysis-guide.md.
"""
from .engine import AnalysisResult, analyze
from .findings import (Finding, count_by_severity, severity_at_least,
                       sort_findings, worst_severity)
from .rules import RULES, Rule, get_rule
from .shape_audit import audit_config, audit_registry

__all__ = [
    "analyze", "AnalysisResult", "Finding", "RULES", "Rule", "get_rule",
    "audit_config", "audit_registry", "sort_findings", "count_by_severity",
    "severity_at_least", "worst_severity",
]
