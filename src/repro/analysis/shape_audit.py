"""Shape-efficiency audit: the paper's §VI-B checklist as enforced lint.

Where `core.advisor.check_alignment` *advises* interactively, this pass
*gates*: every config in the registry is checked against the target
hardware's tile geometry, each violation is priced through the analytic GEMM
model (`core.gemm_model`), and the finding is anchored to the config's
source line — so a `# repro: noqa[SHP10x]` pragma on the offending literal
suppresses it with an auditable trail.

Severity policy
---------------
  * A misalignment on the *executed* path is an ``error``.
  * A misalignment mitigated at runtime (raw vocab that
    `ModelConfig.padded_vocab_size` pads to alignment before any GEMM runs)
    or merely sub-optimal (head_dim with a pow2 factor >= 64 but below the
    full lane) is a ``warn``.
  * Configs with ``production=False`` (smoke configs, the GPT-3 2.7B paper
    case-study variants) have errors downgraded to ``warn``: they stay
    flagged, but never gate CI — deliberately-bad pedagogical shapes remain
    usable in tests and examples.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import List, Optional, Sequence

from ..configs.base import ModelConfig
from ..core import quantization as q
from ..core.gemm_model import GEMM, estimate
from ..core.hardware import Hardware, get_hardware
from .findings import Finding
from .source import load_source

# Tokens in flight for pricing: one 4k training sequence (TRAIN_4K's
# microbatch GEMM row count) — the m the paper's Fig. 20 vocab curve uses.
PRICE_TOKENS = 4096


@dataclasses.dataclass(frozen=True)
class RawFinding:
    """A shape finding before file/line attribution."""

    rule_id: str
    severity: str
    message: str
    fix_hint: str
    needles: Sequence[str]  # source substrings to anchor the finding to


def _gain_pct(t_bad: float, t_good: float) -> float:
    if t_good <= 0:
        return 0.0
    return (t_bad / t_good - 1.0) * 100.0


def _tput_gain_pct(bad: Sequence[GEMM], good: Sequence[GEMM],
                   hw: Hardware) -> float:
    """Predicted % gain in *useful FLOPs per second* from padding — the
    paper's efficiency framing.  (Raw time is the wrong yardstick here:
    `estimate` already folds tile quantization into the misaligned shape's
    time, so padding is roughly time-neutral while adding useful columns.)"""

    def tput(gemms: Sequence[GEMM]) -> float:
        t = sum(estimate(g, hw).time_s for g in gemms)
        f = sum(g.flops for g in gemms)
        return f / t if t > 0 else 0.0

    t_bad, t_good = tput(bad), tput(good)
    if t_bad <= 0:
        return 0.0
    return max((t_good / t_bad - 1.0) * 100.0, 0.0)


def _downgrade(sev: str, cfg: ModelConfig) -> str:
    if sev == "error" and not cfg.production:
        return "warn"
    return sev


def _price_lm_head(cfg: ModelConfig, hw: Hardware, v_bad: int,
                   v_good: int) -> float:
    """Predicted % MXU-throughput gain on the lm_head GEMM from padding."""
    return _tput_gain_pct(
        [GEMM("lm_head", PRICE_TOKENS, cfg.d_model, v_bad)],
        [GEMM("lm_head", PRICE_TOKENS, cfg.d_model, v_good)], hw)


def _price_mlp(cfg: ModelConfig, hw: Hardware, ff_bad: int,
               ff_good: int) -> float:
    """Predicted % throughput gain on the MLP GEMM pair from aligning
    d_ff."""
    h = cfg.d_model

    def pair(ff: int) -> List[GEMM]:
        return [GEMM("mlp_up", PRICE_TOKENS, h, ff),
                GEMM("mlp_down", PRICE_TOKENS, ff, h)]

    return _tput_gain_pct(pair(ff_bad), pair(ff_good), hw)


def _price_heads(cfg: ModelConfig, hw: Hardware) -> Optional[tuple]:
    """(best_heads, est % step-time gain) for realigning head_dim at constant
    d_model — the paper's Fig. 1 C0 -> C3 move — or None if no aligned
    sibling exists."""
    from ..core.advisor import _candidate_heads, step_time

    lane = hw.tile_2byte[1]
    cands = [a for a in _candidate_heads(cfg, lane) if a != cfg.num_heads]
    if not cands:
        return None
    base = step_time(cfg, hw=hw)
    best = None
    for a in cands[:3]:
        kv = cfg.num_kv_heads
        if kv == cfg.num_heads:
            kv = a
        elif kv and a % kv:
            continue
        sib = dataclasses.replace(cfg, num_heads=a, num_kv_heads=kv,
                                  head_dim=cfg.d_model // a)
        t = step_time(sib, hw=hw)
        if best is None or t < best[1]:
            best = (a, t)
    if best is None:
        return None
    return best[0], _gain_pct(base, best[1])


def audit_config(cfg: ModelConfig, hw: Optional[Hardware] = None,
                 tp: int = 1) -> List[RawFinding]:
    """All SHP findings for one config on one hardware target."""
    hw = hw or get_hardware()
    lane = hw.tile_2byte[1]
    out: List[RawFinding] = []

    # SHP101: vocab divisibility (§padded_vocab_size) --------------------
    v = cfg.vocab_size
    if v % lane != 0:
        v_pad = q.round_up(v, lane)
        gain = _price_lm_head(cfg, hw, v, v_pad)
        runtime_pad = cfg.padded_vocab_size % lane == 0
        sev = "warn" if runtime_pad else "error"
        note = (f"; runtime pads the embedding/lm_head to "
                f"{cfg.padded_vocab_size} (padded_vocab_size), so only the "
                f"declared shape is stale" if runtime_pad else
                "; every embedding/lm_head GEMM pads at execution")
        out.append(RawFinding(
            "SHP101", _downgrade(sev, cfg),
            f"[{cfg.name}] vocab {v} % {lane} = {v % lane}{note}",
            f"vocab {v} -> pad to {v_pad}, est. +{gain:.1f}% lm_head GEMM "
            f"throughput",
            (f"vocab_size={v}", f'name="{cfg.name}"')))

    # SHP102: per-head alignment (d_model / num_heads) -------------------
    if cfg.num_heads:
        hd = cfg.head_dim
        p2 = q.pow2_factor(hd)
        if hd % lane != 0:
            sev = "error" if p2 < 64 else "warn"
            priced = _price_heads(cfg, hw)
            if priced is not None:
                a, gain = priced
                hint = (f"num_heads {cfg.num_heads} -> {a} (head_dim "
                        f"{hd} -> {cfg.d_model // a}), est. "
                        f"+{gain:.1f}% step time")
            else:
                hint = (f"choose num_heads so d_model/num_heads has a pow2 "
                        f"factor >= {lane}")
            out.append(RawFinding(
                "SHP102", _downgrade(sev, cfg),
                f"[{cfg.name}] head_dim {hd} (d_model {cfg.d_model} / "
                f"{cfg.num_heads} heads): largest pow2 factor {p2} < lane "
                f"{lane}; attention BMMs run at reduced MXU utilization",
                hint,
                (f"num_heads={cfg.num_heads}", f", {cfg.num_heads})",
                 f'name="{cfg.name}"')))

    # SHP103: d_ff tile quantization -------------------------------------
    if cfg.d_ff and cfg.d_ff % lane != 0:
        ff_pad = q.round_up(cfg.d_ff, lane)
        gain = _price_mlp(cfg, hw, cfg.d_ff, ff_pad)
        out.append(RawFinding(
            "SHP103", _downgrade("error", cfg),
            f"[{cfg.name}] d_ff {cfg.d_ff} % {lane} = {cfg.d_ff % lane}; "
            f"every MLP GEMM pads the hidden dimension "
            f"(util {q.tile_utilization(PRICE_TOKENS, cfg.d_ff, cfg.d_model, hw):.3f})",
            f"d_ff {cfg.d_ff} -> {ff_pad}, est. +{gain:.1f}% MLP GEMM "
            f"throughput (paper §VII-B: LLaMA-2 chose 11008 = 86*128 "
            f"for 8h/3)",
            (f"d_ff={cfg.d_ff}", f'name="{cfg.name}"')))

    # SHP104: MoE expert d_ff --------------------------------------------
    if cfg.num_experts and cfg.moe_d_ff % lane != 0:
        ff_pad = q.round_up(cfg.moe_d_ff, lane)
        gain = _price_mlp(cfg, hw, cfg.moe_d_ff, ff_pad)
        out.append(RawFinding(
            "SHP104", _downgrade("error", cfg),
            f"[{cfg.name}] expert d_ff {cfg.moe_d_ff} % {lane} = "
            f"{cfg.moe_d_ff % lane}; every expert GEMM pads",
            f"moe_d_ff {cfg.moe_d_ff} -> {ff_pad}, est. +{gain:.1f}% "
            f"expert GEMM throughput",
            (f"moe_d_ff={cfg.moe_d_ff}", f'name="{cfg.name}"')))

    # SHP105: SSM state / chunk alignment --------------------------------
    if cfg.ssm_state:
        for field, val in (("ssm_state", cfg.ssm_state),
                           ("ssm_chunk", cfg.ssm_chunk)):
            if val % lane != 0:
                sev = ("warn" if field == "ssm_state"
                       and q.pow2_factor(val) >= 32 else "error")
                out.append(RawFinding(
                    "SHP105", _downgrade(sev, cfg),
                    f"[{cfg.name}] {field} {val} % {lane} = {val % lane}; "
                    f"SSD chunk BMMs pad "
                    f"(util {q.tile_utilization(val, val, cfg.ssm_state, hw):.3f})",
                    f"{field} {val} -> {q.round_up(val, lane)}",
                    (f"{field}={val}", f'name="{cfg.name}"')))

    # SHP106: wave quantization (GPU targets only) -----------------------
    if hw.concurrent_tiles and cfg.d_ff:
        weff = q.wave_efficiency(PRICE_TOKENS, cfg.d_ff, hw)
        if weff < 0.90:
            tiles = q.num_output_tiles(PRICE_TOKENS, cfg.d_ff, hw)
            waves = q.ceil_div(tiles, hw.num_cores)
            out.append(RawFinding(
                "SHP106", "warn",
                f"[{cfg.name}] MLP output tiles ({tiles}) fill the last of "
                f"{waves} waves over {hw.num_cores} SMs to "
                f"{weff * 100:.0f}% on {hw.name} (paper §VI-B wave "
                f"quantization)",
                f"resize d_ff so ceil-tiles divide {hw.num_cores} SMs, or "
                f"absorb into batch",
                (f"d_ff={cfg.d_ff}", f'name="{cfg.name}"')))

    return out


# -- registry attribution -----------------------------------------------------


def _config_module_files():
    """arch module name -> source path, via the registry's arch list."""
    from ..configs import registry as reg

    out = {}
    for arch in reg._ARCHS:
        mod = importlib.import_module(f"repro.configs.{arch}")
        out[arch] = mod.__file__
    return out


def _configs_in_module(arch: str):
    """(config, is_smoke) pairs registered by `repro.configs.<arch>`.

    Registered configs are matched to the module by name against the
    ModelConfig instances in its globals (the registry may hold a
    `production=False` copy of a smoke config, so identity is not enough).
    """
    from ..configs import registry as reg

    reg._load_all()
    mod = importlib.import_module(f"repro.configs.{arch}")
    declared = {v.name for v in vars(mod).values()
                if isinstance(v, ModelConfig)}
    pairs = [(c, False) for c in reg._REGISTRY.values()
             if c.name in declared]
    pairs += [(s, True) for s in reg._SMOKE.values() if s.name in declared]
    return pairs


def audit_registry(hw_name: str = "tpu_v5e", tp: int = 1,
                   include_smoke: bool = True) -> List[Finding]:
    """Audit every registered config; findings anchored to config sources.

    Suppression: a `# repro: noqa[SHP10x]` pragma on the anchored line
    silences the finding (applied here so the CLI and `report.py
    --analysis` agree).
    """
    hw = get_hardware(hw_name)
    out: List[Finding] = []
    for arch, path in _config_module_files().items():
        sf = load_source(path)
        seen = set()
        for cfg, is_smoke in _configs_in_module(arch):
            if is_smoke and not include_smoke:
                continue
            if cfg.name in seen:
                continue
            seen.add(cfg.name)
            for raw in audit_config(cfg, hw, tp):
                line = 1
                for needle in raw.needles:
                    hit = sf.find_line(needle, default=0)
                    if hit:
                        line = hit
                        break
                if sf.suppressions.is_suppressed(line, raw.rule_id):
                    continue
                out.append(Finding(path, line, raw.rule_id, raw.severity,
                                   raw.message, raw.fix_hint, arch=cfg.name))
    return out
