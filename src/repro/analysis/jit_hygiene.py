"""jit / obs hygiene (JIT rules) — keep traced programs pure.

The obs layer's contract (PR 6) is that instrumentation lives *outside*
jitted code: a span or counter inside a traced function fires once at trace
time, silently records nothing afterwards, and — worse — makes the trace
look instrumented when it is not.  Host-side RNG and clocks inside a traced
function freeze to trace-time constants.  This pass finds those hazards
statically.

Scope: functions *reachable from a jit root within the same module* —

  * ``@jax.jit`` / ``@functools.partial(jax.jit, ...)`` decorated defs,
  * defs passed to ``jax.jit(f)`` (the engine's ``return jax.jit(prefill)``
    factory pattern),
  * Pallas kernel bodies (first arg of ``pl.pallas_call``, including
    ``functools.partial(kernel, ...)``),
  * ``jax.custom_vjp`` functions and their ``defvjp`` fwd/bwd pair,
  * ``lax.scan`` / ``cond`` / ``while_loop`` / ``fori_loop`` bodies,

plus anything those call locally.  Cross-module reachability is deliberately
out of scope: trace-time dispatch recording in ops.py wrappers (outside the
inner jitted fns) is by-design "once per lowered program" and must not be
flagged.

Rules:

  * JIT201 — obs call (span/counter/record_dispatch/...) inside traced
    code.  ``jax.named_scope`` is the sanctioned alternative (trace-time
    HLO metadata, no runtime host effect).
  * JIT202 — host RNG / clock (`time.*`, `random.*`, `np.random.*`,
    `datetime.*`) inside traced code: freezes to a trace-time constant.
  * JIT203 — mutable default argument on a traced function: shared across
    every trace, a classic cache-poisoning footgun.
  * JIT204 — traced code reads a module-level mutable (list/dict/set)
    binding: captured by value at trace time; later mutation never
    re-traces.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .findings import Finding
from .source import SourceFile

_OBS_CALLS = {"span", "instant", "counter", "gauge", "histogram",
              "record_dispatch", "enable", "disable", "export_all"}
_JIT_NAMES = {"jit"}  # matched as last attr of jax.jit / jax.jit alias
_CONTROL_FLOW_BODIES = {"scan", "cond", "while_loop", "fori_loop",
                        "switch", "checkpoint", "remat"}
_HOST_EFFECT_ROOTS = {"time", "random", "datetime"}


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_name(call: ast.Call) -> str:
    return _dotted(call.func) or ""


def _last(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def _is_jit_expr(node: ast.expr) -> bool:
    """jax.jit, or functools.partial(jax.jit, ...)."""
    d = _dotted(node)
    if d is not None and _last(d) in _JIT_NAMES:
        return True
    if isinstance(node, ast.Call):
        cn = _call_name(node)
        if _last(cn) == "partial" and node.args:
            return _is_jit_expr(node.args[0])
        if _last(cn) in _JIT_NAMES:
            return True
    return False


def _fn_names_in(expr: ast.expr) -> List[str]:
    """Local function names referenced by a callable-ish argument."""
    if isinstance(expr, ast.Name):
        return [expr.id]
    if isinstance(expr, ast.Call):
        cn = _call_name(expr)
        if _last(cn) == "partial" and expr.args:
            return _fn_names_in(expr.args[0])
    return []


def _collect_defs(tree: ast.AST) -> Dict[str, ast.FunctionDef]:
    defs: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            defs.setdefault(node.name, node)
    return defs


def _collect_roots(sf: SourceFile,
                   defs: Dict[str, ast.FunctionDef]) -> Set[str]:
    roots: Set[str] = set()
    tree = sf.tree

    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if _is_jit_expr(dec):
                    roots.add(node.name)
                d = _dotted(dec) or (_call_name(dec)
                                     if isinstance(dec, ast.Call) else "")
                if d and _last(d) == "custom_vjp":
                    roots.add(node.name)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        tail = _last(name)
        # jax.jit(fn) — the engine _make_* factory pattern
        if tail in _JIT_NAMES and node.args:
            for fn in _fn_names_in(node.args[0]):
                if fn in defs:
                    roots.add(fn)
        # pl.pallas_call(kernel, ...) / pallas_call(kernel=...)
        if tail == "pallas_call":
            kernel = node.args[0] if node.args else None
            if kernel is None:
                for k in node.keywords:
                    if k.arg == "kernel":
                        kernel = k.value
            if kernel is not None:
                for fn in _fn_names_in(kernel):
                    if fn in defs:
                        roots.add(fn)
        # jax.custom_vjp(f), f.defvjp(fwd, bwd)
        if tail in ("custom_vjp", "defvjp"):
            for arg in node.args:
                for fn in _fn_names_in(arg):
                    if fn in defs:
                        roots.add(fn)
        # lax.scan(body, ...) and friends — bodies trace
        if tail in _CONTROL_FLOW_BODIES and name.split(".")[0] in (
                "lax", "jax"):
            for arg in node.args:
                for fn in _fn_names_in(arg):
                    if fn in defs:
                        roots.add(fn)
    return roots


def _reachable(roots: Set[str],
               defs: Dict[str, ast.FunctionDef]) -> Set[str]:
    seen: Set[str] = set()
    work = [r for r in roots if r in defs]
    while work:
        name = work.pop()
        if name in seen:
            continue
        seen.add(name)
        fn = defs[name]
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = _last(_call_name(node))
                if callee in defs and callee not in seen:
                    work.append(callee)
    return seen


def _module_mutables(tree: ast.AST) -> Dict[str, int]:
    """Module-level names bound to mutable list/dict/set values that the
    module also *mutates* somewhere (a frozen-in-practice constant dict is a
    legitimate trace-time capture; one that code appends to is not)."""
    bound: Dict[str, int] = {}
    for node in getattr(tree, "body", []):
        targets = []
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        else:
            continue
        mutable = isinstance(value, (ast.List, ast.Dict, ast.Set,
                                     ast.ListComp, ast.DictComp,
                                     ast.SetComp))
        if isinstance(value, ast.Call) and _last(
                _call_name(value)) in ("list", "dict", "set",
                                       "defaultdict", "deque"):
            mutable = True
        if not mutable:
            continue
        for t in targets:
            if isinstance(t, ast.Name):
                bound[t.id] = node.lineno

    mutated: Set[str] = set()
    _MUTATORS = {"append", "extend", "add", "update", "pop", "popitem",
                 "setdefault", "clear", "insert", "remove", "discard"}
    for node in ast.walk(tree):
        # d[k] = v / del d[k] / d[k] += v
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
            targets = (node.targets if isinstance(node, (ast.Assign,
                                                         ast.Delete))
                       else [node.target])
            for t in targets:
                if (isinstance(t, ast.Subscript)
                        and isinstance(t.value, ast.Name)):
                    mutated.add(t.value.id)
        # d.update(...) etc.
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _MUTATORS
                and isinstance(node.func.value, ast.Name)):
            mutated.add(node.func.value.id)
        # global d; d = ...
        if isinstance(node, ast.Global):
            mutated.update(node.names)
    return {n: ln for n, ln in bound.items() if n in mutated}


def _mutable_defaults(fn: ast.FunctionDef) -> List[ast.expr]:
    out = []
    for d in list(fn.args.defaults) + [d for d in fn.args.kw_defaults
                                       if d is not None]:
        if isinstance(d, (ast.List, ast.Dict, ast.Set)):
            out.append(d)
        elif isinstance(d, ast.Call) and _last(
                _call_name(d)) in ("list", "dict", "set"):
            out.append(d)
    return out


def _local_bindings(fn: ast.FunctionDef) -> Set[str]:
    bound = {a.arg for a in (fn.args.args + fn.args.kwonlyargs
                             + fn.args.posonlyargs)}
    if fn.args.vararg:
        bound.add(fn.args.vararg.arg)
    if fn.args.kwarg:
        bound.add(fn.args.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx,
                                                     (ast.Store,
                                                      ast.Del)):
            bound.add(node.id)
        elif isinstance(node, ast.FunctionDef) and node is not fn:
            bound.add(node.name)
    return bound


def check_file(sf: SourceFile) -> List[Finding]:
    if sf.tree is None:
        return []
    defs = _collect_defs(sf.tree)
    roots = _collect_roots(sf, defs)
    if not roots:
        return []
    traced = _reachable(roots, defs)
    mutables = _module_mutables(sf.tree)
    out: List[Finding] = []

    for name in sorted(traced):
        fn = defs[name]

        # JIT203: mutable defaults on the traced def itself
        for d in _mutable_defaults(fn):
            out.append(Finding(
                sf.path, d.lineno, "JIT203", "error",
                f"traced function {name!r} has a mutable default "
                f"argument; it is shared across every trace",
                fix_hint="default to None and construct inside, or make "
                         "it a static tuple"))

        local = _local_bindings(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            cname = _call_name(node)
            if not cname:
                continue
            head, tail = cname.split(".")[0], _last(cname)

            # JIT201: obs instrumentation inside traced code
            if head == "obs" and tail in _OBS_CALLS:
                out.append(Finding(
                    sf.path, node.lineno, "JIT201", "error",
                    f"obs.{tail}() inside traced function {name!r}: fires "
                    f"once at trace time, then records nothing",
                    fix_hint="hoist to the un-jitted wrapper; use "
                             "jax.named_scope for in-trace HLO labels"))

            # JIT202: host RNG / clocks inside traced code
            host = (head in _HOST_EFFECT_ROOTS and head not in local) or \
                cname.startswith(("np.random.", "numpy.random."))
            if host:
                out.append(Finding(
                    sf.path, node.lineno, "JIT202", "error",
                    f"host effect {cname}() inside traced function "
                    f"{name!r}: freezes to a trace-time constant",
                    fix_hint="thread a jax.random key / pass timestamps "
                             "in as arguments"))

        # JIT204: `global` in traced code, and reads of module-level
        # mutables the module actually mutates (one finding per name)
        flagged: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Global):
                out.append(Finding(
                    sf.path, node.lineno, "JIT204", "error",
                    f"traced function {name!r} declares "
                    f"global {', '.join(node.names)}: module state "
                    f"mutated from traced code runs at trace time only",
                    fix_hint="return the value and update module state "
                             "in the un-jitted caller"))
                continue
            if (isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)
                    and node.id in mutables
                    and node.id not in local
                    and node.id not in flagged):
                flagged.add(node.id)
                out.append(Finding(
                    sf.path, node.lineno, "JIT204", "error",
                    f"traced function {name!r} reads module-level mutable "
                    f"{node.id!r}; captured by value at trace time, later "
                    f"mutation never re-traces",
                    fix_hint="pass it as an argument (donated/static as "
                             "appropriate) or freeze it to a tuple"))
    return out
