"""Finding reporters: human text and machine JSON."""
from __future__ import annotations

import json
from typing import List, Optional, TextIO

from .findings import Finding, count_by_severity, sort_findings

_SEV_TAG = {"info": "I", "warn": "W", "error": "E"}


def render_text(findings: List[Finding], stream: TextIO,
                verbose: bool = True) -> None:
    """flake8-style `file:line: SEV RULE message` lines, worst first."""
    for f in sort_findings(findings):
        tag = _SEV_TAG.get(f.severity, "?")
        arch = f" [{f.arch}]" if f.arch and f"[{f.arch}]" not in f.message \
            else ""
        stream.write(f"{f.file}:{f.line}: {tag} {f.rule_id}{arch} "
                     f"{f.message}\n")
        if verbose and f.fix_hint:
            stream.write(f"    fix: {f.fix_hint}\n")
    counts = count_by_severity(findings)
    total = len(findings)
    if total:
        stream.write(
            f"\n{total} finding{'s' if total != 1 else ''} "
            f"({counts['error']} error, {counts['warn']} warn, "
            f"{counts['info']} info)\n")
    else:
        stream.write("no findings\n")


def render_json(findings: List[Finding], stream: TextIO,
                meta: Optional[dict] = None) -> None:
    doc = {
        "findings": [f.to_json() for f in sort_findings(findings)],
        "counts": count_by_severity(findings),
    }
    if meta:
        doc["meta"] = meta
    json.dump(doc, stream, indent=2, sort_keys=True)
    stream.write("\n")
