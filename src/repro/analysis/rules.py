"""Rule registry: every check the lint engine can raise, with a stable ID.

Rule IDs are grouped by pass:

  * ``SHP1xx`` — shape-efficiency audit over the config registry
    (`analysis.shape_audit`): the paper's §VI-B guidelines as checks, priced
    through `core.gemm_model`.
  * ``KRN1xx`` — Pallas kernel contract (`analysis.kernel_contract`): AST
    checks over `kernels/*` plus the cross-module tuned-op contract against
    `tuning/candidates.py` and `tuning/search.py`.
  * ``JIT2xx`` — jit/obs hygiene (`analysis.jit_hygiene`): host-side effects
    inside `jax.jit`/`pl.pallas_call`-reachable functions.
  * ``ANA0xx`` — the engine itself (unparseable file, unknown rule in a
    pragma).

`docs/static-analysis-guide.md` is the human-facing catalog; this module is
the machine-facing one (``python -m repro.analysis --list-rules`` prints it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    name: str
    default_severity: str  # info | warn | error
    pass_name: str  # shape | kernel | jit | engine
    doc: str


RULES: Dict[str, Rule] = {}


def register(rule_id: str, name: str, default_severity: str, pass_name: str,
             doc: str) -> Rule:
    if rule_id in RULES:
        raise ValueError(f"duplicate rule id {rule_id}")
    r = Rule(rule_id, name, default_severity, pass_name, doc)
    RULES[rule_id] = r
    return r


def get_rule(rule_id: str) -> Rule:
    return RULES[rule_id]


# -- engine ------------------------------------------------------------------
ANA001 = register(
    "ANA001", "syntax-error", "error", "engine",
    "File does not parse; no other checks can run on it.")
ANA002 = register(
    "ANA002", "unknown-rule-in-pragma", "warn", "engine",
    "A `# repro: noqa[...]` pragma names a rule ID that does not exist "
    "(typo'd suppressions silently stop suppressing).")

# -- shape audit -------------------------------------------------------------
SHP101 = register(
    "SHP101", "vocab-alignment", "error", "shape",
    "vocab_size is not a multiple of the hardware lane width (paper §VI-B "
    "'vocab divisible by 64'; 128 on TPU lanes).  The embedding/lm_head GEMM "
    "pads every pass; the fix hint prices padding the declared vocab.")
SHP102 = register(
    "SHP102", "head-dim-alignment", "error", "shape",
    "d_model/num_heads leaves a head_dim whose largest power-of-two factor "
    "is below the lane width — attention BMMs run at reduced MXU utilization "
    "(paper Fig. 1 GPT-3 2.7B case study).")
SHP103 = register(
    "SHP103", "dff-alignment", "error", "shape",
    "d_ff is not lane-aligned (tile quantization pads every MLP GEMM pass; "
    "paper §VII-B d_ff re-search).")
SHP104 = register(
    "SHP104", "expert-dff-alignment", "error", "shape",
    "MoE expert d_ff (moe_d_ff) is not lane-aligned; every expert GEMM pads.")
SHP105 = register(
    "SHP105", "ssm-alignment", "error", "shape",
    "SSM state or chunk size is not lane-aligned; the SSD chunk BMMs pad "
    "(TPU adaptation of the paper's BMM alignment rules).")
SHP106 = register(
    "SHP106", "wave-quantization", "warn", "shape",
    "On wave-scheduled hardware (GPUs), the MLP/lm_head output tile count "
    "leaves a mostly-empty tail wave over the SMs (paper §VI-B wave "
    "quantization).  Only raised for hardware with concurrent_tiles.")

# -- kernel contract ---------------------------------------------------------
KRN101 = register(
    "KRN101", "non-f32-accumulator", "error", "kernel",
    "A Pallas VMEM scratch accumulator is declared at a low-precision float "
    "dtype.  Accumulators must be float32: bf16 accumulation loses ~8 bits "
    "of mantissa per MXU pass.")
KRN102 = register(
    "KRN102", "dot-missing-f32-accum", "error", "kernel",
    "A dot/dot_general inside a Pallas kernel body does not request a wide "
    "accumulator (preferred_element_type=jnp.float32, or jnp.int32 for int8 "
    "operands) — the MXU would accumulate at the input dtype.")
KRN103 = register(
    "KRN103", "blockspec-arity", "error", "kernel",
    "A BlockSpec index_map's parameter count does not match the "
    "pallas_call grid rank; the kernel would fail (or silently broadcast) "
    "at lowering time.")
KRN104 = register(
    "KRN104", "tuned-op-unregistered", "error", "kernel",
    "A tuning-cache lookup names an op that no autotune entry point ever "
    "writes — tuned=True would silently never hit.")
KRN105 = register(
    "KRN105", "tuned-key-arity", "error", "kernel",
    "A tuning-cache lookup's shape-key arity differs from what the "
    "autotuner persists for that op — the key never matches, so tuned=True "
    "silently falls back to defaults.")
KRN106 = register(
    "KRN106", "autotune-without-lattice", "error", "kernel",
    "An autotune entry point does not sweep a `*_candidates` lattice, or "
    "its lattice has no VMEM-budget (`*_vmem_bytes`) feasibility model — "
    "candidates could exceed on-chip memory.")
KRN107 = register(
    "KRN107", "tuned-op-never-consulted", "warn", "kernel",
    "The autotuner persists entries for an op that nothing in the analyzed "
    "tree ever looks up (dead tuning entries).")

# -- jit hygiene -------------------------------------------------------------
JIT201 = register(
    "JIT201", "obs-inside-jit", "error", "jit",
    "An obs span/metric/dispatch call is reachable from a jitted or Pallas "
    "kernel function.  The observability contract (docs/observability-"
    "guide.md) is instrumentation strictly outside jit; inside traced code "
    "it runs at trace time only — or retraces.  Use jax.named_scope inside "
    "jit instead.")
JIT202 = register(
    "JIT202", "host-effect-inside-jit", "error", "jit",
    "A host-side clock or RNG call (time.*, random.*, np.random.*, "
    "datetime.now) is reachable from jitted code: it executes once at trace "
    "time and is baked into the program as a constant.  Use jax.random with "
    "threaded keys, or hoist the call outside the jit.")
JIT203 = register(
    "JIT203", "mutable-default-in-jit", "error", "jit",
    "A function reachable from jitted code has a mutable default argument "
    "(list/dict/set): the default is captured at trace time and shared "
    "across calls/programs.")
JIT204 = register(
    "JIT204", "global-capture-in-jit", "error", "jit",
    "A function reachable from jitted code declares `global`, or reads a "
    "module-level mutable (list/dict/set) that the module mutates elsewhere "
    "— the value is captured at trace time and later mutation never "
    "re-traces.")
