"""Orchestrator: run every pass over a file set, apply suppressions.

`analyze()` is the one entry point the CLI, the tests, and `benchmarks/
report.py --analysis` share.  Pass order:

  1. engine checks (ANA001 parse errors, ANA002 bad pragmas) per file;
  2. per-file AST passes — kernel contract (KRN101-103) and jit hygiene
     (JIT2xx);
  3. the cross-module tuned-op contract (KRN104-107) over the whole set;
  4. the registry shape audit (SHP1xx), unless disabled — it is keyed on
     the config *registry*, not the scanned paths, so it runs whenever the
     repo's configs are importable.

Per-line `# repro: noqa[...]` pragmas are applied to AST-pass findings here
(the shape audit applies its own, since its findings are anchored across
files it did not scan).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set

from . import jit_hygiene, kernel_contract
from .findings import Finding, sort_findings
from .source import SourceFile, engine_findings, iter_python_files, \
    load_source


@dataclasses.dataclass
class AnalysisResult:
    findings: List[Finding]
    files_scanned: int

    def with_rules(self, rule_ids: Set[str]) -> "AnalysisResult":
        return AnalysisResult(
            [f for f in self.findings if f.rule_id in rule_ids],
            self.files_scanned)


def _suppressed(sf: SourceFile, f: Finding) -> bool:
    return sf.suppressions.is_suppressed(f.line, f.rule_id)


def analyze(paths: Sequence[str], registry_audit: bool = True,
            hw_name: str = "tpu_v5e", tp: int = 1,
            include_smoke: bool = True,
            rules: Optional[Set[str]] = None) -> AnalysisResult:
    files = [load_source(p) for p in iter_python_files(list(paths))]
    findings: List[Finding] = []

    for sf in files:
        findings.extend(engine_findings(sf))
        if sf.tree is None:
            continue
        for f in kernel_contract.check_file(sf) + jit_hygiene.check_file(sf):
            if not _suppressed(sf, f):
                findings.append(f)

    by_path = {sf.path: sf for sf in files}
    for f in kernel_contract.check_tuned_contract(files):
        sf = by_path.get(f.file)
        if sf is None or not _suppressed(sf, f):
            findings.append(f)

    if registry_audit:
        from .shape_audit import audit_registry
        try:
            findings.extend(audit_registry(hw_name=hw_name, tp=tp,
                                           include_smoke=include_smoke))
        except ImportError:
            pass  # scanning a tree without the repo's configs on path

    if rules is not None:
        findings = [f for f in findings if f.rule_id in rules]
    return AnalysisResult(sort_findings(findings), len(files))
