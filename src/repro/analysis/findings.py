"""Finding model for the codesign lint engine.

A `Finding` is one rule violation anchored to a source location.  Severities
are ordered (`info < warn < error`) so the CLI's `--fail-on` threshold and
the reporters can sort/filter without string games.  Shape-audit findings
additionally carry the architecture name (`arch`) they were raised for, and
— wherever the analytic cost model can price the fix — a `fix_hint` that
quotes the predicted gain (e.g. "pad vocab 50257 -> 50304, est. +4.1%
lm_head GEMM").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

SEVERITIES = ("info", "warn", "error")
_SEV_ORDER = {s: i for i, s in enumerate(SEVERITIES)}


def severity_at_least(sev: str, threshold: str) -> bool:
    return _SEV_ORDER[sev] >= _SEV_ORDER[threshold]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at file:line."""

    file: str
    line: int
    rule_id: str
    severity: str  # info | warn | error
    message: str
    fix_hint: str = ""
    arch: str = ""  # config name, for registry-audit findings

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")

    @property
    def sort_key(self):
        return (-_SEV_ORDER[self.severity], self.file, self.line, self.rule_id)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Finding":
        return cls(**d)


def sort_findings(findings) -> list:
    return sorted(findings, key=lambda f: f.sort_key)


def count_by_severity(findings) -> dict:
    counts = {s: 0 for s in SEVERITIES}
    for f in findings:
        counts[f.severity] += 1
    return counts


def worst_severity(findings) -> Optional[str]:
    worst = None
    for f in findings:
        if worst is None or _SEV_ORDER[f.severity] > _SEV_ORDER[worst]:
            worst = f.severity
    return worst
