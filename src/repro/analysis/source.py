"""Source loading + per-line `# repro: noqa[...]` pragma suppression.

Pragma syntax (modeled on flake8's noqa, namespaced so generic linters
ignore it):

    x = do_thing()          # repro: noqa[KRN102]
    y = other_thing()       # repro: noqa[KRN101,JIT201]
    z = last_thing()        # repro: noqa          <- suppresses every rule

A suppression applies to findings anchored on its line.  Unknown rule IDs
inside the brackets raise ANA002 (a typo'd suppression that silently stops
suppressing is worse than noise).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, List, Optional, Set, Tuple

from .findings import Finding
from .rules import RULES

NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa(?:\s*\[(?P<rules>[A-Za-z0-9_,\s]*)\])?")


@dataclasses.dataclass
class Suppressions:
    """Per-file map of line -> suppressed rule IDs (None = all rules)."""

    by_line: Dict[int, Optional[Set[str]]]
    unknown: List[Tuple[int, str]]  # (line, bad rule id) for ANA002

    def is_suppressed(self, line: int, rule_id: str) -> bool:
        if line not in self.by_line:
            return False
        rules = self.by_line[line]
        return rules is None or rule_id in rules


def _iter_comments(text: str, lines: List[str]):
    """(line, comment_text) pairs — real comments only, via tokenize, so a
    docstring *showing* the pragma syntax never counts as a suppression.
    Falls back to a whole-line scan if the file does not tokenize."""
    try:
        import io
        import tokenize

        toks = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        for i, line in enumerate(lines, start=1):
            yield i, line
        return
    for tok in toks:
        if tok.type == tokenize.COMMENT:
            yield tok.start[0], tok.string


def scan_pragmas(text: str, lines: List[str]) -> Suppressions:
    by_line: Dict[int, Optional[Set[str]]] = {}
    unknown: List[Tuple[int, str]] = []
    for i, comment in _iter_comments(text, lines):
        m = NOQA_RE.search(comment)
        if not m:
            continue
        spec = m.group("rules")
        if spec is None:
            by_line[i] = None  # bare noqa: everything
            continue
        ids = {r.strip() for r in spec.split(",") if r.strip()}
        for rid in ids:
            if rid not in RULES:
                unknown.append((i, rid))
        by_line[i] = ids
    return Suppressions(by_line=by_line, unknown=unknown)


@dataclasses.dataclass
class SourceFile:
    path: str
    text: str
    lines: List[str]
    tree: Optional[ast.AST]  # None when the file does not parse
    parse_error: Optional[str]
    suppressions: Suppressions

    def find_line(self, needle: str, default: int = 1) -> int:
        """First 1-based line containing `needle` (shape-audit attribution:
        point the finding at the offending literal, so a noqa pragma on that
        line suppresses it naturally)."""
        for i, text in enumerate(self.lines, start=1):
            if needle in text:
                return i
        return default


def load_source(path: str) -> SourceFile:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    lines = text.splitlines()
    tree: Optional[ast.AST] = None
    err: Optional[str] = None
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as e:
        err = f"{e.msg} (line {e.lineno})"
    return SourceFile(path=path, text=text, lines=lines, tree=tree,
                      parse_error=err,
                      suppressions=scan_pragmas(text, lines))


def iter_python_files(paths: List[str]) -> List[str]:
    """Expand files/directories into a sorted, deduped .py file list
    (skipping __pycache__ and hidden directories)."""
    out: List[str] = []
    seen: Set[str] = set()

    def add(p: str):
        p = os.path.normpath(p)
        if p not in seen:
            seen.add(p)
            out.append(p)

    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                add(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d != "__pycache__" and not d.startswith("."))
            for name in sorted(files):
                if name.endswith(".py"):
                    add(os.path.join(root, name))
    return out


def engine_findings(sf: SourceFile) -> List[Finding]:
    """Findings the loader itself raises: parse errors and bad pragmas."""
    out: List[Finding] = []
    if sf.parse_error is not None:
        out.append(Finding(sf.path, 1, "ANA001", "error",
                           f"syntax error: {sf.parse_error}"))
    for line, rid in sf.suppressions.unknown:
        out.append(Finding(
            sf.path, line, "ANA002", "warn",
            f"pragma names unknown rule {rid!r}",
            fix_hint="check the rule catalog: python -m repro.analysis "
                     "--list-rules"))
    return out
