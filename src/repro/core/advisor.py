"""Shape advisor: score a model config against hardware shape rules and
propose nearby, faster shapes at ~constant parameter count.

This operationalizes the paper's §VI-B checklist and §VII case studies:
  * vocab divisible by the lane alignment (64 on A100 → 128 on TPU),
  * head_dim (h/a) divisible by a power of two, ideally the full lane width,
  * h/t, d_ff/t, a/t, kv/t, experts/t divisibility for t-way TP/EP,
  * (b·a)/t integral,
  * L divisible by pipeline stages,
  * SwiGLU d_ff re-search around 8h/3,
and the search procedure used for Fig. 1 (GPT-3 2.7B: a 32→20/40) and
§VII-B (LLaMA-2 d_ff=11008).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..configs.base import ModelConfig, ShapeConfig, TRAIN_4K
from .hardware import Hardware, get_hardware
from .gemm_model import MeasuredProfile, throughput_tflops, total_time
from .transformer_gemms import model_gemms
from .quantization import pow2_factor, round_up, shard_quantization


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    severity: str  # ok | warn | bad
    message: str


@dataclasses.dataclass(frozen=True)
class Proposal:
    config: ModelConfig
    change: str
    predicted_speedup: float  # >1 is faster than the input config
    param_delta: float  # relative parameter-count change
    tflops: float


def check_alignment(cfg: ModelConfig, hw: Optional[Hardware] = None,
                    tp: int = 1, pp: int = 1,
                    global_batch: int = 256) -> List[Finding]:
    """The paper's rule checklist, evaluated for `cfg` on `hw`."""
    hw = hw or get_hardware()
    lane = hw.tile_2byte[1]
    f: List[Finding] = []

    def rule(name, ok, warn, msg_ok, msg_bad):
        sev = "ok" if ok else ("warn" if warn else "bad")
        f.append(Finding(name, sev, msg_ok if ok else msg_bad))

    v = cfg.vocab_size
    rule("vocab_alignment", v % lane == 0, v % 64 == 0,
         f"vocab {v} is a multiple of {lane}",
         f"vocab {v} % {lane} = {v % lane}; pad to {round_up(v, lane)} "
         f"(+{round_up(v, lane) - v} tokens)")

    if cfg.num_heads:
        hd = cfg.head_dim
        p2 = pow2_factor(hd)
        rule("head_dim_alignment", hd % lane == 0, p2 >= 64,
             f"head_dim {hd} is a multiple of {lane}",
             f"head_dim {hd}: largest pow2 factor {p2} (< {lane}); "
             f"attention BMMs run at reduced MXU utilization")
        rule("heads_div_tp", cfg.num_heads % tp == 0, False,
             f"num_heads {cfg.num_heads} divisible by tp={tp}",
             f"num_heads {cfg.num_heads} not divisible by tp={tp}")
        if cfg.num_kv_heads:
            rule("kv_heads_div_tp", cfg.num_kv_heads % tp == 0,
                 tp % cfg.num_kv_heads == 0,
                 f"kv_heads {cfg.num_kv_heads} divisible by tp={tp}",
                 f"kv_heads {cfg.num_kv_heads} vs tp={tp}: KV heads must be "
                 f"replicated or resharded")

    rule("hidden_shard_alignment", (cfg.d_model % tp == 0)
         and ((cfg.d_model // tp) % lane == 0),
         cfg.d_model % tp == 0,
         f"h/t = {cfg.d_model // max(tp,1)} is a multiple of {lane}",
         f"h={cfg.d_model}, t={tp}: per-shard width misaligned")

    if cfg.d_ff:
        ff = cfg.d_ff
        rule("dff_shard_alignment", ff % tp == 0 and (ff // tp) % lane == 0,
             ff % tp == 0,
             f"d_ff/t = {ff // max(tp,1)} is a multiple of {lane}",
             f"d_ff={ff}, t={tp}: per-shard MLP width misaligned "
             f"(util {shard_quantization(ff, tp):.3f})")

    if cfg.num_experts:
        rule("experts_div_ep", cfg.num_experts % tp == 0, False,
             f"{cfg.num_experts} experts divide EP={tp}",
             f"{cfg.num_experts} experts do not divide EP={tp}")
        rule("expert_dff_alignment", cfg.moe_d_ff % lane == 0,
             cfg.moe_d_ff % 64 == 0,
             f"expert d_ff {cfg.moe_d_ff} is a multiple of {lane}",
             f"expert d_ff {cfg.moe_d_ff} misaligned")

    if cfg.ssm_state:
        rule("ssm_state_alignment", cfg.ssm_state % lane == 0,
             pow2_factor(cfg.ssm_state) >= 32,
             f"ssm_state {cfg.ssm_state} is a multiple of {lane}",
             f"ssm_state {cfg.ssm_state} misaligned (SSD chunk BMMs pad)")
        rule("ssm_chunk_alignment", cfg.ssm_chunk % lane == 0, False,
             f"ssm_chunk {cfg.ssm_chunk} is a multiple of {lane}",
             f"ssm_chunk {cfg.ssm_chunk} misaligned")

    rule("layers_div_pp", cfg.num_layers % pp == 0, False,
         f"L={cfg.num_layers} divisible by pp={pp}",
         f"L={cfg.num_layers} not divisible by pp={pp} (paper §VI-B)")

    rule("batch_div_dp", global_batch % 1 == 0, True, "batch rule checked by mesh", "")
    return f


def score(cfg: ModelConfig, shape: ShapeConfig = TRAIN_4K,
          hw: Optional[Hardware] = None, tp: int = 1,
          microbatch: int = 1,
          profile: Optional[MeasuredProfile] = None) -> float:
    """Predicted achieved TFLOP/s for one microbatch through the whole model
    (the paper's Fig. 1 y-axis; analytic, or measurement-calibrated when a
    `MeasuredProfile` is given)."""
    hw = hw or get_hardware()
    mode = "decode" if shape.is_decode else "train"
    gemms = model_gemms(cfg, microbatch, shape.seq_len, t=tp, mode=mode)
    return throughput_tflops(gemms, hw, profile)


def step_time(cfg: ModelConfig, shape: ShapeConfig = TRAIN_4K,
              hw: Optional[Hardware] = None, tp: int = 1,
              microbatch: int = 1,
              profile: Optional[MeasuredProfile] = None) -> float:
    hw = hw or get_hardware()
    mode = "decode" if shape.is_decode else "train"
    gemms = model_gemms(cfg, microbatch, shape.seq_len, t=tp, mode=mode)
    mult = 3.0 if shape.mode == "train" else 1.0  # fwd+bwd
    return mult * total_time(gemms, hw, profile)


def precision_plan(cfg: ModelConfig, shape: ShapeConfig = TRAIN_4K,
                   hw: Optional[Hardware] = None, tp: int = 1,
                   microbatch: int = 1,
                   dtypes: tuple = ("bfloat16", "int8"),
                   min_speedup: float = 1.05) -> List[dict]:
    """Per-layer GEMM precision recommendations under the analytic model.

    For every named GEMM in the model's step (Table II decomposition), price
    it at each candidate storage precision and report the winner — the
    dtype-aware companion to `check_alignment`: decode-mode skinny GEMMs are
    bandwidth-bound, so int8 weights (kernels.quantized / linear_impl=
    "quantized") buy their byte ratio, while compute-bound prefill GEMMs
    stay at the baseline.  Returns one dict per GEMM:
      {name, m, k, n, bound, recommended_dtype, speedup, candidates}
    with `candidates` mapping dtype -> predicted time_s.
    """
    from .gemm_model import estimate, precision_candidates, recommend_precision
    hw = hw or get_hardware()
    mode = "decode" if shape.is_decode else "train"
    gemms = model_gemms(cfg, microbatch, shape.seq_len, t=tp, mode=mode)
    plan: List[dict] = []
    for g in gemms:
        ests = precision_candidates(g, hw, dtypes)
        best, speedup = recommend_precision(g, hw, dtypes,
                                            min_speedup=min_speedup)
        plan.append({
            "name": g.name,
            "m": g.m, "k": g.k, "n": g.n,
            "bound": estimate(g, hw).bound,
            "recommended_dtype": best,
            "speedup": speedup,
            "candidates": {dt: e.time_s for dt, e in ests.items()},
        })
    return plan


def _candidate_heads(cfg: ModelConfig, lane: int,
                     max_head_dim: int = 256) -> List[int]:
    """Head counts near cfg.num_heads with aligned head_dim, h unchanged.

    head_dim is capped (default 256): the paper warns that aggressively
    shrinking `a` can cost accuracy (§VI-B), so we only propose shapes in the
    empirically safe 64..256 head_dim band.
    """
    h = cfg.d_model
    cands = []
    for a in range(1, min(h, 4 * cfg.num_heads) + 1):
        if h % a:
            continue
        hd = h // a
        if hd > max_head_dim or hd < 32:
            continue
        if hd % lane == 0 or pow2_factor(hd) >= 64:
            cands.append(a)
    # keep the ones closest to the original head count
    cands.sort(key=lambda a: abs(a - cfg.num_heads))
    return cands[:6]


def _candidate_dff(cfg: ModelConfig, lane: int, tp: int, tol: float) -> List[int]:
    """d_ff values near the original that are lane*tp aligned (§VII-B).

    Only values >= the original are proposed: shrinking d_ff is trivially
    'faster' but cuts capacity — the paper's search (and LLaMA-2's actual
    11008 = 86*128 choice for 8h/3 = 10922.6) rounds UP to alignment."""
    base = cfg.d_ff
    step = lane * max(tp, 1)
    hi = int(base * (1 + tol))
    out = [d for d in range(round_up(base, step), hi + 1, step)]
    return out[:32]


def advise(cfg: ModelConfig, shape: ShapeConfig = TRAIN_4K,
           hw: Optional[Hardware] = None, tp: int = 1,
           param_tolerance: float = 0.05,
           microbatch: int = 1,
           profile: Optional[MeasuredProfile] = None) -> List[Proposal]:
    """Search nearby configs; return proposals ranked by predicted speedup.

    Reproduces the paper's case studies: for GPT-3 2.7B (h=2560, a=32) the
    top proposals change `a` so head_dim is 64/128-aligned; for SwiGLU models
    it re-searches d_ff around 8h/3; for any model it pads the vocab.

    When `profile` is given, every step-time prediction is grounded in the
    measured kernel timings it carries (see gemm_model.MeasuredProfile);
    `propose()` builds that profile from the autotuning cache automatically.
    """
    hw = hw or get_hardware()
    lane = hw.tile_2byte[1]
    base_t = step_time(cfg, shape, hw, tp, microbatch, profile)
    base_params = cfg.param_count()
    props: List[Proposal] = []

    def consider(new_cfg: ModelConfig, change: str):
        p = new_cfg.param_count()
        delta = (p - base_params) / base_params
        if abs(delta) > param_tolerance:
            return
        t = step_time(new_cfg, shape, hw, tp, microbatch, profile)
        props.append(Proposal(new_cfg, change, base_t / t, delta,
                              score(new_cfg, shape, hw, tp, microbatch,
                                    profile)))

    # 1. vocab padding (Fig. 20 / Karpathy rule)
    v_pad = round_up(cfg.vocab_size, lane * max(tp, 1))
    if v_pad != cfg.vocab_size:
        consider(dataclasses.replace(cfg, vocab_size=v_pad),
                 f"pad vocab {cfg.vocab_size} -> {v_pad}")

    # 2. head count (Fig. 1 C1/C2 case study)
    if cfg.num_heads and cfg.attn_type == "gqa":
        for a in _candidate_heads(cfg, lane):
            if a == cfg.num_heads:
                continue
            kv = cfg.num_kv_heads
            if kv == cfg.num_heads:
                kv = a  # MHA: keep MHA
            elif a % max(kv, 1):
                continue  # GQA requires kv | a
            consider(dataclasses.replace(cfg, num_heads=a, num_kv_heads=kv,
                                         head_dim=cfg.d_model // a),
                     f"heads {cfg.num_heads} -> {a} (head_dim "
                     f"{cfg.head_dim} -> {cfg.d_model // a})")

    # 3. d_ff re-search (SwiGLU §VII-B, or any misaligned MLP)
    if cfg.d_ff:
        for ff in _candidate_dff(cfg, lane, tp, param_tolerance):
            if ff == cfg.d_ff:
                continue
            consider(dataclasses.replace(cfg, d_ff=ff),
                     f"d_ff {cfg.d_ff} -> {ff}")

    # 4. SSD chunk/state alignment (TPU adaptation of the BMM rules)
    if cfg.ssm_state and cfg.ssm_chunk % lane:
        consider(dataclasses.replace(cfg, ssm_chunk=round_up(cfg.ssm_chunk, lane)),
                 f"ssm_chunk {cfg.ssm_chunk} -> {round_up(cfg.ssm_chunk, lane)}")

    props.sort(key=lambda p: -p.predicted_speedup)
    return props


def propose(cfg: ModelConfig, shape: ShapeConfig = TRAIN_4K,
            hw: Optional[Hardware] = None, tp: int = 1,
            param_tolerance: float = 0.05, microbatch: int = 1,
            profile: Optional[MeasuredProfile] = None,
            cache=None) -> List[Proposal]:
    """`advise`, grounded in measurement when a tuning cache exists.

    If `profile` is None, one is built from `cache` (default: the process
    default tuning cache — see repro.tuning.cache).  With no cache entries
    this degrades gracefully to the purely analytic `advise`.
    """
    hw = hw or get_hardware()
    if profile is None:
        profile = MeasuredProfile.from_cache(cache, hw.name)
    return advise(cfg, shape, hw, tp, param_tolerance, microbatch, profile)


def best_combined(cfg: ModelConfig, shape: ShapeConfig = TRAIN_4K,
                  hw: Optional[Hardware] = None, tp: int = 1,
                  param_tolerance: float = 0.05,
                  profile: Optional[MeasuredProfile] = None) -> Proposal:
    """Greedily stack the top proposal of each category."""
    hw = hw or get_hardware()
    cur = cfg
    changes = []
    for _ in range(4):
        props = advise(cur, shape, hw, tp, param_tolerance, profile=profile)
        props = [p for p in props if p.predicted_speedup > 1.005]
        if not props:
            break
        cur = props[0].config
        changes.append(props[0].change)
    base_t = step_time(cfg, shape, hw, tp, profile=profile)
    new_t = step_time(cur, shape, hw, tp, profile=profile)
    return Proposal(cur, "; ".join(changes) or "no change", base_t / new_t,
                    (cur.param_count() - cfg.param_count()) / cfg.param_count(),
                    score(cur, shape, hw, tp, profile=profile))
