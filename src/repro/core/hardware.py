"""Hardware descriptions for the co-design engine.

The paper derives its shape rules from GPU micro-architecture constants
(tensor-core alignment, tile sizes, #SMs).  We parameterize those constants so
the same analytic machinery can target TPU v5e (our production target) and the
paper's GPUs (for paper-fidelity benchmark regeneration).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Hardware:
    """A single accelerator chip, as seen by the GEMM cost model."""

    name: str
    # peak dense matmul throughput at the benchmark dtype, FLOP/s
    peak_flops: float
    # HBM bandwidth, bytes/s
    hbm_bw: float
    # interconnect bandwidth per chip (sum of usable links), bytes/s
    ici_bw: float
    # matmul unit native tile (rows, cols) in *elements* at bf16/fp16
    mxu: tuple[int, int]
    # native (sublane, lane) register/VMEM tile at 2-byte dtypes
    tile_2byte: tuple[int, int]
    # number of independent schedulable compute units.  GPUs: #SMs (wave
    # quantization domain).  TPU v5e: 1 TensorCore per chip (grid steps are
    # sequential); v5p Megacore: 2.
    num_cores: int
    # fast on-chip memory per core available to a kernel working set, bytes
    sram_bytes: int
    # whether the 'wave quantization' rule (paper §VI-B) applies: thread
    # blocks are scheduled concurrently in waves over num_cores.
    concurrent_tiles: bool
    # kernel launch / grid-step fixed overhead, seconds (tail-latency floor)
    launch_overhead: float = 2.0e-6

    def alignment_elements(self, dtype_bytes: int = 2) -> int:
        """Paper's tensor-core rule, generalized: dims should be multiples of
        this many elements for full matmul-unit utilization."""
        return self.mxu[1] * 2 // max(dtype_bytes, 1) if self.name.startswith("tpu") else (
            128 // dtype_bytes
        )


# --- TPU v5e: the production target -------------------------------------------------
# 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (values from the task brief).
# 2D torus: model-parallel collectives typically see ~2 usable links per direction;
# we budget 3 links aggregate (conservative between 2 and 4).
TPU_V5E = Hardware(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=3 * 50e9,
    mxu=(128, 128),
    tile_2byte=(16, 128),
    num_cores=1,
    sram_bytes=64 * 1024 * 1024,  # usable VMEM working-set budget
    concurrent_tiles=False,
)

# --- Paper GPUs (paper-fidelity mode for benchmark regeneration) --------------------
A100_40GB = Hardware(
    name="a100",
    peak_flops=312e12,  # fp16 tensor core
    hbm_bw=1555e9,
    ici_bw=600e9,  # NVLink
    mxu=(128, 256),  # most efficient CUTLASS tile (paper §VI-B)
    tile_2byte=(64, 64),  # 128-byte alignment at fp16 => 64 elements
    num_cores=108,
    sram_bytes=192 * 1024,
    concurrent_tiles=True,
)

V100_16GB = Hardware(
    name="v100",
    peak_flops=125e12,
    hbm_bw=900e9,
    ici_bw=300e9,
    mxu=(128, 256),
    tile_2byte=(8, 8),  # 16-byte alignment at fp16 => 8 elements
    num_cores=80,
    sram_bytes=96 * 1024,
    concurrent_tiles=True,
)

H100_SXM = Hardware(
    name="h100",
    peak_flops=989e12,
    hbm_bw=3350e9,
    ici_bw=900e9,
    mxu=(128, 256),
    tile_2byte=(64, 64),
    num_cores=132,
    sram_bytes=228 * 1024,
    concurrent_tiles=True,
)

BY_NAME = {hw.name: hw for hw in (TPU_V5E, A100_40GB, V100_16GB, H100_SXM)}


def get_hardware(name: str = "tpu_v5e") -> Hardware:
    try:
        return BY_NAME[name]
    except KeyError as e:
        raise ValueError(f"unknown hardware {name!r}; have {sorted(BY_NAME)}") from e


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A mesh of chips for roofline purposes."""

    chip: Hardware
    num_chips: int

    @property
    def peak_flops(self) -> float:
        return self.chip.peak_flops * self.num_chips

    @property
    def hbm_bw(self) -> float:
        return self.chip.hbm_bw * self.num_chips
