"""Tile- and wave-quantization math (paper §III-B, §VI-B), hardware-parametric.

GPU mode reproduces the paper's rules verbatim:
  * tensor-core alignment: dims multiple of `tile_2byte` elements,
  * tile quantization: output matrix divided into mxu-tile blocks, partial
    blocks execute at full-block cost,
  * wave quantization: blocks scheduled to `num_cores` SMs in waves; a tail
    wave runs at full-wave latency with partial useful work.

TPU mode keeps the first two (MXU pass padding) and replaces the third:
grid steps on a v5e TensorCore are *sequential*, so the "wave" is a single
grid step and the tail effect is the partial final block plus shard-level
divisibility (see `shard_quantization`).

Naming note: this module is about *tile/wave* quantization — utilization
loss from shapes that do not divide the hardware's native tiles.  *Numeric*
quantization (compressing values to int8/fp8) lives in `repro.quant`; the
two share a name in the literature but nothing else.
"""
from __future__ import annotations


from .hardware import Hardware


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(x: int, multiple: int) -> int:
    return ceil_div(x, multiple) * multiple


def pow2_factor(n: int, cap: int = 1024) -> int:
    """Largest power of two dividing n (capped).  The paper's Figs. 7-9 color
    curves by this quantity."""
    if n <= 0:
        return 1
    f = n & (-n)
    return min(f, cap)


def tile_utilization(m: int, n: int, k: int, hw: Hardware, dtype_bytes: int = 2) -> float:
    """Fraction of matmul-unit work that is useful after padding every
    dimension up to the native tile.  1.0 = perfectly aligned.

    This is the paper's tensor-core + tile-quantization effect folded into a
    single multiplicative utilization term.
    """
    sub, lane = hw.tile_2byte
    # scale sublane granularity with dtype (f32: 8, bf16: 16, int8: 32 on TPU)
    sub = max(1, sub * 2 // max(dtype_bytes, 1)) if hw.name.startswith("tpu") else sub
    tm, tn = hw.mxu
    # dims are padded to the register tile, and the output is blocked into
    # mxu tiles; both pads waste multiply-accumulate cycles.
    m_pad = round_up(round_up(m, sub), 1)
    n_pad = round_up(round_up(n, lane), 1)
    k_pad = round_up(k, lane)
    m_blk = round_up(m_pad, tm)
    n_blk = round_up(n_pad, tn)
    useful = m * n * k
    padded = m_blk * n_blk * k_pad
    return useful / max(padded, 1)


def num_output_tiles(m: int, n: int, hw: Hardware) -> int:
    tm, tn = hw.mxu
    return ceil_div(m, tm) * ceil_div(n, tn)


def wave_efficiency(m: int, n: int, hw: Hardware, batch: int = 1) -> float:
    """Paper §VI-B wave quantization: `batch * tiles` thread blocks scheduled
    over `num_cores` SMs.  Tail wave runs at full-wave latency.

    Returns useful_waves / actual_waves in (0, 1].  For hardware with
    sequential grids (TPU), returns 1.0 — the tail cost is already inside
    `tile_utilization` (partial final block) and `shard_quantization`.
    """
    if not hw.concurrent_tiles:
        return 1.0
    blocks = num_output_tiles(m, n, hw) * batch
    waves = ceil_div(blocks, hw.num_cores)
    return blocks / (waves * hw.num_cores)


def wave_free(m: int, n: int, hw: Hardware) -> bool:
    """Paper's no-wave-quantization constraint:
    ceil(X/t1)*ceil(Y/t2) ≡ 0 (mod #SMs)  (either tile orientation)."""
    t1, t2 = hw.mxu
    a = ceil_div(m, t1) * ceil_div(n, t2)
    b = ceil_div(m, t2) * ceil_div(n, t1)
    return a % hw.num_cores == 0 or b % hw.num_cores == 0


def shard_quantization(dim: int, shards: int) -> float:
    """TPU-scale analogue of wave quantization: utilization loss from a
    dimension that does not divide evenly across a mesh axis.  XLA SPMD pads
    every shard to ceil(dim/shards); utilization = dim / (shards * shard)."""
    if shards <= 1:
        return 1.0
    per = ceil_div(dim, shards)
    return dim / (per * shards)
