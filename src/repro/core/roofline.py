"""Three-term roofline analysis from compiled XLA artifacts.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

`compiled.cost_analysis()` reports the *per-partition* (per-chip) program, so
per-chip FLOPs/bytes divided by per-chip peaks give the same result as the
whole-cluster formula; we record per-chip numbers and say so.

collective_bytes is not in cost_analysis: we parse the (post-SPMD) HLO text
and sum output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (async start ops counted once).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from .hardware import Hardware, get_hardware

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# one shape token: bf16[2,4096,512]{2,1,0}  (layout optional)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# an HLO instruction line: "%name = <shape-or-tuple> opcode(..."
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z0-9-]+)(?:-start)?\("
)


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_txt):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective category from HLO text."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        shape_txt, opcode = m.group(1), m.group(2)
        if opcode.endswith("-done"):
            continue
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in out:
            out[base] += _shape_bytes(shape_txt)
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    num_chips: int
    # per-chip quantities (SPMD partition program)
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float  # 6*N*D per-chip-equivalent useful training FLOPs
    bytes_per_device: Optional[float] = None  # from memory_analysis

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput vs chip peak, given the bound step time
        (an analytic MFU)."""
        t = self.step_time_lower_bound
        return (self.model_flops / t) / _PEAK if t else 0.0


_PEAK = 197e12  # set at report build; kept for the property above


def build_report(arch: str, shape: str, mesh: str, num_chips: int,
                 flops: float, nbytes: float, coll: Dict[str, float],
                 model_flops_total: float,
                 hw: Optional[Hardware] = None,
                 bytes_per_device: Optional[float] = None) -> RooflineReport:
    """Assemble a RooflineReport from per-chip quantities.

    flops/nbytes/coll come from `core.hlo_analysis.analyze_hlo` on the
    compiled (post-SPMD) HLO text — NOT from raw `cost_analysis()`, which
    counts while-loop bodies once and so under-reports scanned models; the
    raw value is still recorded by the dry-run for reference.
    `model_flops_total` is whole-cluster useful FLOPs per step (6·N_active·D
    train / 2·N_active·D serve), divided by chips here.
    """
    hw = hw or get_hardware()
    global _PEAK
    _PEAK = hw.peak_flops
    total = float(coll.get("total", sum(coll.values())))
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, num_chips=num_chips,
        hlo_flops=flops,
        hlo_bytes=nbytes,
        coll_bytes=total,
        coll_breakdown=coll,
        compute_s=flops / hw.peak_flops,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=total / hw.ici_bw,
        model_flops=model_flops_total / max(num_chips, 1),
        bytes_per_device=bytes_per_device,
    )


def to_row(r: RooflineReport) -> Dict[str, object]:
    return {
        "arch": r.arch,
        "shape": r.shape,
        "mesh": r.mesh,
        "compute_s": f"{r.compute_s:.4f}",
        "memory_s": f"{r.memory_s:.4f}",
        "collective_s": f"{r.collective_s:.4f}",
        "dominant": r.dominant,
        "useful_ratio": f"{r.useful_ratio:.3f}",
        "roofline_fraction": f"{r.roofline_fraction:.3f}",
        "bytes_per_device_GB": (f"{r.bytes_per_device/2**30:.2f}"
                                 if r.bytes_per_device else "n/a"),
    }
