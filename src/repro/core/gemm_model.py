"""Analytic GEMM/BMM cost model (paper §III, §V) — hardware-parametric.

Predicted kernel time = max(compute_time, memory_time, launch_overhead) where

  compute_time = padded_flops / (peak_flops * wave_efficiency)
  memory_time  = bytes_moved / hbm_bw

`padded_flops` folds in tensor-core/tile quantization (see quantization.py);
`wave_efficiency` applies only on wave-scheduled hardware (GPUs).  The model
reproduces the paper's Figures 5-10 qualitatively: throughput rises with
arithmetic intensity, dips at misaligned dims and at wave boundaries.

`MeasuredProfile` grounds the analytic model in reality: built from the
autotuning cache (`repro.tuning`), it substitutes measured wall times for
GEMMs whose exact shape was tuned and rescales the rest by the measured/
analytic calibration ratio, so relative comparisons stay on one scale.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, Optional, Tuple

from .hardware import Hardware, get_hardware
from . import quantization as q


@dataclasses.dataclass(frozen=True)
class GEMM:
    """C[b](m,n) += A[b](m,k) @ B[b](k,n), `batch` independent problems.

    `name` ties the GEMM back to its transformer module (Table II).
    `weight_bytes` lets callers mark B as a resident weight (counted once per
    step for memory-traffic purposes regardless of batch).
    """

    name: str
    m: int
    k: int
    n: int
    batch: int = 1
    dtype_bytes: int = 2
    weight_is_b: bool = True  # B is a weight matrix (vs. activation BMM)
    count: int = 1  # how many times this GEMM occurs (e.g. per layer)

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n * self.batch * self.count

    @property
    def bytes_moved(self) -> float:
        """HBM traffic assuming A, B, C each move once (no fusion credit)."""
        a = self.m * self.k
        b = self.k * self.n
        c = self.m * self.n
        return (a + b + c) * self.batch * self.dtype_bytes * self.count

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_moved, 1.0)


@dataclasses.dataclass(frozen=True)
class GEMMEstimate:
    gemm: GEMM
    time_s: float
    compute_s: float
    memory_s: float
    tile_util: float
    wave_eff: float
    achieved_tflops: float
    bound: str  # "compute" | "memory" | "overhead" | "measured"

    @property
    def efficiency(self) -> float:
        """Achieved/peak — the quantity the paper plots."""
        return self.tile_util * self.wave_eff


@dataclasses.dataclass(frozen=True)
class MeasuredProfile:
    """Measured kernel timings for one hardware target, keyed by GEMM shape.

    Built from the autotuning cache (`MeasuredProfile.from_cache`).  Two uses:

      * exact hit — a GEMM whose (m, k, n, dtype) was autotuned gets the
        measured per-call wall time (scaled by batch*count) instead of the
        analytic roofline prediction;
      * calibration — GEMMs without an exact entry get the analytic time
        scaled by the median measured/analytic ratio over all entries, so
        measured and modeled GEMMs stay comparable inside one step_time sum.

    On a real TPU the calibration ratio is the model's systematic error
    (~1-2x); on this CPU container (interpret-mode timings vs TPU analytic
    constants) it is large, but uniform — relative rankings survive.
    """

    hw_name: str
    # (m, k, n, dtype_bytes) -> measured seconds per single GEMM call
    points: Dict[Tuple[int, int, int, int], float]
    calibration: float = 1.0

    @classmethod
    def from_cache(cls, cache=None,
                   hw_name: str = "tpu_v5e") -> "Optional[MeasuredProfile]":
        """Build from a TuningCache (default: the process default cache).
        Returns None when the cache has no matmul entries for `hw_name`."""
        # Deferred import: core must stay importable without tuning and
        # tuning.search imports the kernels, which import core.
        from ..tuning.cache import get_default_cache

        cache = cache if cache is not None else get_default_cache()
        hw = get_hardware(hw_name)
        points: Dict[Tuple[int, int, int, int], float] = {}
        ratios = []
        for entry in cache.by_op("matmul", hw_name):
            m, k, n = entry.shape
            dtype_bytes = _DTYPE_BYTES.get(entry.dtype, 2)
            measured_s = entry.time_us * 1e-6
            points[(m, k, n, dtype_bytes)] = measured_s
            analytic = estimate(GEMM("cal", m, k, n, dtype_bytes=dtype_bytes), hw)
            if analytic.time_s > 0:
                ratios.append(measured_s / analytic.time_s)
        if not points:
            return None
        return cls(hw_name=hw_name, points=dict(points),
                   calibration=statistics.median(ratios) if ratios else 1.0)

    def measured_time(self, gemm: GEMM) -> Optional[float]:
        """Measured seconds for `gemm` (batch*count folded in), or None."""
        t = self.points.get((gemm.m, gemm.k, gemm.n, gemm.dtype_bytes))
        if t is None:
            return None
        return t * gemm.batch * gemm.count

    def blend(self, gemm: GEMM, analytic_s: float) -> Tuple[float, bool]:
        """(time_s, was_measured): exact measurement if available, else the
        calibrated analytic prediction."""
        t = self.measured_time(gemm)
        if t is not None:
            return t, True
        return analytic_s * self.calibration, False


_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1,
                "float8_e4m3fn": 1, "float8_e5m2": 1}


def estimate(gemm: GEMM, hw: Optional[Hardware] = None,
             profile: Optional[MeasuredProfile] = None) -> GEMMEstimate:
    hw = hw or get_hardware()
    util = q.tile_utilization(gemm.m, gemm.n, gemm.k, hw, gemm.dtype_bytes)
    weff = q.wave_efficiency(gemm.m, gemm.n, hw, gemm.batch)
    eff_flops = hw.peak_flops * util * weff
    compute_s = gemm.flops / eff_flops
    memory_s = gemm.bytes_moved / hw.hbm_bw
    over_s = hw.launch_overhead * gemm.count
    time_s = max(compute_s, memory_s, over_s)
    bound = (
        "compute"
        if time_s == compute_s
        else ("memory" if time_s == memory_s else "overhead")
    )
    if profile is not None:
        time_s, measured = profile.blend(gemm, time_s)
        if measured:
            bound = "measured"
    return GEMMEstimate(
        gemm=gemm,
        time_s=time_s,
        compute_s=compute_s,
        memory_s=memory_s,
        tile_util=util,
        wave_eff=weff,
        achieved_tflops=gemm.flops / time_s / 1e12,
        bound=bound,
    )


def estimate_many(gemms: list[GEMM], hw: Optional[Hardware] = None,
                  profile: Optional[MeasuredProfile] = None) -> list[GEMMEstimate]:
    hw = hw or get_hardware()
    return [estimate(g, hw, profile) for g in gemms]


def total_time(gemms: list[GEMM], hw: Optional[Hardware] = None,
               profile: Optional[MeasuredProfile] = None) -> float:
    return sum(e.time_s for e in estimate_many(gemms, hw, profile))


def throughput_tflops(gemms: list[GEMM], hw: Optional[Hardware] = None,
                      profile: Optional[MeasuredProfile] = None) -> float:
    """End-to-end achieved TFLOP/s over a GEMM set (the paper's y-axis)."""
    t = total_time(gemms, hw, profile)
    f = sum(g.flops for g in gemms)
    return f / t / 1e12 if t > 0 else 0.0


# --- precision pricing ----------------------------------------------------------------

def precision_candidates(gemm: GEMM, hw: Optional[Hardware] = None,
                         dtypes: Tuple[str, ...] = ("bfloat16", "int8"),
                         profile: Optional[MeasuredProfile] = None,
                         ) -> Dict[str, GEMMEstimate]:
    """Price the same GEMM at each storage precision.

    Only `dtype_bytes` changes per candidate: the model credits low
    precision with its bandwidth win (and the int8 sublane granule via
    tile_utilization), not a higher MXU issue rate — conservative, since
    the paper's bandwidth-bound serving GEMMs are where the bytes dominate.
    """
    hw = hw or get_hardware()
    return {
        dt: estimate(
            dataclasses.replace(gemm, dtype_bytes=_DTYPE_BYTES[dt]),
            hw, profile)
        for dt in dtypes
    }


def recommend_precision(gemm: GEMM, hw: Optional[Hardware] = None,
                        dtypes: Tuple[str, ...] = ("bfloat16", "int8"),
                        min_speedup: float = 1.05,
                        profile: Optional[MeasuredProfile] = None,
                        ) -> Tuple[str, float]:
    """(best_dtype, speedup_vs_dtypes[0]) under the analytic model.

    Sticks with the baseline precision unless a candidate clears
    `min_speedup` — a compute-bound GEMM sees ~1.0x from int8 here and the
    quantization-noise cost isn't worth paying for it.
    """
    ests = precision_candidates(gemm, hw, dtypes, profile)
    base_s = ests[dtypes[0]].time_s
    best = min(ests, key=lambda d: ests[d].time_s)
    speedup = base_s / ests[best].time_s if ests[best].time_s > 0 else 1.0
    if best == dtypes[0] or speedup < min_speedup:
        return dtypes[0], 1.0
    return best, speedup
