"""Analytic GEMM/BMM cost model (paper §III, §V) — hardware-parametric.

Predicted kernel time = max(compute_time, memory_time, launch_overhead) where

  compute_time = padded_flops / (peak_flops * wave_efficiency)
  memory_time  = bytes_moved / hbm_bw

`padded_flops` folds in tensor-core/tile quantization (see quantization.py);
`wave_efficiency` applies only on wave-scheduled hardware (GPUs).  The model
reproduces the paper's Figures 5-10 qualitatively: throughput rises with
arithmetic intensity, dips at misaligned dims and at wave boundaries.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .hardware import Hardware, get_hardware
from . import quantization as q


@dataclasses.dataclass(frozen=True)
class GEMM:
    """C[b](m,n) += A[b](m,k) @ B[b](k,n), `batch` independent problems.

    `name` ties the GEMM back to its transformer module (Table II).
    `weight_bytes` lets callers mark B as a resident weight (counted once per
    step for memory-traffic purposes regardless of batch).
    """

    name: str
    m: int
    k: int
    n: int
    batch: int = 1
    dtype_bytes: int = 2
    weight_is_b: bool = True  # B is a weight matrix (vs. activation BMM)
    count: int = 1  # how many times this GEMM occurs (e.g. per layer)

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n * self.batch * self.count

    @property
    def bytes_moved(self) -> float:
        """HBM traffic assuming A, B, C each move once (no fusion credit)."""
        a = self.m * self.k
        b = self.k * self.n
        c = self.m * self.n
        return (a + b + c) * self.batch * self.dtype_bytes * self.count

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_moved, 1.0)


@dataclasses.dataclass(frozen=True)
class GEMMEstimate:
    gemm: GEMM
    time_s: float
    compute_s: float
    memory_s: float
    tile_util: float
    wave_eff: float
    achieved_tflops: float
    bound: str  # "compute" | "memory" | "overhead"

    @property
    def efficiency(self) -> float:
        """Achieved/peak — the quantity the paper plots."""
        return self.tile_util * self.wave_eff


def estimate(gemm: GEMM, hw: Optional[Hardware] = None) -> GEMMEstimate:
    hw = hw or get_hardware()
    util = q.tile_utilization(gemm.m, gemm.n, gemm.k, hw, gemm.dtype_bytes)
    weff = q.wave_efficiency(gemm.m, gemm.n, hw, gemm.batch)
    eff_flops = hw.peak_flops * util * weff
    compute_s = gemm.flops / eff_flops
    memory_s = gemm.bytes_moved / hw.hbm_bw
    over_s = hw.launch_overhead * gemm.count
    time_s = max(compute_s, memory_s, over_s)
    bound = (
        "compute"
        if time_s == compute_s
        else ("memory" if time_s == memory_s else "overhead")
    )
    return GEMMEstimate(
        gemm=gemm,
        time_s=time_s,
        compute_s=compute_s,
        memory_s=memory_s,
        tile_util=util,
        wave_eff=weff,
        achieved_tflops=gemm.flops / time_s / 1e12,
        bound=bound,
    )


def estimate_many(gemms: list[GEMM], hw: Optional[Hardware] = None) -> list[GEMMEstimate]:
    hw = hw or get_hardware()
    return [estimate(g, hw) for g in gemms]


def total_time(gemms: list[GEMM], hw: Optional[Hardware] = None) -> float:
    return sum(e.time_s for e in estimate_many(gemms, hw))


def throughput_tflops(gemms: list[GEMM], hw: Optional[Hardware] = None) -> float:
    """End-to-end achieved TFLOP/s over a GEMM set (the paper's y-axis)."""
    t = total_time(gemms, hw)
    f = sum(g.flops for g in gemms)
    return f / t / 1e12 if t > 0 else 0.0
