"""Core co-design engine: the paper's contribution as a composable library.

  hardware          — accelerator descriptions (TPU v5e target, paper GPUs)
  quantization      — tile/wave/shard quantization math (paper §III-B, §VI-B)
  gemm_model        — analytic GEMM/BMM cost model (paper §V figures)
  transformer_gemms — Table II mapping, generalized to all assigned families
  advisor           — shape rule checks + nearby-shape search (paper §VI-B, §VII)
  roofline          — three-term roofline from compiled XLA artifacts
"""
from .hardware import Hardware, TPU_V5E, A100_40GB, V100_16GB, H100_SXM, get_hardware
from .gemm_model import GEMM, GEMMEstimate, MeasuredProfile, estimate, estimate_many, throughput_tflops, total_time
from .transformer_gemms import layer_gemms, model_gemms, training_flops, vanilla_forward_flops
from .advisor import advise, best_combined, check_alignment, propose, score, step_time, Finding, Proposal
from .roofline import RooflineReport, build_report, collective_bytes, to_row
from . import quantization

__all__ = [
    "Hardware", "TPU_V5E", "A100_40GB", "V100_16GB", "H100_SXM", "get_hardware",
    "GEMM", "GEMMEstimate", "MeasuredProfile", "estimate", "estimate_many", "throughput_tflops", "total_time",
    "layer_gemms", "model_gemms", "training_flops", "vanilla_forward_flops",
    "advise", "best_combined", "check_alignment", "propose", "score", "step_time", "Finding", "Proposal",
    "RooflineReport", "build_report", "collective_bytes", "to_row",
    "quantization",
]
