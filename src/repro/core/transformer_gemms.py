"""Transformer → GEMM decomposition (paper Table II), generalized.

The paper enumerates the GEMMs of a vanilla decoder layer.  We extend the
mapping to every assigned architecture family so the same analytic machinery
(cost model, advisor, roofline) covers GQA, MLA, MoE, SSD, hybrid and
enc-dec stacks.  All sizes are *per-shard* with `t`-way tensor parallelism,
mirroring the paper's "hidden size per GPU" convention (§III-C).

Modes:
  train/prefill: m = b*s tokens flow through every projection;
  decode:        m = b (one new token), attention BMMs read an s-long cache.
"""
from __future__ import annotations

import math
from typing import List

from ..configs.base import ModelConfig
from .gemm_model import GEMM
from .quantization import ceil_div


def _attn_gemms(cfg: ModelConfig, b: int, s: int, t: int, decode: bool,
                prefix: str = "", count: int = 1) -> List[GEMM]:
    """GQA/MHA attention GEMMs for one layer (Table II rows 3-6)."""
    h = cfg.d_model
    hd = cfg.head_dim
    a = max(cfg.num_heads // t, 1)
    kv = max(cfg.num_kv_heads // t, 1)
    m = b * (1 if decode else s)
    s_kv = s  # cache length in decode; sequence length otherwise
    out: List[GEMM] = [
        GEMM(prefix + "qkv_transform", m, h, (a + 2 * kv) * hd, count=count),
        GEMM(prefix + "attn_score", (1 if decode else s), hd, s_kv, batch=b * a, count=count),
        GEMM(prefix + "attn_over_value", (1 if decode else s), s_kv, hd, batch=b * a,
             weight_is_b=False, count=count),
        GEMM(prefix + "attn_out_proj", m, a * hd, h, count=count),
    ]
    return out


def _mla_gemms(cfg: ModelConfig, b: int, s: int, t: int, decode: bool) -> List[GEMM]:
    """DeepSeek-V3 Multi-head Latent Attention GEMMs.

    Train/prefill uses the naive (decompressed) path; decode uses the
    weight-absorbed path against the rank-(kv_lora+rope) latent cache.
    """
    h = cfg.d_model
    a = max(cfg.num_heads // t, 1)
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    vd = cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    m = b * (1 if decode else s)
    g: List[GEMM] = [
        GEMM("mla_q_down", m, h, qr),
        GEMM("mla_q_up", m, qr, a * (nope + rope)),
        GEMM("mla_kv_down", m, h, kvr + rope),
    ]
    if decode:
        # absorbed path: queries hit the latent cache directly
        g += [
            GEMM("mla_q_absorb", 1, nope, kvr, batch=b * a),
            GEMM("mla_score_latent", 1, kvr + rope, s, batch=b * a),
            GEMM("mla_attn_over_latent", 1, s, kvr, batch=b * a, weight_is_b=False),
            GEMM("mla_v_absorb", 1, kvr, vd, batch=b * a),
        ]
    else:
        g += [
            GEMM("mla_k_up", m, kvr, a * nope),
            GEMM("mla_v_up", m, kvr, a * vd),
            GEMM("mla_score", s, nope + rope, s, batch=b * a),
            GEMM("mla_attn_over_value", s, s, vd, batch=b * a, weight_is_b=False),
        ]
    g.append(GEMM("mla_out_proj", m, a * vd, h))
    return g


def _mlp_gemms(cfg: ModelConfig, b: int, s: int, t: int, decode: bool,
               d_ff: int | None = None, prefix: str = "", count: int = 1) -> List[GEMM]:
    h = cfg.d_model
    f = max((d_ff if d_ff is not None else cfg.d_ff) // t, 1)
    m = b * (1 if decode else s)
    g = [GEMM(prefix + "mlp_up", m, h, f, count=count)]
    if cfg.mlp_type == "swiglu":
        g.append(GEMM(prefix + "mlp_gate", m, h, f, count=count))
    g.append(GEMM(prefix + "mlp_down", m, f, h, count=count))
    return g


def _moe_gemms(cfg: ModelConfig, b: int, s: int, t: int, decode: bool) -> List[GEMM]:
    """MoE layer: router + routed experts (EP over `t`) + shared experts."""
    h = cfg.d_model
    m = b * (1 if decode else s)
    e_local = max(cfg.num_experts // t, 1)
    cap = cfg.moe_capacity_factor
    tokens_per_expert = max(int(math.ceil(m * cfg.top_k * cap / cfg.num_experts)), 1)
    f = cfg.moe_d_ff  # experts are NOT tp-sharded internally under EP
    g = [GEMM("moe_router", m, h, cfg.num_experts)]
    mats_up = 2 if cfg.mlp_type == "swiglu" else 1
    g.append(GEMM("moe_expert_up", tokens_per_expert, h, f, batch=e_local, count=mats_up))
    g.append(GEMM("moe_expert_down", tokens_per_expert, f, h, batch=e_local))
    if cfg.num_shared_experts:
        g += _mlp_gemms(cfg, b, s, t, decode, d_ff=cfg.moe_d_ff * cfg.num_shared_experts,
                        prefix="moe_shared_")
    return g


def _ssd_gemms(cfg: ModelConfig, b: int, s: int, t: int, decode: bool) -> List[GEMM]:
    """Mamba2 SSD (state-space duality) chunked dual form.

    The intra-chunk computation is exactly an attention-like pair of BMMs with
    chunk length Q in place of s and (head_dim P, state N) in place of
    (h/a, h/a) — the paper's BMM sizing rules apply with Q, P, N as the knobs.
    """
    h = cfg.d_model
    di = max(cfg.ssm_d_inner // t, 1)
    N = cfg.ssm_state
    P = cfg.ssm_head_dim
    nh = max(di // P, 1)
    ng = cfg.ssm_ngroups
    proj_in = 2 * di + 2 * ng * N + nh  # z, x, B, C, dt
    if decode:
        # recurrent single-step: state update is (nh) batched (P,N) outer
        # products + dot; dominated by in/out projections.
        return [
            GEMM("ssd_in_proj", b, h, proj_in),
            GEMM("ssd_state_update", P, 1, N, batch=b * nh, weight_is_b=False),
            GEMM("ssd_state_read", P, N, 1, batch=b * nh, weight_is_b=False),
            GEMM("ssd_out_proj", b, di, h),
        ]
    Q = cfg.ssm_chunk
    nc = ceil_div(s, Q)
    return [
        GEMM("ssd_in_proj", b * s, h, proj_in),
        # G = C B^T within chunk (per chunk, per group)
        GEMM("ssd_chunk_score", Q, N, Q, batch=b * nc * ng, weight_is_b=False),
        # Y_intra = (G*L) X  (per chunk, per head)
        GEMM("ssd_chunk_over_value", Q, Q, P, batch=b * nc * nh, weight_is_b=False),
        # chunk states: B^T X  (per chunk, per head)
        GEMM("ssd_chunk_state", N, Q, P, batch=b * nc * nh, weight_is_b=False),
        # inter-chunk: C h_state  (per chunk, per head)
        GEMM("ssd_state_read", Q, N, P, batch=b * nc * nh, weight_is_b=False),
        GEMM("ssd_out_proj", b * s, di, h),
    ]


def layer_gemms(cfg: ModelConfig, b: int, s: int, t: int = 1,
                mode: str = "train", layer: int = 0) -> List[GEMM]:
    """All GEMMs of one layer of `cfg` at microbatch b, sequence s, TP t."""
    decode = mode == "decode"
    g: List[GEMM] = []
    if cfg.family in ("ssm", "hybrid"):
        g += _ssd_gemms(cfg, b, s, t, decode)
        if (cfg.family == "hybrid" and cfg.hybrid_attn_every
                and layer % cfg.hybrid_attn_every == cfg.hybrid_attn_every - 1):
            # zamba2 shared attention+MLP block application
            g += _attn_gemms(cfg, b, s, t, decode, prefix="shared_")
            g += _mlp_gemms(cfg, b, s, t, decode, prefix="shared_")
        return g
    if cfg.attn_type == "mla":
        g += _mla_gemms(cfg, b, s, t, decode)
    else:
        g += _attn_gemms(cfg, b, s, t, decode)
    if cfg.is_moe_layer(layer):
        g += _moe_gemms(cfg, b, s, t, decode)
    else:
        g += _mlp_gemms(cfg, b, s, t, decode)
    return g


def model_gemms(cfg: ModelConfig, b: int, s: int, t: int = 1,
                mode: str = "train") -> List[GEMM]:
    """All GEMMs of the full model (layers + logit head + enc-dec extras)."""
    decode = mode == "decode"
    out: List[GEMM] = []
    for layer in range(cfg.num_layers):
        out += layer_gemms(cfg, b, s, t, mode, layer)
    # encoder stack + cross attention (whisper)
    if cfg.is_encoder_decoder and not decode:
        se = cfg.encoder_seq or s
        for _ in range(cfg.num_encoder_layers):
            out += _attn_gemms(cfg, b, se, t, False, prefix="enc_")
            out += _mlp_gemms(cfg, b, se, t, False, prefix="enc_")
        for _ in range(cfg.num_layers):
            out += _cross_attn_gemms(cfg, b, s, se, t, decode)
    elif cfg.is_encoder_decoder and decode:
        se = cfg.encoder_seq or 1500
        for _ in range(cfg.num_layers):
            out += _cross_attn_gemms(cfg, b, 1, se, t, True)
    # logit head (Table II "Linear Output"); vocab is TP-sharded
    m = b * (1 if decode else s)
    out.append(GEMM("logit_layer", m, cfg.d_model, max(cfg.vocab_size // t, 1)))
    return out


def _cross_attn_gemms(cfg: ModelConfig, b: int, sq: int, skv: int, t: int,
                      decode: bool) -> List[GEMM]:
    h = cfg.d_model
    hd = cfg.head_dim
    a = max(cfg.num_heads // t, 1)
    m = b * sq
    return [
        GEMM("xattn_q", m, h, a * hd),
        GEMM("xattn_kv", b * skv, h, 2 * a * hd),
        GEMM("xattn_score", sq, hd, skv, batch=b * a, weight_is_b=False),
        GEMM("xattn_over_value", sq, skv, hd, batch=b * a, weight_is_b=False),
        GEMM("xattn_out", m, a * hd, h),
    ]


def training_flops(cfg: ModelConfig, b: int, s: int) -> float:
    """Paper's 24bsh^2(1 + s/6h) generalized: fwd FLOPs x3 for fwd+bwd."""
    fwd = sum(g.flops for g in model_gemms(cfg, b, s, t=1, mode="train"))
    return 3.0 * fwd


def vanilla_forward_flops(h: int, b: int, s: int) -> float:
    """The paper's closed form for one vanilla layer: 24bsh^2 + 4bs^2h."""
    return 24.0 * b * s * h * h + 4.0 * b * s * s * h
