"""Structural analyzer for optimized HLO text: FLOPs / HBM bytes / collective
bytes with while-loop trip-count multiplication.

Why: `compiled.cost_analysis()` (XLA HloCostAnalysis) visits a while body
ONCE, so any model that scans over layers or gradient-accumulation
microbatches under-reports FLOPs/bytes by the trip count (we measured ~50x on
a 24-layer scan x 16 microbatches).  This module parses `compiled.as_text()`
and walks the computation graph, multiplying loop bodies by their trip counts
(taken from XLA's own `backend_config={"known_trip_count":{"n":...}}`),
giving honest per-chip roofline terms.

Counting rules:
  flops       — dot ops: 2 * prod(output dims) * prod(lhs contracting dims),
                with operand shapes resolved through a per-computation symbol
                table; recursion into fusion/call/while(xN)/conditional(max).
  hbm bytes   — per top-level instruction: operand + output buffer sizes;
                fusion bodies are internal (registers/VMEM) and counted at
                the op boundary; parameter/constant/tuple plumbing skipped.
  collectives — output-shape bytes per all-gather / all-reduce /
                reduce-scatter / all-to-all / collective-permute, x trips.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy",
    # dtype legalization plumbing: XLA:CPU upcasts bf16 compute to f32 with
    # convert chains that a TPU build would not emit; tensor traffic is
    # already charged at producers/consumers.
    "convert",
    # control plumbing: bodies are walked and charged separately
    "while", "conditional", "call", "optimization-barrier",
}


def _shape_list(txt: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(txt):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(txt: str) -> float:
    total = 0
    for dtype, dims in _shape_list(txt):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return float(total)


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_txt: str        # output type (single shape or tuple text)
    operands_txt: str   # inside the opcode's parens
    attrs_txt: str      # after the closing paren (metadata, configs)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    symbols: Dict[str, str] = dataclasses.field(default_factory=dict)


def _match_paren(s: str, start: int) -> int:
    """Index just past the paren that closes s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        c = s[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\s*\(")


def _parse_instr(line: str) -> Optional[Instr]:
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    # output type: tuple "( ... )" (may contain /*index=N*/ comments) or shape
    if rest.startswith("("):
        end = _match_paren(rest, 0)
        out_txt = rest[:end]
        rest = rest[end:]
    else:
        sm = re.match(r"\s*[a-z]\d*[a-z0-9]*\[[0-9,]*\](?:\{[^{}]*\})?", rest)
        if not sm:
            return None
        out_txt = sm.group(0)
        rest = rest[sm.end():]
    om = _OPCODE_RE.match(rest)
    if not om:
        return None
    opcode = om.group(1)
    p0 = om.end() - 1
    p1 = _match_paren(rest, p0)
    return Instr(name, opcode, out_txt, rest[p0 + 1:p1 - 1], rest[p1:])


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            s = line.lstrip()
            if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                is_entry = s.startswith("ENTRY")
                if is_entry:
                    s = s[len("ENTRY"):].lstrip()
                name = s.split(" ", 1)[0].split("(", 1)[0].lstrip("%")
                if name in ("HloModule",):
                    continue
                cur = Computation(name)
                if is_entry:
                    entry = name
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs.append(ins)
            cur.symbols[ins.name] = ins.out_txt
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


_CALLED_RE = re.compile(
    r"(?:calls|to_apply|condition|body|branch_computations)="
    r"(?:{([^}]*)}|%?([\w.\-]+))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _called_comps(attrs: str) -> List[str]:
    out = []
    for m in _CALLED_RE.finditer(attrs):
        if m.group(1) is not None:
            out += [x.strip().lstrip("%") for x in m.group(1).split(",")]
        else:
            out.append(m.group(2))
    return out


def _trip_count(ins: Instr, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(ins.attrs_txt)
    if m:
        return max(int(m.group(1)), 1)
    # fallback: largest s32 scalar constant in the condition computation
    for m2 in _CALLED_RE.finditer(ins.attrs_txt):
        pass
    cond = None
    cm = re.search(r"condition=%?([\w.\-]+)", ins.attrs_txt)
    if cm:
        cond = comps.get(cm.group(1))
    best = 1
    if cond:
        for i2 in cond.instrs:
            if i2.opcode == "constant" and "s32[]" in i2.out_txt:
                m3 = re.search(r"^\s*(-?\d+)", i2.operands_txt)
                if m3:
                    best = max(best, int(m3.group(1)))
    return best


def _operand_bytes_list(ins: Instr, comp: Computation) -> List[float]:
    out = []
    for name in _OPERAND_RE.findall(ins.operands_txt):
        shape = comp.symbols.get(name)
        if shape:
            out.append(_shape_bytes(shape))
    return out


def _operand_bytes(ins: Instr, comp: Computation) -> float:
    return float(sum(_operand_bytes_list(ins, comp)))


def _is_buffer_update(ins: Instr) -> bool:
    """Lowered in-place updates carry the originating jax op in metadata
    (dynamic_update_slice / scatter); elementwise fusions do not."""
    return ("dynamic_update_slice" in ins.attrs_txt
            or "/scatter" in ins.attrs_txt)


def _fusion_bytes(ins: Instr, comp: Computation, has_dus: bool = False) -> float:
    """Kind-aware fusion traffic.

    kLoop fusions stream one element per operand per output element — an
    operand accessed through a dynamic-slice/broadcast inside the fusion
    contributes ~min(operand, output) bytes, NOT its full size (charging the
    full buffer made every scanned layer 'read' the whole (L, ...) stack).
    kInput fusions (reduce roots) legitimately read their full operands.
    Buffer updates (DUS in the body — scan ys-stacking) use the aliasing
    rule: traffic ~ 2x the update payload, the big buffer is aliased.
    """
    if has_dus or _is_buffer_update(ins):
        return _buffer_update_bytes(ins, comp)
    out_b = _shape_bytes(ins.out_txt)
    ops = _operand_bytes_list(ins, comp)
    if "kind=kLoop" in ins.attrs_txt:
        return out_b + sum(min(o, out_b) for o in ops)
    return out_b + sum(ops)


def _buffer_update_bytes(ins: Instr, comp: Computation) -> float:
    """Aliasing-aware traffic for in-place buffer updates (dynamic-update-
    slice and DUS-rooted fusions): XLA aliases the big buffer in/out, so real
    HBM traffic is ~2x the update payload, not the whole buffer.  All
    buffer-sized operands are excluded (CPU legalization can keep both an
    f32 and a bf16 copy of the same logical buffer)."""
    out_b = _shape_bytes(ins.out_txt)
    ops = _operand_bytes_list(ins, comp)
    big = [o for o in ops if o >= out_b * 0.45]  # buffer-like (any dtype width)
    if big:
        small = sum(o for o in ops if o < out_b * 0.45)
        return 2.0 * small
    return out_b + sum(ops)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_shapes = _shape_list(ins.out_txt)
    if not out_shapes:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", ins.attrs_txt)
    ops = _OPERAND_RE.findall(ins.operands_txt)
    lhs_shape = comp.symbols.get(ops[0]) if ops else None
    if not m or not lhs_shape:
        return 2.0 * out_elems
    lhs_dims = _shape_list(lhs_shape)
    if not lhs_dims:
        return 2.0 * out_elems
    dims = lhs_dims[0][1]
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            k *= dims[idx]
    return 2.0 * out_elems * k


def _is_s2(out_txt: str) -> bool:
    """True if a shape has two equal >=2048 dims — the s^2 signature of
    naive attention score/mask/softmax tensors (logits (s, v) have unequal
    big dims and are excluded)."""
    for _, dims in _shape_list(out_txt):
        big = [d for d in dims if d >= 2048]
        if len(big) >= 2 and len(set(big)) < len(big):
            return True
    return False


@dataclasses.dataclass
class Counts:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    dots: float = 0.0
    loops: Dict[str, int] = dataclasses.field(default_factory=dict)
    s2_bytes: float = 0.0  # bytes moved through s^2 attention tensors

    def scaled(self, k: float) -> "Counts":
        return Counts(self.flops * k, self.bytes * k,
                      {c: v * k for c, v in self.coll.items()},
                      self.dots * k, dict(self.loops), self.s2_bytes * k)

    def add(self, o: "Counts"):
        self.flops += o.flops
        self.bytes += o.bytes
        for c in self.coll:
            self.coll[c] += o.coll[c]
        self.dots += o.dots
        self.loops.update(o.loops)
        self.s2_bytes += o.s2_bytes

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes, "dots": self.dots,
                "coll": dict(self.coll), "coll_total": self.coll_total,
                "loops": dict(self.loops)}


def _analyze(comps: Dict[str, Computation], name: str,
             memo: Dict[str, Counts]) -> Counts:
    if name in memo:
        return memo[name]
    memo[name] = Counts()  # cycle guard
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    total = Counts()
    for ins in comp.instrs:
        op = ins.opcode
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if op == "while":
            trips = _trip_count(ins, comps)
            bm = re.search(r"body=%?([\w.\-]+)", ins.attrs_txt)
            if bm:
                sub = _analyze(comps, bm.group(1), memo)
                total.add(sub.scaled(trips))
                total.loops[ins.name] = trips
            continue
        if op == "conditional":
            subs = [_analyze(comps, b, memo) for b in _called_comps(ins.attrs_txt)]
            if subs:
                total.add(max(subs, key=lambda s: s.flops + s.bytes))
            continue
        if op in ("call", "async-start"):
            for c in _called_comps(ins.attrs_txt):
                total.add(_analyze(comps, c, memo))
            continue
        if op == "fusion":
            plumbing_only = True
            has_dus = False
            for c in _called_comps(ins.attrs_txt):
                sub = _analyze(comps, c, memo)
                total.flops += sub.flops
                total.dots += sub.dots
                for k in total.coll:
                    total.coll[k] += sub.coll[k]
                body = comps.get(c)
                if body is not None:
                    has_dus |= any(i.opcode == "dynamic-update-slice"
                                   for i in body.instrs)
                if body is None or any(
                        i.opcode not in ("convert", "bitcast", "parameter",
                                         "copy", "constant", "reshape",
                                         "transpose")
                        for i in body.instrs):
                    plumbing_only = False
            if not plumbing_only:
                _charge(total, ins, _fusion_bytes(ins, comp, has_dus))
            continue
        if base in _COLLECTIVES:
            nbytes = _shape_bytes(ins.out_txt)
            total.coll[base] += nbytes
            total.bytes += nbytes + _operand_bytes(ins, comp)
            continue
        if op in ("dot", "convolution"):
            total.flops += _dot_flops(ins, comp)
            total.dots += 1
            _charge(total, ins,
                    _shape_bytes(ins.out_txt) + _operand_bytes(ins, comp))
            continue
        if op in _SKIP_BYTES_OPS:
            continue
        if op == "dynamic-slice":
            # reads only the slice; the big operand buffer is not streamed
            _charge(total, ins, 2.0 * _shape_bytes(ins.out_txt))
            continue
        if op in ("dynamic-update-slice", "scatter") or (
                op == "select" and _is_buffer_update(ins)):
            _charge(total, ins, _buffer_update_bytes(ins, comp))
            continue
        if op in ("custom-call",):
            _charge(total, ins, _shape_bytes(ins.out_txt))
            continue
        _charge(total, ins,
                _shape_bytes(ins.out_txt) + _operand_bytes(ins, comp))
    memo[name] = total
    return total


def _charge(total: Counts, ins: Instr, nbytes: float):
    total.bytes += nbytes
    if _is_s2(ins.out_txt):
        total.s2_bytes += nbytes


def analyze_hlo(text: str) -> Counts:
    """Trip-count-aware Counts for the entry computation of an HLO module."""
    comps, entry = parse_module(text)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else ""
    return _analyze(comps, entry, {})
