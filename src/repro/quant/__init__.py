"""Numeric quantization helpers: int8 and emulated-fp8 value compression.

Naming note — this package is about *numeric* quantization (compressing
tensor values to fewer bits); `repro.core.quantization` is about *tile/wave*
quantization (utilization loss from shape-vs-hardware-tile mismatch, paper
§III-B/§VI-B).  The two concepts share a name in the literature but nothing
else; keep imports explicit to avoid collisions.

Conventions (match the Pallas int8 idiom):

  * symmetric absmax scaling: ``scale = max|x| / 127``, ``q = round(x/scale)``
    clipped to [-127, 127] (−128 unused so the range is symmetric),
  * scales are float32 and live *alongside* the int8 payload — weights carry
    one scale per output channel, activations one per row, KV-cache entries
    one per (token, kv_head),
  * fp8 is *emulated*: values are rounded through ``float8_e4m3fn`` /
    ``float8_e5m2`` storage and widened back, so the matmul itself runs on
    the bf16 MXU path.  This reproduces fp8 numerics (and HBM bytes, when
    stored) without requiring fp8 matmul units.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
# emulated fp8 storage formats (both 1 byte; e4m3 = more mantissa,
# e5m2 = more range)
FP8_DTYPES = ("float8_e4m3fn", "float8_e5m2")
# smallest scale we divide by; absmax-zero slices quantize to all-zeros
EPS = 1e-8


class QuantizedTensor(NamedTuple):
    """An int8 payload plus the float32 scales that de-quantize it.

    ``axis`` is the *contraction-reduced* axis the scales were computed over
    (scales have that axis collapsed to size 1), kept so ``dequantize``
    can broadcast without re-deriving it.
    """

    q: jax.Array       # int8 values
    scale: jax.Array   # float32, broadcastable against q
    axis: int          # axis reduced when computing absmax


def quantize_int8(x, axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-slice int8 quantization.

    Returns ``(q, scale)`` with ``q`` int8 and ``scale`` float32 shaped like
    ``x`` with ``axis`` collapsed to 1, such that ``q * scale ~= x``.
    """
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, EPS) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, dtype=jnp.float32):
    """Inverse of `quantize_int8`: widen and re-scale."""
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def quantize_weight(w, dtype: str = "int8") -> QuantizedTensor:
    """Quantize a (k, n) weight matrix per *output channel* (reduce over k).

    Per-channel scales are the standard accuracy/throughput sweet spot for
    weight-only int8: each output column sees its own dynamic range, and the
    de-scale folds into the GEMM epilogue as a (1, n) row-vector multiply.
    """
    if dtype == "int8":
        q, scale = quantize_int8(w, axis=-2)
        return QuantizedTensor(q=q, scale=scale, axis=-2)
    if dtype in FP8_DTYPES:
        # fp8 emulation keeps a trivial all-ones scale: the rounding itself
        # is the compression, the storage dtype carries the exponent
        q = w.astype(jnp.dtype(dtype))
        scale = jnp.ones((1,) * w.ndim, jnp.float32)
        return QuantizedTensor(q=q, scale=scale, axis=-2)
    raise ValueError(
        f"unknown quant dtype {dtype!r}; valid: ['int8', *{list(FP8_DTYPES)}]")


def fp8_round_trip(x, fp8_dtype: str = "float8_e4m3fn"):
    """Round `x` through fp8 storage and widen back to its input dtype.

    This is the emulation primitive: the value grid (and therefore the
    numerics) are fp8's, while the compute that follows stays on the bf16 /
    f32 MXU path.
    """
    if fp8_dtype not in FP8_DTYPES:
        raise ValueError(
            f"unknown fp8 dtype {fp8_dtype!r}; valid: {list(FP8_DTYPES)}")
    return x.astype(jnp.dtype(fp8_dtype)).astype(x.dtype)


# -- KV-cache quantization -----------------------------------------------------
def quantize_kv(x) -> Tuple[jax.Array, jax.Array]:
    """Quantize a KV tensor (..., kv_heads, head_dim) per (token, kv_head).

    Returns (int8 values, float32 scales with head_dim dropped) — the layout
    the quantized SlotPool/PagedPool leaves carry ("k"/"v" int8 plus
    "k_scale"/"v_scale" float32, see models/blocks._kv_cache_shape).
    """
    q, scale = quantize_int8(x, axis=-1)
    return q, scale[..., 0]


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    """Inverse of `quantize_kv`: scales broadcast back over head_dim."""
    return dequantize_int8(q, scale[..., None], dtype)


def kv_bytes_per_token(num_kv_heads: int, head_dim: int,
                       kv_dtype: str = "auto",
                       compute_bytes: int = 2) -> int:
    """Per-token-per-layer KV bytes (K and V), the slots-per-GiB numerator.

    int8 stores 1 byte per element plus one f32 scale per (token, head) for
    each of K and V; "auto" stores the compute dtype.
    """
    elems = 2 * num_kv_heads * head_dim  # K and V
    if kv_dtype == "int8":
        return elems * 1 + 2 * num_kv_heads * 4
    return elems * compute_bytes
