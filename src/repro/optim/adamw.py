"""AdamW (pure JAX) with cosine schedule, global-norm clipping, and an
int8 row-quantized moment variant (`adamw8bit`).

The 8-bit variant is the distributed-optimization trick that makes the
340B/671B optimizer state fit 16 GB/chip at 512 chips: m and v are stored as
int8 IN THE PARAMETER'S SHAPE with a per-row (last-dim absmax) f32 scale.

Shape-preserving quantization is what keeps the state ZeRO-shardable: the
codes take the parameter's own PartitionSpec and the scale its spec minus
the last axis.  (A first version stored flattened (nblocks, 128) codes; the
SPMD partitioner could not relate that sharding to the parameter's, and
every step all-gathered fully dequantized f32 moments — 2.6 TB/chip on
deepseek-v3.  EXPERIMENTS.md §Perf documents the measurement.)
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig


# --- row-wise int8 quantization ------------------------------------------------------

def quantize_i8(x: jax.Array):
    """x (param shape, f32) -> {codes: int8 same shape,
    scale: f32 absmax/127 over the last dim (keepdims)}."""
    if x.ndim == 0:
        x = x[None]
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"codes": codes, "scale": scale.astype(jnp.float32)}


def dequantize_i8(q, shape=None) -> jax.Array:
    out = q["codes"].astype(jnp.float32) * q["scale"]
    if shape is not None:
        out = out.reshape(shape)
    return out


# --- schedules -----------------------------------------------------------------------

def lr_schedule(tc: TrainConfig):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = jnp.minimum(step / max(tc.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - tc.warmup_steps)
                        / max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return tc.learning_rate * warm * (0.1 + 0.9 * cos)
    return lr


# --- AdamW ---------------------------------------------------------------------------

class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt(params, tc: TrainConfig) -> OptState:
    if tc.optimizer == "adamw8bit":
        zeros = jax.tree.map(lambda p: quantize_i8(jnp.zeros_like(p, jnp.float32)), params)
        return OptState(jnp.zeros((), jnp.int32), zeros,
                        jax.tree.map(lambda p: quantize_i8(jnp.zeros_like(p, jnp.float32)), params))
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), z,
                    jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads), g


_QUANT_LEAF = lambda x: isinstance(x, dict) and "codes" in x


def apply_updates(params, grads, state: OptState, tc: TrainConfig,
                  b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = state.step + 1
    lr = lr_schedule(tc)(step)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t
    eightbit = tc.optimizer == "adamw8bit"

    def upd(p, g, m, v):
        if eightbit:
            m_f, v_f = dequantize_i8(m, p.shape), dequantize_i8(v, p.shape)
        else:
            m_f, v_f = m, v
        g = g.astype(jnp.float32)
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * jnp.square(g)
        mh = m_f / bc1
        vh = v_f / bc2
        pn = p.astype(jnp.float32)
        pn = pn - lr * (mh / (jnp.sqrt(vh) + eps) + tc.weight_decay * pn)
        if eightbit:
            return pn.astype(p.dtype), quantize_i8(m_f), quantize_i8(v_f)
        return pn.astype(p.dtype), m_f, v_f

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree_util.tree_flatten(state.m, is_leaf=_QUANT_LEAF)[0]
    flat_v = jax.tree_util.tree_flatten(state.v, is_leaf=_QUANT_LEAF)[0]
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
