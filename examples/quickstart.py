"""Quickstart: the co-design workflow on the paper's GPT-3 2.7B case study.

    PYTHONPATH=src python examples/quickstart.py

1. Check a model shape against the hardware rules (paper §VI-B on TPU v5e).
2. Get ranked nearby-shape proposals at ~constant parameter count (Fig. 1).
3. Sanity-train the original and the advised shape for a few steps on CPU.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.gpt3_2p7b import VARIANTS
from repro.core import advisor
from repro.data.pipeline import make_batch
from repro.models import init_lm
from repro.optim.adamw import init_opt
from repro.train.train_step import make_train_step

c0 = VARIANTS["c0"]  # Brown et al. shape: h=2560, a=32 (head_dim 80)

print("=== 1. alignment report (TPU v5e rules) ===")
for f in advisor.check_alignment(c0, tp=16):
    print(f"  [{f.severity:4s}] {f.rule}: {f.message}")

print("\n=== 2. shape proposals (param-preserving) ===")
for p in advisor.advise(c0, microbatch=4)[:5]:
    print(f"  {p.predicted_speedup:.3f}x  {p.change}  "
          f"(params {p.param_delta:+.2%}, {p.tflops:.0f} TF/s analytic)")
best = advisor.best_combined(c0)
print(f"  combined: {best.predicted_speedup:.3f}x via '{best.change}'")

print("\n=== 3. tiny training sanity (reduced config, CPU) ===")
import dataclasses
tiny = dataclasses.replace(c0, num_layers=2, d_model=128, num_heads=4,
                           num_kv_heads=4, d_ff=512, vocab_size=512,
                           dtype="float32", name="tiny-c0")
tc = TrainConfig(total_steps=20, warmup_steps=2, learning_rate=1e-3)
shape = ShapeConfig("tiny", 128, 4, "train")
params = init_lm(jax.random.PRNGKey(0), tiny)
opt = init_opt(params, tc)
step = jax.jit(make_train_step(tiny, tc), donate_argnums=(0, 1))
for i in range(20):
    batch = {k: jnp.asarray(v) for k, v in make_batch(tiny, shape, i).items()}
    params, opt, m = step(params, opt, batch)
    if i % 5 == 0 or i == 19:
        print(f"  step {i:3d} loss {float(m['loss']):.4f}")
print("done — see examples/shape_advisor.py for the full 10-arch sweep")
