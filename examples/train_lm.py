"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
CPU with checkpointing, using the production training stack.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The config is a scaled GPT-family shape chosen WITH the advisor: head_dim
128, d_ff lane-aligned, vocab padded to 50304.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig
from repro.configs.registry import register
from repro.launch import train as train_driver

CFG_100M = ModelConfig(
    name="gpt-100m-aligned", family="dense",
    num_layers=8, d_model=512, num_heads=4, num_kv_heads=4,
    d_ff=2048, vocab_size=50257,  # padded to 50304 automatically
    mlp_type="gelu", norm_type="layernorm", dtype="float32",
)
register(CFG_100M, CFG_100M)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    print(f"params: {CFG_100M.param_count() / 1e6:.1f}M")
    train_driver.main([
        "--arch", "gpt-100m-aligned",
        "--steps", str(args.steps),
        "--global-batch", "2", "--seq-len", "128",
        "--lr", "6e-4", "--checkpoint-every", "100",
        "--checkpoint-dir", "/tmp/repro_100m_ckpt",
        "--log-every", "20",
    ])
