"""Run the hardware-shape advisor over every assigned architecture at the
production parallelism (tp=16), printing findings and the best proposal —
the paper's contribution applied across the model zoo.

    PYTHONPATH=src python examples/shape_advisor.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs.registry import get_config, list_archs
from repro.core import advisor

TP = 16

for name in list_archs(assigned_only=True):
    cfg = get_config(name)
    findings = [f for f in advisor.check_alignment(cfg, tp=TP)
                if f.severity != "ok"]
    print(f"\n=== {name} ({cfg.param_count() / 1e9:.1f}B, family={cfg.family}) ===")
    if not findings:
        print("  all shape rules satisfied at tp=16")
    for f in findings:
        print(f"  [{f.severity:4s}] {f.rule}: {f.message}")
    props = advisor.advise(cfg, tp=TP, microbatch=1)
    for p in props[:2]:
        print(f"  proposal: {p.predicted_speedup:.3f}x  {p.change} "
              f"(params {p.param_delta:+.2%})")
