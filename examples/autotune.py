"""End-to-end autotuning workflow: search -> cache -> tuned kernels ->
measurement-calibrated advisor.

    PYTHONPATH=src python -m examples.autotune [--cache tuning_cache.json]

1. Sweep tile-aligned block candidates for a few matmul shapes and one
   flash-attention shape, timing each (interpret mode on CPU; real kernels
   on a TPU host) and persisting the winners to a JSON tuning cache.
2. Call `matmul(..., tuned=True)` — the wrapper consults the cache and
   dispatches with the measured-best blocks (verified against the oracle).
3. Build a `MeasuredProfile` from the cache and run `advisor.propose`, whose
   step-time predictions are now grounded in the measured timings.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gpt3_2p7b import VARIANTS
from repro.core import advisor
from repro.core.gemm_model import MeasuredProfile
from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.tuning import TuningCache, set_default_cache
from repro.tuning.search import (autotune_flash_attention,
                                 autotune_flash_backward, autotune_fused_mlp,
                                 autotune_int8_matmul, autotune_matmul)

MATMUL_SHAPES = [(256, 256, 256), (256, 512, 256)]
# (m, h, f) for the fused SwiGLU hidden: f = 683 is the 8h/3 heuristic for
# h = 256 — the §VII-B misaligned shape the fused kernel pays padding on
FUSED_MLP_SHAPE = (256, 256, 683)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache", default="tuning_cache.json")
    ap.add_argument("--iters", type=int, default=2)
    args = ap.parse_args()

    print(f"=== 1. block-size search -> {args.cache} ===")
    cache = TuningCache.load(args.cache)
    for m, k, n in MATMUL_SHAPES:
        cfg = autotune_matmul(m, k, n, dtype=jnp.float32, cache=cache,
                              iters=args.iters, warmup=1, max_candidates=6)
        b = cfg.blocks
        print(f"  matmul {m}x{k}x{n}: best blocks "
              f"({b['block_m']},{b['block_n']},{b['block_k']}) "
              f"{cfg.time_us:.0f} us, {cfg.speedup_vs_default:.2f}x vs 128^3 "
              f"({cfg.candidates_tried} candidates)")
    fcfg = autotune_flash_attention(1, 256, 2, 64, cache=cache,
                                    iters=args.iters, warmup=1,
                                    max_candidates=4)
    print(f"  flash b1 s256 a2 d64: best blocks "
          f"({fcfg.blocks['block_q']},{fcfg.blocks['block_kv']}) "
          f"{fcfg.time_us:.0f} us, {fcfg.speedup_vs_default:.2f}x vs 128x128")
    bcfg = autotune_flash_backward(1, 256, 2, 64, cache=cache,
                                   iters=args.iters, warmup=1,
                                   max_candidates=3)
    print(f"  flash_bwd b1 s256 a2 d64: best blocks "
          f"({bcfg.blocks['block_q']},{bcfg.blocks['block_kv']}) "
          f"{bcfg.time_us:.0f} us, {bcfg.speedup_vs_default:.2f}x vs 128x128 "
          f"(attn_impl=\"flash\" training picks this up via tuned=True)")
    m, h, f = FUSED_MLP_SHAPE
    mcfg = autotune_fused_mlp(m, h, f, cache=cache, iters=args.iters,
                              warmup=1, max_candidates=4)
    b = mcfg.blocks
    print(f"  fused_mlp m{m} h{h} f{f} (8h/3-misaligned): best blocks "
          f"({b['block_m']},{b['block_f']},{b['block_k']}) "
          f"{mcfg.time_us:.0f} us, {mcfg.speedup_vs_default:.2f}x vs 128^3 "
          f"(linear_impl=\"fused\" MLPs pick this up via tuned=True)")
    m, k, n = MATMUL_SHAPES[0]
    qcfg = autotune_int8_matmul(m, k, n, cache=cache, iters=args.iters,
                                warmup=1, max_candidates=4)
    b = qcfg.blocks
    print(f"  int8_matmul {m}x{k}x{n}: best blocks "
          f"({b['block_m']},{b['block_n']},{b['block_k']}) "
          f"{qcfg.time_us:.0f} us, key dtype \"{qcfg.dtype}\" — the mixed "
          f"activation x weight key linear_impl=\"quantized\" looks up")
    path = cache.save(args.cache)
    print(f"  saved {len(cache)} entries -> {path}")

    print("\n=== 2. tuned kernel dispatch ===")
    set_default_cache(cache)
    m, k, n = MATMUL_SHAPES[0]
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    out = matmul(a, b, tuned=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               atol=2e-4, rtol=2e-5)
    ent = cache.get("matmul", (m, k, n), "float32", "tpu_v5e")
    print(f"  matmul(tuned=True) used cached blocks {ent.blocks} "
          f"and matches the jnp oracle")

    print("\n=== 3. measurement-calibrated advisor ===")
    profile = MeasuredProfile.from_cache(cache, "tpu_v5e")
    print(f"  profile: {len(profile.points)} measured GEMM shapes, "
          f"calibration x{profile.calibration:.3g} "
          f"(interpret-mode CPU vs TPU-analytic; ~1-2x on real hardware)")
    c0 = VARIANTS["c0"]  # GPT-3 2.7B: h=2560, a=32 (head_dim 80)
    for p in advisor.propose(c0, microbatch=4, profile=profile)[:3]:
        print(f"  {p.predicted_speedup:.3f}x  {p.change}  "
              f"(params {p.param_delta:+.2%})")
    print("done — docs/codesign-guide.md documents the cache format")


if __name__ == "__main__":
    main()
