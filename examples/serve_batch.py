"""Serving example: the static batch loop, then the continuous-batching
engine on the same architecture (CPU smoke scale).

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_driver

if __name__ == "__main__":
    print("== static batch (baseline) ==")
    serve_driver.main([
        "--arch", "internlm2-1.8b", "--smoke",
        "--batch", "8", "--prompt-len", "64", "--gen", "32",
    ])
    print()
    print("== continuous-batching engine ==")
    serve_driver.main([
        "--arch", "internlm2-1.8b", "--smoke", "--engine",
        "--batch", "8", "--prompt-len", "64", "--gen", "32",
        "--requests", "16", "--arrival", "uniform",
    ])
