"""Batched serving example: prefill a batch of prompts and decode with the
production cache layout (the decode_32k dry-run path, at CPU scale).

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_driver

if __name__ == "__main__":
    serve_driver.main([
        "--arch", "internlm2-1.8b", "--smoke",
        "--batch", "8", "--prompt-len", "64", "--gen", "32",
    ])
