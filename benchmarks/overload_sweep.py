"""Goodput and tail TTFT under 1x / 2x / 4x offered load.

The engine is sized for a known sustainable throughput (pool slots x
calibrated decode-step time); this sweep offers multiples of it and
measures how the admission controller degrades:

  * 1x — arrivals match service capacity: everything should complete, no
    shedding, goodput ~1.0;
  * 2x / 4x — the queue grows without bound if nothing sheds.  With a
    `ShedPolicy` (queue depth + predicted-TTFT SLO) the engine must drop
    the excess *at admission* (cheap: no slot, no prefill) and keep p99
    TTFT of the admitted requests bounded, instead of serving everyone
    late — or worse, crashing.

Offered load is controlled through the arrival gap: capacity is
pool_slots / mean_new_tokens requests per step, so a gap of
mean_new / slots steps is 1x and dividing it by the load factor overloads.
Every run is crash-free by construction (run() never raises per-request) —
the sweep asserts that and records the finish_reason breakdown.

    PYTHONPATH=src python -m benchmarks.overload_sweep --json BENCH_overload.json
    PYTHONPATH=src python -m benchmarks.overload_sweep --gate   # CI smoke

--gate checks the hardening contract at 2x overload: zero crashes, zero
rejected (the workload is valid), and goodput >= 0.9 on ADMITTED requests
(shedding is the mechanism, so shed requests don't count against it).
"""
from __future__ import annotations

import argparse
import json

import jax

NUM_REQUESTS = 32
MAX_PROMPT = 48
MAX_NEW = 16
LOADS = (1.0, 2.0, 4.0)
QUEUE_DEPTH = 8
SLO_STEPS = 48          # ttft_slo_s = SLO_STEPS * calibrated step_s


def _model():
    from repro.configs.registry import get_smoke_config
    from repro.models import init_lm

    cfg = get_smoke_config("internlm2-1.8b")
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def sweep(num_requests=NUM_REQUESTS, loads=LOADS):
    from repro.serving.engine import Engine, ShedPolicy, synthetic_requests

    cfg, params = _model()
    eng = Engine(params, cfg, max_batch=8, max_prompt=MAX_PROMPT,
                 max_new=MAX_NEW)
    step_s = eng.calibrate_step_s()
    slots = eng.policy.num_slots
    mean_new = (MAX_NEW // 4 + MAX_NEW) / 2
    gap_1x = mean_new / slots       # steps between arrivals at 1x load
    shed = ShedPolicy(max_queue_depth=QUEUE_DEPTH,
                      ttft_slo_s=SLO_STEPS * step_s, step_s=step_s)

    results = []
    for load in loads:
        reqs = synthetic_requests(
            num_requests, pattern="uniform", min_prompt=4,
            max_prompt=MAX_PROMPT, min_new=MAX_NEW // 4, max_new=MAX_NEW,
            vocab=cfg.vocab_size, step_s=step_s,
            arrival_gap_steps=max(gap_1x / load, 1e-3), seed=29)
        done, stats = eng.run(reqs, shed=shed)
        assert len(done) == num_requests, "a request went missing"
        ok_ttfts = sorted(c.ttft_s for c in done if c.ok)
        p99 = ok_ttfts[min(int(len(ok_ttfts) * 0.99),
                           len(ok_ttfts) - 1)] if ok_ttfts else 0.0
        results.append({
            "load": load,
            "offered_gap_steps": gap_1x / load,
            "goodput": stats.goodput,
            "num_ok": stats.num_ok,
            "num_shed": stats.num_shed,
            "num_timeout": stats.num_timeout,
            "num_rejected": stats.num_rejected,
            "finish_reasons": stats.finish_reasons,
            "ttft_ok_p99_s": p99,
            "tok_s": stats.tok_s,
            "stats": stats.to_json(),
        })
    return {
        "workload": {"num_requests": num_requests, "max_prompt": MAX_PROMPT,
                     "max_new": MAX_NEW, "pattern": "uniform"},
        "engine": {"slots": slots, "seq_max": eng.policy.seq_max,
                   "step_s": step_s},
        "shed_policy": {"max_queue_depth": QUEUE_DEPTH,
                        "ttft_slo_steps": SLO_STEPS},
        "loads": results,
    }


def run(json_path=None):
    summary = sweep()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
    rows = []
    for r in summary["loads"]:
        rows.append((f"overload/{r['load']:g}x",
                     f"{r['ttft_ok_p99_s']*1e6:.0f}",
                     f"{r['goodput']:.2f}_goodput_{r['num_shed']}_shed_"
                     f"{r['num_timeout']}_timeout"))
    return rows


def gate(summary) -> list:
    """CI contract at 2x overload (see module docstring).  Returns the list
    of violations (empty = pass)."""
    problems = []
    by_load = {r["load"]: r for r in summary["loads"]}
    two = by_load.get(2.0)
    if two is None:
        return ["no 2x load point in the sweep"]
    if two["num_rejected"]:
        problems.append(f"2x: {two['num_rejected']} rejected "
                        f"(workload is valid; rejects mean a bug)")
    if two["goodput"] < 0.9:
        problems.append(f"2x: goodput {two['goodput']:.3f} < 0.9 on "
                        f"admitted requests")
    four = by_load.get(4.0)
    if four is not None and four["num_ok"] == 0:
        problems.append("4x: nothing completed — shedding starved the "
                        "engine instead of protecting it")
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="persist the sweep summary (BENCH_overload.json)")
    ap.add_argument("--gate", action="store_true",
                    help="fail unless the 2x-overload hardening contract "
                         "holds (CI smoke)")
    args = ap.parse_args()
    summary = sweep()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
    for r in summary["loads"]:
        print(f"overload/{r['load']:g}x,{r['ttft_ok_p99_s']*1e6:.0f},"
              f"{r['goodput']:.2f}_goodput_{r['num_shed']}_shed_"
              f"{r['num_timeout']}_timeout")
    if args.gate:
        problems = gate(summary)
        if problems:
            raise SystemExit("overload gate FAILED:\n  " +
                             "\n  ".join(problems))
        print("overload gate: OK (2x overload, zero crashes, "
              f"goodput {next(r for r in summary['loads'] if r['load'] == 2.0)['goodput']:.3f})")


if __name__ == "__main__":
    main()
