"""Continuous-batching engine vs static batching across arrival patterns,
plus the prefix-cache (block-table) engine vs the slot pool on a
shared-prefix workload.

Both scheduling policies run through the SAME engine machinery (jitted
programs, bucket policy, slot pool) — only the scheduler differs: continuous
refills a slot the moment it frees; static waits for the whole pool to drain
(the classic batch-serving baseline, and exactly what `launch/serve.py` did
pre-engine).  The delta therefore isolates the scheduling policy: fewer
pool-wide decode steps (no dead slots riding to the batch max) and no
batch-boundary waiting.

The prefix section replays a system-prompt workload (`--prefix-share` of
requests open with a common prefix) through the slot engine and the
block-table engine (prefix caching + copy-on-write sharing).  Outputs are
asserted token-identical; the jsonl rows carry cache-hit rate and the TTFT
split between cache-hit and cold requests.  `--prefix-json` persists the
summary (BENCH_serve_prefix.json) the docs quote.

CPU smoke scale; deterministic workloads (`serving.engine.workload`), wall
clock measured after a full compile warmup.  Emits the harness CSV rows and,
with --jsonl, per-run records `benchmarks.report` renders into the serving
latency-percentile section.

    PYTHONPATH=src python -m benchmarks.run --only serve
    PYTHONPATH=src python -m benchmarks.serve_engine --jsonl serve_engine.jsonl \
        --prefix-json BENCH_serve_prefix.json
"""
from __future__ import annotations

import argparse
import json

import jax

NUM_REQUESTS = 16
MAX_PROMPT = 48
MAX_NEW = 24
PREFIX_SHARE = 0.8
# prefix section: long system prompt so prefill dominates TTFT (the regime
# prefix caching targets); 16 full blocks at the default block size (16)
PREFIX_MAX_PROMPT = 320
PREFIX_MAX_NEW = 8
SHARED_PREFIX_LEN = 256


def _model():
    from repro.configs.registry import get_smoke_config
    from repro.models import init_lm

    cfg = get_smoke_config("internlm2-1.8b")
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _engine(cfg, params, max_prompt=MAX_PROMPT, max_new=MAX_NEW, **kw):
    from repro.serving.engine import Engine

    eng = Engine(params, cfg, max_batch=8, max_prompt=max_prompt,
                 max_new=max_new, **kw)
    return eng, eng.calibrate_step_s()


def run_patterns(cfg, eng, step_s):
    from repro.serving.engine import PATTERNS, synthetic_requests

    rows, records = [], []
    for pattern in PATTERNS:
        reqs = synthetic_requests(
            NUM_REQUESTS, pattern=pattern, min_prompt=4,
            max_prompt=MAX_PROMPT, min_new=4, max_new=MAX_NEW,
            vocab=cfg.vocab_size, step_s=step_s, seed=17)
        out = {}
        for policy in ("continuous", "static"):
            done, stats = eng.run(reqs, policy=policy)
            out[policy] = stats
            us_per_tok = stats.wall_s / max(stats.total_generated, 1) * 1e6
            rows.append((f"serve_engine/{pattern}/{policy}",
                         f"{us_per_tok:.1f}", f"{stats.tok_s:.1f}_tok_s"))
            records.append({"pattern": pattern, "policy": policy,
                            **stats.to_json()})
        speedup = out["continuous"].tok_s / max(out["static"].tok_s, 1e-9)
        step_ratio = (out["static"].decode_steps
                      / max(out["continuous"].decode_steps, 1))
        rows.append((f"serve_engine/{pattern}/speedup", "0",
                     f"{speedup:.2f}x_tok_s_{step_ratio:.2f}x_steps"))
    return rows, records


def run_prefix(cfg, params, *, prefix_share=PREFIX_SHARE,
               num_requests=NUM_REQUESTS):
    """Slot pool vs block-table prefix cache on a shared-prefix workload.

    Long prompts (prefill-dominated TTFT — the regime prefix caching
    targets), arrivals spaced so each request's TTFT measures its own
    admission rather than queueing.  Returns (csv rows, jsonl records,
    summary dict).  The paged engine runs the workload twice: cold (first
    sharer populates the cache) and warm (every previously-seen prompt
    hits — the steady serving state)."""
    from repro.serving.engine import synthetic_requests

    slot_eng, step_s = _engine(cfg, params, max_prompt=PREFIX_MAX_PROMPT,
                               max_new=PREFIX_MAX_NEW)
    paged_eng, _ = _engine(cfg, params, max_prompt=PREFIX_MAX_PROMPT,
                           max_new=PREFIX_MAX_NEW, prefix_cache=True)
    reqs = synthetic_requests(
        num_requests, pattern="uniform", min_prompt=SHARED_PREFIX_LEN + 4,
        max_prompt=PREFIX_MAX_PROMPT, min_new=4, max_new=PREFIX_MAX_NEW,
        vocab=cfg.vocab_size, step_s=step_s, arrival_gap_steps=16,
        prefix_share=prefix_share, shared_prefix_len=SHARED_PREFIX_LEN,
        seed=23)

    done_slot, stats_slot = slot_eng.run(reqs)
    done_cold, stats_cold = paged_eng.run(reqs)
    done_warm, stats_warm = paged_eng.run(reqs)
    for a, b, c in zip(done_slot, done_cold, done_warm):
        assert a.tokens == b.tokens == c.tokens, \
            f"rid {a.rid}: prefix cache changed greedy tokens"
    paged_eng.pool.blocks.check()

    ttft_slot = {c.rid: c.ttft_s for c in done_slot}

    def hit_ttft_speedup(done_paged):
        """Median per-request TTFT improvement, cache-hit requests only,
        each against the SAME request on the slot engine."""
        import numpy as np
        ratios = [ttft_slot[c.rid] / c.ttft_s for c in done_paged
                  if c.cached_tokens > 0 and c.ttft_s > 0]
        return float(np.median(ratios)) if ratios else 0.0

    rows, records = [], []
    for tag, stats, done in (("slot", stats_slot, done_slot),
                             ("paged_cold", stats_cold, done_cold),
                             ("paged_warm", stats_warm, done_warm)):
        rows.append((f"serve_prefix/{tag}",
                     f"{stats.ttft_p50_s*1e6:.0f}",
                     f"{stats.tok_s:.1f}_tok_s_"
                     f"{stats.cache_hit_rate:.2f}_hit_rate"))
        records.append({"prefix_share": prefix_share, "engine": tag,
                        "ttft_hit_speedup": hit_ttft_speedup(done),
                        **stats.to_json()})
    tok_speedup = stats_warm.tok_s / max(stats_slot.tok_s, 1e-9)
    ttft_speedup = hit_ttft_speedup(done_warm)
    rows.append(("serve_prefix/speedup", "0",
                 f"{tok_speedup:.2f}x_tok_s_{ttft_speedup:.2f}x_ttft_hit"))
    summary = {
        "workload": {"num_requests": num_requests,
                     "prefix_share": prefix_share,
                     "shared_prefix_len": SHARED_PREFIX_LEN,
                     "max_prompt": PREFIX_MAX_PROMPT,
                     "max_new": PREFIX_MAX_NEW},
        "block_size": paged_eng.pool.block_size,
        "num_blocks": paged_eng.pool.blocks.num_blocks,
        "slot": stats_slot.to_json(),
        "paged_cold": stats_cold.to_json(),
        "paged_warm": stats_warm.to_json(),
        "tok_s_speedup_warm": tok_speedup,
        "ttft_hit_speedup_cold": hit_ttft_speedup(done_cold),
        "ttft_hit_speedup_warm": ttft_speedup,
        "token_identical": True,
    }
    return rows, records, summary


def run(jsonl_path=None, prefix_json=None, prefix_share=PREFIX_SHARE):
    cfg, params = _model()
    eng, step_s = _engine(cfg, params)
    rows, records = run_patterns(cfg, eng, step_s)
    if prefix_share > 0.0:
        prows, precs, summary = run_prefix(cfg, params,
                                           prefix_share=prefix_share)
        rows += prows
        records += precs
        if prefix_json:
            with open(prefix_json, "w") as f:
                json.dump(summary, f, indent=2, sort_keys=True)
    if jsonl_path:
        with open(jsonl_path, "w") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default=None,
                    help="also write per-run stats records for "
                         "benchmarks.report --serve")
    ap.add_argument("--prefix-share", type=float, default=PREFIX_SHARE,
                    help="fraction of requests opening with the shared "
                         "system prefix (0 disables the prefix section)")
    ap.add_argument("--prefix-json", default=None,
                    help="persist the prefix-cache summary "
                         "(BENCH_serve_prefix.json)")
    args = ap.parse_args()
    for name, us, derived in run(args.jsonl, args.prefix_json,
                                 args.prefix_share):
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
