"""Continuous-batching engine vs static batching across arrival patterns.

Both policies run through the SAME engine machinery (jitted programs, bucket
policy, slot pool) — only the scheduler differs: continuous refills a slot
the moment it frees; static waits for the whole pool to drain (the classic
batch-serving baseline, and exactly what `launch/serve.py` did pre-engine).
The delta therefore isolates the scheduling policy: fewer pool-wide decode
steps (no dead slots riding to the batch max) and no batch-boundary waiting.

CPU smoke scale; deterministic workloads (`serving.engine.workload`), wall
clock measured after a full compile warmup.  Emits the harness CSV rows and,
with --jsonl, per-run records `benchmarks.report` renders into the serving
latency-percentile section.

    PYTHONPATH=src python -m benchmarks.run --only serve
    PYTHONPATH=src python -m benchmarks.serve_engine --jsonl serve_engine.jsonl
"""
from __future__ import annotations

import argparse
import json

import jax

NUM_REQUESTS = 16
MAX_PROMPT = 48
MAX_NEW = 24


def _engine():
    from repro.configs.registry import get_smoke_config
    from repro.models import init_lm
    from repro.serving.engine import Engine

    cfg = get_smoke_config("internlm2-1.8b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, max_batch=8, max_prompt=MAX_PROMPT,
                 max_new=MAX_NEW)
    return cfg, eng, eng.calibrate_step_s()


def run(jsonl_path=None):
    from repro.serving.engine import PATTERNS, synthetic_requests

    cfg, eng, step_s = _engine()
    rows, records = [], []
    for pattern in PATTERNS:
        reqs = synthetic_requests(
            NUM_REQUESTS, pattern=pattern, min_prompt=4,
            max_prompt=MAX_PROMPT, min_new=4, max_new=MAX_NEW,
            vocab=cfg.vocab_size, step_s=step_s, seed=17)
        out = {}
        for policy in ("continuous", "static"):
            done, stats = eng.run(reqs, policy=policy)
            out[policy] = stats
            us_per_tok = stats.wall_s / max(stats.total_generated, 1) * 1e6
            rows.append((f"serve_engine/{pattern}/{policy}",
                         f"{us_per_tok:.1f}", f"{stats.tok_s:.1f}_tok_s"))
            records.append({"pattern": pattern, "policy": policy,
                            **stats.to_json()})
        speedup = out["continuous"].tok_s / max(out["static"].tok_s, 1e-9)
        step_ratio = (out["static"].decode_steps
                      / max(out["continuous"].decode_steps, 1))
        rows.append((f"serve_engine/{pattern}/speedup", "0",
                     f"{speedup:.2f}x_tok_s_{step_ratio:.2f}x_steps"))
    if jsonl_path:
        with open(jsonl_path, "w") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default=None,
                    help="also write per-run stats records for "
                         "benchmarks.report --serve")
    args = ap.parse_args()
    for name, us, derived in run(args.jsonl):
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
