"""Paper Figs. 6-9 (and appendix 21-47): attention score / attention-over-
value BMM throughput vs hidden size for various head counts.

Reproduces the paper's two findings with TPU constants:
  * fewer heads (larger h/a) => higher BMM throughput (Figs 8, 9),
  * throughput keyed by the largest power of two dividing h/a (Fig 7).
"""
from repro.core.gemm_model import GEMM, estimate
from repro.core.hardware import get_hardware
from repro.core.quantization import pow2_factor


def run():
    rows = []
    hw = get_hardware("tpu_v5e")
    b, s = 4, 2048
    # Fig 7: fixed a=32, h sweep; color = pow2 factor of h/a
    for h in range(2048, 4097, 256):
        a = 32
        hd = h // a
        g = GEMM("score", s, hd, s, batch=b * a)
        e = estimate(g, hw)
        rows.append((f"bmm_heads/score_a32_h{h}", 0.0,
                     f"tflops={e.achieved_tflops:.1f};pow2(h/a)={pow2_factor(hd)}"))
    # Figs 8/9: heads sweep at fixed h/a=64 and fixed h
    for a in (8, 16, 32, 64, 128):
        h = 4096
        hd = h // a
        g_score = GEMM("score", s, hd, s, batch=b * a)
        g_aov = GEMM("aov", s, s, hd, batch=b * a)
        rows.append((f"bmm_heads/score_h4096_a{a}", 0.0,
                     f"tflops={estimate(g_score, hw).achieved_tflops:.1f}"))
        rows.append((f"bmm_heads/aov_h4096_a{a}", 0.0,
                     f"tflops={estimate(g_aov, hw).achieved_tflops:.1f}"))
    # invariant asserted by the paper: decreasing a increases throughput
    t8 = estimate(GEMM("s", s, 4096 // 8, s, batch=b * 8), hw).achieved_tflops
    t128 = estimate(GEMM("s", s, 4096 // 128, s, batch=b * 128), hw).achieved_tflops
    assert t8 >= t128, "fewer heads should be faster (paper Fig. 8)"
    return rows
