"""Paper Fig. 20: logit-layer throughput vs vocabulary size.

The paper's rule: pad v to a multiple of 64 (A100) — 128 lanes on TPU.  We
sweep v around 50257 and report the analytic utilization cliff, plus the
system-level padded_vocab_size every config gets automatically.
"""
from repro.configs.base import ModelConfig
from repro.core.gemm_model import GEMM, estimate
from repro.core.hardware import get_hardware


def run():
    rows = []
    hw = get_hardware("tpu_v5e")
    b, s, h = 4, 2048, 2560
    for v in (50176, 50200, 50257, 50280, 50304, 50432):
        g = GEMM("logit", b * s, h, v)
        e = estimate(g, hw)
        rows.append((f"vocab_padding/v{v}", 0.0,
                     f"tflops={e.achieved_tflops:.1f};util={e.tile_util:.4f}"))
    aligned = estimate(GEMM("l", b * s, h, 50304), hw).achieved_tflops
    ragged = estimate(GEMM("l", b * s, h, 50257), hw).achieved_tflops
    assert aligned >= ragged
    cfg = ModelConfig(name="v", family="dense", num_layers=1, d_model=h,
                      num_heads=20, num_kv_heads=20, d_ff=4 * h,
                      vocab_size=50257)
    rows.append(("vocab_padding/system_padded_vocab", 0.0,
                 f"50257->{cfg.padded_vocab_size}"))
    assert cfg.padded_vocab_size == 50304  # the nanoGPT number
    return rows
