"""Paper Fig. 5: GEMM throughput vs size.

Analytic TFLOP/s on TPU v5e (target) and A100 (paper-fidelity: reproduces
the wave-quantization dips of Fig. 5b).  A CPU wall-clock smoke at tiny
sizes checks the monotone trend.
"""
import jax.numpy as jnp

from repro.core.gemm_model import GEMM, estimate
from repro.core.hardware import get_hardware

from .common import wall_us


def run():
    rows = []
    v5e, a100 = get_hardware("tpu_v5e"), get_hardware("a100")
    # Fig 5a: square-ish sweep
    for n in (256, 512, 1024, 2048, 4096, 8192, 16384):
        g = GEMM("sq", n, n, n)
        rows.append((f"gemm_sweep/v5e_square_n{n}", 0.0,
                     f"tflops={estimate(g, v5e).achieved_tflops:.1f}"))
    # Fig 5b: (m=2048k) sweep exposing wave quantization on A100
    for k in range(20, 29):
        m = 128 * k
        g = GEMM("wave", m, 4096, 4096)
        e = estimate(g, a100)
        rows.append((f"gemm_sweep/a100_wave_m{m}", 0.0,
                     f"tflops={e.achieved_tflops:.1f};wave_eff={e.wave_eff:.3f}"))
    # CPU smoke: throughput must rise with size
    prev = 0.0
    for n in (128, 256, 512):
        a = jnp.ones((n, n), jnp.float32)
        us = wall_us(lambda a: a @ a, a)
        fl = 2 * n ** 3 / (us * 1e-6) / 1e9
        rows.append((f"gemm_sweep/cpu_smoke_n{n}", round(us, 1),
                     f"gflops={fl:.1f}"))
        assert fl >= prev * 0.5, "throughput collapsed with size"
        prev = fl
    return rows
