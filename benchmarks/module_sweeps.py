"""Paper Figs. 10, 15-19: per-module GEMM throughput vs hidden size
(QKV transform, linear projection, MLP h->4h and 4h->h)."""
from repro.core.gemm_model import GEMM, estimate
from repro.core.hardware import get_hardware


def run():
    rows = []
    hw = get_hardware("tpu_v5e")
    b, s = 4, 2048
    for h in (1024, 2048, 4096, 8192, 12288, 16384):
        mods = {
            "qkv": GEMM("qkv", b * s, h, 3 * h),
            "proj": GEMM("proj", b * s, h, h),
            "mlp_up": GEMM("up", b * s, h, 4 * h),
            "mlp_down": GEMM("down", b * s, 4 * h, h),
        }
        for name, g in mods.items():
            e = estimate(g, hw)
            rows.append((f"module_sweeps/{name}_h{h}", 0.0,
                         f"tflops={e.achieved_tflops:.1f};bound={e.bound}"))
    # paper: throughput saturates with h (Figs 10a/10b)
    lo = estimate(GEMM("up", b * s, 1024, 4096), hw).achieved_tflops
    hi = estimate(GEMM("up", b * s, 8192, 32768), hw).achieved_tflops
    assert hi >= lo
    return rows
