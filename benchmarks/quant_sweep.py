"""Low-precision sweep: bf16 vs int8 vs fp8-emulated GEMM/MLP, f32 vs int8
KV decode, and the dtype-aware analytic pricing that justifies the paths.

Two kinds of signal, matching the container reality (CPU-only; Pallas runs
in interpret mode):

  * CPU smoke wall-clock + parity — the int8/fp8 kernels run end-to-end and
    land within quantization noise of the f32 GEMM.  Absolute interpret-mode
    times are NOT TPU times; they only prove the paths execute.
  * Analytic pricing (`core.gemm_model.precision_candidates` on tpu_v5e) —
    where int8 actually wins: a memory-bound decode GEMM moves ~half the
    weight bytes, so the roofline prices it near 1.9x over bf16; compute-
    bound train GEMMs stay bf16 (the model prices bandwidth only — the int8
    MXU rate bonus would only widen the win).  This is the number a TPU
    deployment of the quantized path is expected to track.

Plus the serving-economics row: `repro.quant.kv_bytes_per_token` prices KV
slots-per-GiB at kv_dtype="auto" vs "int8" for real registry shapes.

Emits harness CSV rows; --jsonl writes records for `benchmarks.report
--quant`; --json persists the BENCH_quant.json summary the docs quote.

    PYTHONPATH=src python -m benchmarks.run --only quant
    PYTHONPATH=src python -m benchmarks.quant_sweep --jsonl quant.jsonl \
        --json BENCH_quant.json
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from .common import wall_us

GEMM_M, GEMM_K, GEMM_N = 256, 256, 256  # tile-aligned CPU-smoke GEMM
MLP_M, MLP_H, MLP_F = 128, 256, 512
HW = "tpu_v5e"
ARCHS = ("internlm2-1.8b", "qwen1.5-4b")


def _gemm_smoke(records):
    from repro.kernels.matmul.ops import matmul
    from repro.kernels.quantized.ops import fp8_matmul, int8_matmul

    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (GEMM_M, GEMM_K)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (GEMM_K, GEMM_N)) * 0.5
    want = np.asarray(a @ w)
    denom = np.abs(want).max()

    impls = {
        "f32_pallas": lambda a: matmul(a, w, interpret=True),
        "int8": lambda a: int8_matmul(a, w, interpret=True),
        "fp8_e4m3": lambda a: fp8_matmul(a, w, interpret=True),
    }
    rows = []
    for name, fn in impls.items():
        us = wall_us(fn, a, iters=2, warmup=1, jit=False)
        err = float(np.abs(np.asarray(fn(a)) - want).max() / denom)
        rows.append((f"quant_sweep/gemm_{name}", round(us, 1),
                     f"rel_err={err:.4f};shape={GEMM_M}x{GEMM_K}x{GEMM_N}"))
        records.append({"type": "gemm_cpu", "impl": name, "m": GEMM_M,
                        "k": GEMM_K, "n": GEMM_N, "cpu_us": us,
                        "rel_err": err})
    return rows


def _mlp_smoke(records):
    from repro.kernels.fused_mlp.ops import fused_mlp_hidden
    from repro.kernels.quantized.ops import int8_fused_mlp_hidden

    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (MLP_M, MLP_H)) * 0.5
    wg = jax.random.normal(jax.random.fold_in(key, 1), (MLP_H, MLP_F)) * 0.3
    wu = jax.random.normal(jax.random.fold_in(key, 2), (MLP_H, MLP_F)) * 0.3
    want = np.asarray(fused_mlp_hidden(x, wg, wu, mlp_type="swiglu",
                                       interpret=True))
    denom = np.abs(want).max()
    impls = {
        "fused_f32": lambda x: fused_mlp_hidden(x, wg, wu, mlp_type="swiglu",
                                                interpret=True),
        "fused_int8": lambda x: int8_fused_mlp_hidden(x, wg, wu,
                                                      interpret=True),
    }
    rows = []
    for name, fn in impls.items():
        us = wall_us(fn, x, iters=2, warmup=1, jit=False)
        err = float(np.abs(np.asarray(fn(x)) - want).max() / denom)
        rows.append((f"quant_sweep/mlp_{name}", round(us, 1),
                     f"rel_err={err:.4f};shape={MLP_M}x{MLP_H}x{MLP_F}"))
        records.append({"type": "mlp_cpu", "impl": name, "m": MLP_M,
                        "h": MLP_H, "f": MLP_F, "cpu_us": us, "rel_err": err})
    return rows


def _analytic_pricing(records):
    """Per-GEMM dtype pricing on tpu_v5e for real registry configs, decode
    and train modes — the §VI-style roofline with dtype_bytes as an axis."""
    from repro.configs.base import DECODE_32K, TRAIN_4K
    from repro.configs.registry import get_config
    from repro.core.advisor import precision_plan
    from repro.core.hardware import get_hardware

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in (DECODE_32K, TRAIN_4K):
            plan = precision_plan(cfg, shape=shape, hw=get_hardware(HW))
            # model_gemms enumerates per layer: collapse identical shapes
            # (the stack repeats one block) so the report stays readable
            uniq = {}
            for g in plan:
                k = (g["m"], g["k"], g["n"], g["bound"],
                     g["recommended_dtype"])
                if k in uniq:
                    uniq[k]["count"] += 1
                else:
                    uniq[k] = {"type": "analytic", "arch": arch,
                               "mode": shape.mode, "count": 1, **g}
            records.extend(uniq.values())
            int8_wins = [g for g in plan if g["recommended_dtype"] == "int8"]
            best = max((g["speedup"] for g in int8_wins), default=1.0)
            rows.append((
                f"quant_sweep/pricing_{arch}_{shape.mode}", 0.0,
                f"int8_recommended={len(int8_wins)}/{len(plan)};"
                f"best_speedup={best:.2f}x"))
    return rows


def _kv_decode_smoke(records):
    from repro.kernels.flash_attention.ops import paged_decode
    from repro.quant import quantize_kv

    slots, s_max, nkv, d, b = 8, 256, 2, 64, 4
    key = jax.random.PRNGKey(2)
    kp = jax.random.normal(key, (slots, s_max, nkv, d)) * 0.5
    vp = jax.random.normal(jax.random.fold_in(key, 1),
                           (slots, s_max, nkv, d)) * 0.5
    q = jax.random.normal(jax.random.fold_in(key, 2), (b, nkv * 4, d))
    idx = jnp.arange(b, dtype=jnp.int32)
    lens = jnp.full((b,), s_max, jnp.int32)
    kq, ks = quantize_kv(kp)
    vq, vs = quantize_kv(vp)

    f32_us = wall_us(
        lambda q: paged_decode(q, kp, vp, idx, lens, interpret=True),
        q, iters=2, warmup=1, jit=False)
    int8_us = wall_us(
        lambda q: paged_decode(q, kq, vq, idx, lens, k_scale=ks, v_scale=vs,
                               interpret=True),
        q, iters=2, warmup=1, jit=False)
    want = np.asarray(paged_decode(q, kp, vp, idx, lens, interpret=True))
    got = np.asarray(paged_decode(q, kq, vq, idx, lens, k_scale=ks,
                                  v_scale=vs, interpret=True))
    err = float(np.abs(got - want).max() / np.abs(want).max())
    records.append({"type": "kv_cpu", "f32_us": f32_us, "int8_us": int8_us,
                    "rel_err": err, "slots": slots, "s_max": s_max,
                    "nkv": nkv, "d": d})
    return [("quant_sweep/kv_decode_f32", round(f32_us, 1),
             f"pool={slots}x{s_max}x{nkv}x{d}"),
            ("quant_sweep/kv_decode_int8", round(int8_us, 1),
             f"rel_err={err:.4f}")]


def _kv_slots(records):
    """Serving economics: KV slots per GiB of pool at max_seq tokens."""
    from repro.configs.registry import get_config
    from repro.quant import kv_bytes_per_token

    rows = []
    max_seq = 4096
    for arch in ARCHS:
        cfg = get_config(arch)
        d = cfg.d_model // cfg.num_heads
        per = {dt: kv_bytes_per_token(cfg.num_kv_heads, d, dt)
               * cfg.num_layers * max_seq for dt in ("auto", "int8")}
        # float slots: big models fit O(1) max_seq slots per GiB and integer
        # truncation would fake the gain
        slots = {dt: (1 << 30) / b for dt, b in per.items()}
        ratio = per["auto"] / per["int8"]
        rows.append((f"quant_sweep/kv_slots_{arch}", 0.0,
                     f"auto={slots['auto']:.1f};int8={slots['int8']:.1f};"
                     f"gain={ratio:.2f}x"))
        records.append({"type": "kv_slots", "arch": arch, "max_seq": max_seq,
                        "slots_per_gib_auto": round(slots["auto"], 2),
                        "slots_per_gib_int8": round(slots["int8"], 2),
                        "gain": ratio})
    return rows


def _summary(records) -> dict:
    analytic = [r for r in records if r["type"] == "analytic"]
    decode_int8 = [r["speedup"] for r in analytic
                   if r["mode"] == "decode" and
                   r["recommended_dtype"] == "int8"]
    gemm = {r["impl"]: r for r in records if r["type"] == "gemm_cpu"}
    kv = next(r for r in records if r["type"] == "kv_cpu")
    return {
        "hw": HW,
        "analytic": {
            "gemms_priced": sum(r["count"] for r in analytic),
            "decode_int8_recommended": sum(
                r["count"] for r in analytic
                if r["mode"] == "decode" and
                r["recommended_dtype"] == "int8"),
            "decode_int8_best_speedup": max(decode_int8, default=1.0),
            "decode_int8_min_speedup": min(decode_int8, default=1.0),
        },
        "cpu_smoke": {
            "interpret_mode": True,
            "int8_gemm_rel_err": gemm["int8"]["rel_err"],
            "fp8_gemm_rel_err": gemm["fp8_e4m3"]["rel_err"],
            "kv_decode_int8_rel_err": kv["rel_err"],
        },
        "kv_slots_per_gib": {
            r["arch"]: {"auto": r["slots_per_gib_auto"],
                        "int8": r["slots_per_gib_int8"],
                        "gain": r["gain"]}
            for r in records if r["type"] == "kv_slots"},
    }


def run(jsonl_path=None, json_path=None):
    records = []
    rows = []
    rows += _gemm_smoke(records)
    rows += _mlp_smoke(records)
    rows += _analytic_pricing(records)
    rows += _kv_decode_smoke(records)
    rows += _kv_slots(records)
    summary = _summary(records)
    rows.append((
        "quant_sweep/summary", 0.0,
        f"decode_int8_best={summary['analytic']['decode_int8_best_speedup']:.2f}x;"
        f"int8_rel_err={summary['cpu_smoke']['int8_gemm_rel_err']:.4f}"))
    if jsonl_path:
        with open(jsonl_path, "w") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default=None,
                    help="per-cell records for benchmarks.report --quant")
    ap.add_argument("--json", default=None,
                    help="summary the docs quote (BENCH_quant.json)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(args.jsonl, args.json):
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
