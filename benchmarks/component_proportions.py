"""Paper Figs. 2 and 11: proportion of per-layer latency by transformer
component, for a medium (2.7B) and large (20B-class) model.

Derived from the analytic GEMM model over the Table II decomposition; the
paper's qualitative claim — QKV + MLP GEMMs dominate large models, GEMMs are
>= ~68% of total — is asserted.
"""
from collections import defaultdict

from repro.configs.base import ModelConfig
from repro.core.gemm_model import estimate
from repro.core.hardware import get_hardware
from repro.core.transformer_gemms import layer_gemms


def _cfg(h, a, L):
    return ModelConfig(name=f"prop{h}", family="dense", num_layers=L,
                       d_model=h, num_heads=a, num_kv_heads=a, d_ff=4 * h,
                       vocab_size=50304, mlp_type="gelu")


def run():
    rows = []
    hw = get_hardware("tpu_v5e")
    for tag, h, a in (("medium2.7b", 2560, 32), ("large20b", 6144, 48)):
        cfg = _cfg(h, a, 32)
        gemms = layer_gemms(cfg, b=4, s=2048)
        times = defaultdict(float)
        for g in gemms:
            times[g.name] += estimate(g, hw).time_s
        total = sum(times.values())
        for name, t in sorted(times.items(), key=lambda kv: -kv[1]):
            rows.append((f"component_proportions/{tag}/{name}", 0.0,
                         f"pct={100 * t / total:.1f}"))
        mlp_qkv = (times["mlp_up"] + times["mlp_down"] + times["qkv_transform"])
        rows.append((f"component_proportions/{tag}/mlp+qkv_share", 0.0,
                     f"pct={100 * mlp_qkv / total:.1f}"))
    return rows
