"""Fused vs unfused MLP sweep across aligned and 8h/3-misaligned d_ff.

The paper's §VII-B case study: the SwiGLU 8h/3 heuristic lands d_ff off the
tile lattice and every MLP GEMM pays padding.  This sweep crosses that
alignment axis with the execution strategy the new linear-execution layer
dispatches between:

  jnp       XLA x @ w pair + elementwise (the pre-refactor baseline)
  unfused   two Pallas matmul kernels + XLA silu*mul (kernels/matmul)
  fused     ONE Pallas kernel for the gate/up pair + combine
            (kernels/fused_mlp), forward and — in the grad rows — its
            recompute-based custom-VJP backward

On this CPU container the Pallas rows run in interpret mode, so absolute
times are not TPU times; the signals are (a) the aligned-vs-misaligned
ratio within an impl (tile padding) and (b) fused-vs-unfused on equal
shapes (one streamed x pass + no HBM round-trip for the gate/up
activations).  A TPU host re-runs with REPRO_KERNEL_INTERPRET=0 for
deployment numbers.

Emits harness CSV rows and, with --jsonl, records that `benchmarks.report`
renders into the MLP-fusion section.

    PYTHONPATH=src python -m benchmarks.run --only mlp_fusion
    PYTHONPATH=src python -m benchmarks.mlp_fusion_sweep --jsonl mlp_fusion.jsonl
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from .common import wall_us

M, H = 256, 256  # tokens x model width
# 8h/3 for h=256 is 682.67: the heuristic's 683 breaks the 128 lane grid;
# the advisor-style re-search picks the aligned 768
DFFS = [
    ("aligned_768", 768, True),
    ("heuristic_683", 683, False),
]
IMPLS = ("jnp", "unfused", "fused")


def _hidden_fns(wg, wu):
    from repro.kernels.fused_mlp.ops import fused_mlp_hidden
    from repro.models.linear import linear

    @jax.jit
    def jnp_hidden(x):
        return jax.nn.silu(x @ wg) * (x @ wu)

    def unfused_hidden(x):
        # the model's unfused Pallas path (linear carries the custom VJP the
        # grad rows differentiate through)
        return jax.nn.silu(linear(x, wg, impl="pallas")) * \
            linear(x, wu, impl="pallas")

    def fused_hidden(x):
        return fused_mlp_hidden(x, wg, wu, mlp_type="swiglu", interpret=True)

    return {"jnp": jnp_hidden, "unfused": unfused_hidden,
            "fused": fused_hidden}


def _cell(d_ff: int):
    from repro.kernels.matmul.ops import alignment_report

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, H), jnp.float32)
    wg = jax.random.normal(jax.random.fold_in(key, 1), (H, d_ff)) * 0.1
    wu = jax.random.normal(jax.random.fold_in(key, 2), (H, d_ff)) * 0.1
    fns = _hidden_fns(wg, wu)
    util = alignment_report(M, H, d_ff, dtype=x.dtype)["mxu_utilization"]

    out = {}
    for impl, fn in fns.items():
        fwd = wall_us(fn, x, iters=2, warmup=1, jit=False)
        grad = wall_us(
            jax.jit(jax.grad(lambda x, fn=fn: fn(x).astype(jnp.float32).sum())),
            x, iters=2, warmup=1, jit=False)
        out[impl] = {"fwd_us": fwd, "grad_us": grad}
    return out, util


def run(jsonl_path=None):
    rows, records = [], []
    for tag, d_ff, aligned in DFFS:
        cells, util = _cell(d_ff)
        for impl in IMPLS:
            c = cells[impl]
            ratio = c["fwd_us"] / max(cells["unfused"]["fwd_us"], 1e-9)
            rows.append((
                f"mlp_fusion_sweep/{impl}_{tag}", round(c["fwd_us"], 1),
                f"grad_us={c['grad_us']:.1f};util={util:.3f};"
                f"vs_unfused={ratio:.2f}"))
            records.append({"impl": impl, "shape": tag, "d_ff": d_ff,
                            "aligned": aligned, "m": M, "h": H,
                            "mxu_utilization": util,
                            "fwd_us": c["fwd_us"], "grad_us": c["grad_us"],
                            "fwd_vs_unfused": ratio})
    # the co-design headline: what the heuristic d_ff costs each impl
    by = {(r["impl"], r["aligned"]): r["fwd_us"] for r in records}
    for impl in IMPLS:
        if by.get((impl, True)):
            ratio = by[(impl, False)] / by[(impl, True)]
            rows.append((f"mlp_fusion_sweep/{impl}_misalign_ratio", 0.0,
                         f"{ratio:.2f}x"))
            for r in records:
                if r["impl"] == impl:
                    r["misalign_ratio"] = ratio
    if jsonl_path:
        with open(jsonl_path, "w") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default=None,
                    help="also write per-cell records for benchmarks.report")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(args.jsonl):
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
