"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
import argparse
import sys
import traceback

MODULES = [
    "gemm_sweep",            # Fig. 5
    "bmm_heads_sweep",       # Figs. 6-9, 21-47
    "module_sweeps",         # Figs. 10, 15-19
    "component_proportions", # Figs. 2, 11
    "case_gpt3_shapes",      # Fig. 1
    "vocab_padding",         # Fig. 20
    "swiglu_search",         # §VII-B
    "flash_roofline",        # Fig. 12
    "pythia_inference",      # Fig. 13
    "dimension_order",       # Fig. 14
    "autotune_sweep",        # beyond-paper: measured block-size search
    "serve_engine",          # beyond-paper: continuous batching vs static
    "train_attention_sweep", # beyond-paper: fused-attn training step times
    "mlp_fusion_sweep",      # beyond-paper: fused vs unfused MLP, d_ff alignment
    "quant_sweep",           # beyond-paper: int8/fp8 GEMMs, int8 KV, dtype pricing
    "overload_sweep",        # beyond-paper: goodput/shedding under overload
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us},{derived}", flush=True)
        except Exception as e:
            failed.append(mod_name)
            print(f"{mod_name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
