"""Paper Fig. 13: Pythia-suite inference latency — 410M is off-trend (slow
for its size), 1B is on-trend, because of shape choices (410M: 24L x
head_dim 64; 1B: 16L x head_dim 256).

We reproduce the effect analytically: per-token decode time from the GEMM
model, showing 1B's latency is much closer to 410M's than the 2.4x parameter
ratio implies.
"""
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import advisor

PYTHIA = {
    "pythia-160m": ModelConfig("pythia-160m", "dense", 12, 768, 12, 12,
                               3072, 50304, mlp_type="gelu", norm_type="layernorm"),
    "pythia-410m": ModelConfig("pythia-410m", "dense", 24, 1024, 16, 16,
                               4096, 50304, mlp_type="gelu", norm_type="layernorm"),
    "pythia-1b": ModelConfig("pythia-1b", "dense", 16, 2048, 8, 8,
                             8192, 50304, mlp_type="gelu", norm_type="layernorm"),
    "pythia-1.4b": ModelConfig("pythia-1.4b", "dense", 24, 2048, 16, 16,
                               8192, 50304, mlp_type="gelu", norm_type="layernorm"),
}


def run():
    rows = []
    shape = ShapeConfig("decode", 2048, 8, "decode")
    times = {}
    for name, cfg in PYTHIA.items():
        t = advisor.step_time(cfg, shape, microbatch=8)
        times[name] = t
        rows.append((f"pythia_inference/{name}", 0.0,
                     f"per_token_ms={t * 1e3:.3f};params={cfg.param_count() / 1e9:.2f}B"))
    ratio = times["pythia-1b"] / times["pythia-410m"]
    rows.append(("pythia_inference/1b_over_410m_latency_ratio", 0.0,
                 f"{ratio:.2f} (param ratio ~2.4x; <2.4 == 410m off-trend)"))
    assert ratio < 2.4
    return rows
