"""Shared helpers for the benchmark harness.

Every benchmark regenerates one paper table/figure.  TPU numbers are
ANALYTIC (this container is CPU-only; the cost model is
repro.core.gemm_model targeting TPU v5e, with the paper's A100 available via
hw="a100" for fidelity checks).  Where a CPU wall-clock smoke adds signal
(trend checks at tiny scale), it is labeled `cpu_us`.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def wall_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    f = jax.jit(fn)
    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
