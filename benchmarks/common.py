"""Shared helpers for the benchmark harness.

Every benchmark regenerates one paper table/figure.  TPU numbers are
ANALYTIC (this container is CPU-only; the cost model is
repro.core.gemm_model targeting TPU v5e, with the paper's A100 available via
hw="a100" for fidelity checks).  Where a CPU wall-clock smoke adds signal
(trend checks at tiny scale), it is labeled `cpu_us`.

The wall-clock timer lives in repro.tuning.measure so the autotuner and the
benchmark harness measure identically; `wall_us` here is a re-export.
"""
from __future__ import annotations

from repro.tuning.measure import wall_us  # noqa: F401  (harness-wide timer)


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
