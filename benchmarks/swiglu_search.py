"""Paper §VII-B: SwiGLU d_ff brute-force search near 8h/3.

For h=4096 (LLaMA-2-7B), the paper observes that the public model's
d_ff=11008 is among the best-performing sizes in its range.  We run the
advisor's search and report the ranking.
"""
from repro.configs.base import ModelConfig
from repro.core import advisor
from repro.core.gemm_model import GEMM, estimate
from repro.core.hardware import get_hardware


def run():
    rows = []
    hw = get_hardware("tpu_v5e")
    h = 4096
    naive = int(8 * h / 3)  # 10922 — breaks all alignments
    cfg = ModelConfig(name="llama7b-ish", family="dense", num_layers=32,
                      d_model=h, num_heads=32, num_kv_heads=32,
                      d_ff=naive, vocab_size=32000, mlp_type="swiglu")
    props = [p for p in advisor.advise(cfg, param_tolerance=0.02)
             if "d_ff" in p.change]
    for p in props[:6]:
        rows.append((f"swiglu_search/{p.change.replace(' ', '')}", 0.0,
                     f"speedup={p.predicted_speedup:.4f};dparams={p.param_delta:+.4f}"))
    best = props[0].config.d_ff if props else naive
    rows.append(("swiglu_search/winner", 0.0,
                 f"d_ff={best};llama2_choice=11008"))
    assert best % 128 == 0
    # brute throughput check around the range (paper's brute-force)
    b, s = 4, 2048
    for dff in (10880, 10922, 11008, 11136, 11264):
        e = estimate(GEMM("up", b * s, h, dff), hw)
        rows.append((f"swiglu_search/brute_dff{dff}", 0.0,
                     f"tflops={e.achieved_tflops:.1f}"))
    return rows
