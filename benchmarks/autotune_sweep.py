"""Measured block-size autotuning sweep (beyond-paper: closes the loop
between the analytic §V cost model and the kernels that actually run).

For each problem shape: sweep the tile-aligned candidate lattice, report the
measured winner, its speedup over the 128-default blocks, and the analytic
model's pick — the gap between the two columns is exactly the calibration
error `core.gemm_model.MeasuredProfile` corrects for.

CPU container caveat: kernels run in Pallas interpret mode, so times rank
candidates relatively; on a TPU host the same sweep produces deployment
timings.  Shapes are kept small so the whole sweep stays in seconds.
"""
import jax.numpy as jnp

from repro.core.gemm_model import GEMM, estimate
from repro.core.hardware import get_hardware
from repro.tuning import TuningCache
from repro.tuning.search import (autotune_flash_attention,
                                 autotune_flash_backward, autotune_fused_mlp,
                                 autotune_int8_fused_mlp, autotune_int8_matmul,
                                 autotune_matmul)

MATMUL_SHAPES = [(256, 256, 256), (256, 512, 256), (384, 256, 128)]
FLASH_SHAPES = [(1, 256, 2, 64)]  # (batch, seq, heads, head_dim)
# (m, h, f) fused SwiGLU hidden shapes: aligned f and the 8h/3 heuristic f
FUSED_MLP_SHAPES = [(256, 256, 768), (256, 256, 683)]
# low-precision lattices tune separately: the int8 VMEM model admits larger
# k blocks (1-byte operands), so the winner need not match the f32 one
INT8_MATMUL_SHAPES = [(256, 256, 256)]
INT8_FUSED_MLP_SHAPES = [(256, 256, 768)]


def run():
    rows = []
    hw = get_hardware()
    cache = TuningCache()  # in-memory; examples/autotune.py persists one
    for m, k, n in MATMUL_SHAPES:
        cfg = autotune_matmul(m, k, n, dtype=jnp.float32, hw=hw, cache=cache,
                              iters=2, warmup=1, max_candidates=6)
        blk = cfg.blocks
        analytic = estimate(GEMM("a", m, k, n, dtype_bytes=4), hw)
        rows.append((
            f"autotune_sweep/matmul_{m}x{k}x{n}", round(cfg.time_us, 1),
            f"blocks={blk['block_m']}x{blk['block_n']}x{blk['block_k']};"
            f"speedup_vs_128={cfg.speedup_vs_default:.2f};"
            f"candidates={cfg.candidates_tried};"
            f"analytic_us={analytic.time_s * 1e6:.2f}"))
    for b, s, a, d in FLASH_SHAPES:
        cfg = autotune_flash_attention(b, s, a, d, hw=hw, cache=cache,
                                       iters=2, warmup=1, max_candidates=4)
        blk = cfg.blocks
        rows.append((
            f"autotune_sweep/flash_b{b}_s{s}_a{a}_d{d}",
            round(cfg.time_us, 1),
            f"blocks={blk['block_q']}x{blk['block_kv']};"
            f"speedup_vs_128={cfg.speedup_vs_default:.2f};"
            f"candidates={cfg.candidates_tried}"))
        # the training path's other half: the fused backward grids
        cfg = autotune_flash_backward(b, s, a, d, hw=hw, cache=cache,
                                      iters=1, warmup=1, max_candidates=2)
        blk = cfg.blocks
        rows.append((
            f"autotune_sweep/flash_bwd_b{b}_s{s}_a{a}_d{d}",
            round(cfg.time_us, 1),
            f"blocks={blk['block_q']}x{blk['block_kv']};"
            f"speedup_vs_128={cfg.speedup_vs_default:.2f};"
            f"candidates={cfg.candidates_tried}"))
    for m, h, f in FUSED_MLP_SHAPES:
        cfg = autotune_fused_mlp(m, h, f, hw=hw, cache=cache, iters=2,
                                 warmup=1, max_candidates=4)
        blk = cfg.blocks
        rows.append((
            f"autotune_sweep/fused_mlp_{m}x{h}x{f}", round(cfg.time_us, 1),
            f"blocks={blk['block_m']}x{blk['block_f']}x{blk['block_k']};"
            f"speedup_vs_128={cfg.speedup_vs_default:.2f};"
            f"candidates={cfg.candidates_tried}"))
    for m, k, n in INT8_MATMUL_SHAPES:
        cfg = autotune_int8_matmul(m, k, n, hw=hw, cache=cache, iters=2,
                                   warmup=1, max_candidates=4)
        blk = cfg.blocks
        rows.append((
            f"autotune_sweep/int8_matmul_{m}x{k}x{n}", round(cfg.time_us, 1),
            f"blocks={blk['block_m']}x{blk['block_n']}x{blk['block_k']};"
            f"speedup_vs_128={cfg.speedup_vs_default:.2f};"
            f"candidates={cfg.candidates_tried};dtype={cfg.dtype}"))
    for m, h, f in INT8_FUSED_MLP_SHAPES:
        cfg = autotune_int8_fused_mlp(m, h, f, hw=hw, cache=cache, iters=2,
                                      warmup=1, max_candidates=4)
        blk = cfg.blocks
        rows.append((
            f"autotune_sweep/int8_fused_mlp_{m}x{h}x{f}",
            round(cfg.time_us, 1),
            f"blocks={blk['block_m']}x{blk['block_f']}x{blk['block_k']};"
            f"speedup_vs_128={cfg.speedup_vs_default:.2f};"
            f"candidates={cfg.candidates_tried};dtype={cfg.dtype}"))
    return rows
