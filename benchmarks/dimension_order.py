"""Paper Fig. 14 (appendix): batched-dim ordering does not change GEMM
throughput — (2048,4,n)x(n,3n), (4,2048,n)x(n,3n) and (8192,n)x(n,3n) run
at the same speed.  On XLA the layouts are canonicalized; we verify the
wall-clock spread at small n on CPU and assert the analytic model treats
them identically.
"""
import jax.numpy as jnp

from repro.core.gemm_model import GEMM, estimate
from repro.core.hardware import get_hardware

from .common import wall_us


def run():
    rows = []
    hw = get_hardware("tpu_v5e")
    n = 256
    t_flat = estimate(GEMM("flat", 8192, n, 3 * n), hw).time_s
    t_bat = estimate(GEMM("bat", 2048, n, 3 * n, batch=4), hw).time_s
    rows.append(("dimension_order/analytic_flat_vs_batched", 0.0,
                 f"ratio={t_bat / t_flat:.3f}"))

    a1 = jnp.ones((2048, 4, n), jnp.float32)
    a2 = jnp.ones((4, 2048, n), jnp.float32)
    a3 = jnp.ones((8192, n), jnp.float32)
    w = jnp.ones((n, 3 * n), jnp.float32)
    us1 = wall_us(lambda a, w: a @ w, a1, w)
    us2 = wall_us(lambda a, w: a @ w, a2, w)
    us3 = wall_us(lambda a, w: a @ w, a3, w)
    mx, mn = max(us1, us2, us3), min(us1, us2, us3)
    rows.append(("dimension_order/cpu_2048x4", round(us1, 1), ""))
    rows.append(("dimension_order/cpu_4x2048", round(us2, 1), ""))
    rows.append(("dimension_order/cpu_8192", round(us3, 1), ""))
    rows.append(("dimension_order/max_over_min", 0.0, f"{mx / mn:.2f}"))
    return rows
