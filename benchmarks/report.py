"""Render EXPERIMENTS.md from the dry-run / perf / serving JSONL artifacts.

    PYTHONPATH=src python -m benchmarks.report \
        --dryrun dryrun_results.jsonl --perf perf_qwen.jsonl perf_whisper.jsonl \
        perf_deepseek.jsonl --serve serve_engine.jsonl --out EXPERIMENTS.md
"""
import argparse
import json
import os
from collections import defaultdict

HW_NOTE = (
    "All numbers are per-chip, derived from compiled (post-SPMD) HLO of the "
    "512-host-device dry-run via `repro.core.hlo_analysis` (trip-count-aware; "
    "raw `cost_analysis()` counts scan bodies once and is recorded in the "
    "JSONL for reference).  Hardware constants: TPU v5e, 197 TFLOP/s bf16, "
    "819 GB/s HBM, 150 GB/s ICI budget/chip.  CPU-backend caveat: XLA:CPU "
    "legalizes bf16 via f32 converts, inflating byte counts ~1.5-2x vs a TPU "
    "build; relative (before/after) comparisons are unaffected."
)


def _load(path):
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    return rows


def _fmt(x, nd=3):
    return "n/a" if x is None else f"{x:.{nd}f}"


def dryrun_section(rows):
    out = ["## §Dry-run", "",
           "Every (architecture × input shape) cell lowered + compiled on the "
           "single-pod 16x16 (256 chip) AND multi-pod 2x16x16 (512 chip) "
           "meshes.  `skipped` cells are the documented long_500k "
           "full-attention skips (DESIGN.md §4).", ""]
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    n_err = sum(r["status"] == "error" for r in rows)
    out.append(f"**{len(rows)} cells: {n_ok} compiled OK, {n_skip} skipped, "
               f"{n_err} errors.**")
    out.append("")
    out += [
        "**Memory fit (16 GB/chip v5e).**  `memory_analysis()` per chip on "
        "the largest cells: arguments (f32 master params + int8 optimizer "
        "state + batch) = 7.5 GB (nemotron-340B) / 14.8 GB (deepseek-671B) "
        "— the int8 optimizer-state compression is what makes these fit.  "
        "Temp memory under the paper-faithful config is dominated by the "
        "remat-saved residual stack (L x s x h); enabling sequence "
        "parallelism shards it t-fold: nemotron temp 52.8 -> 21.6 GB "
        "measured, ~11 GB in TPU-native bf16 (XLA:CPU stores the scan "
        "carries in f32) -> fits.  deepseek's temp is MoE dispatch buffers "
        "(39 GB at cf=1.25 in CPU-f32; ~13 GB at bf16+cf=1.0) -> fits with "
        "the §Perf treatments.  Decode/prefill cells are far below budget.",
        ""]
    out.append("| arch | shape | mesh | status | bytes/chip GB | coll GB | "
               "compile s |")
    out.append("|---|---|---|---|---|---|---|")
    for r in rows:
        gb = (f"{r['hlo_bytes'] / 1e9:.0f}" if r.get("hlo_bytes") else "-")
        cg = (f"{r['coll_bytes'] / 1e9:.1f}" if r.get("coll_bytes") is not None
              and r["status"] == "ok" else "-")
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                   f"{r['status']} | {gb} | {cg} | "
                   f"{r.get('compile_s', '-')} |")
    out.append("")
    return out


def roofline_section(rows):
    out = ["## §Roofline", "", HW_NOTE, "",
           "Terms (seconds/step, per chip): compute = HLO_FLOPs/peak; "
           "memory = HLO_bytes/HBM_bw; collective = collective_bytes/ICI_bw. "
           "`useful` = MODEL_FLOPS(6·N_active·D) / HLO_FLOPs; `rf` = "
           "analytic roofline fraction (useful-FLOP throughput at the "
           "dominant-term step time vs chip peak).", ""]
    out.append("| arch | shape | compute s | memory s | collective s | "
               "dominant | useful | rf | what moves the dominant term |")
    out.append("|---|---|---|---|---|---|---|---|---|")

    def note(r):
        s2 = r.get("s2_bytes") or 0.0
        tot = r.get("hlo_bytes") or 1.0
        if r["shape"].startswith(("decode", "long")):
            return (f"decode is bandwidth-bound by construction (streams "
                    f"params+cache per token); lower bound "
                    f"{r['memory_s'] * 1e3:.1f} ms/step — batch more "
                    f"sequences to amortize")
        if r["dominant"] == "compute":
            return "at compute roofline: remat policy (dots) / larger mb"
        if r["dominant"] == "collective":
            return ("MoE dispatch + TP/FSDP traffic: EP-local combine, "
                    "fewer microbatches, bf16 reductions")
        if s2 / tot > 0.3:
            return (f"s^2 attention is {100 * s2 / tot:.0f}% of bytes: "
                    f"Pallas flash kernel (kernels/flash_attention)")
        if r["compute_s"] > 0.4 * r["memory_s"]:
            return ("within 2.5x of compute roofline: bf16 backward + "
                    "remat tuning close the gap")
        return ("residual-stream activation traffic: sequence parallelism, "
                "bf16 backward, wider per-shard GEMMs")

    for r in rows:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(r['compute_s'])} | "
            f"{_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} | "
            f"{r['dominant']} | {_fmt(r['useful_ratio'], 2)} | "
            f"{_fmt(r['roofline_fraction'], 4)} | {note(r)} |")
    out.append("")
    out += ["### Multi-pod deltas (2x16x16 vs 16x16, train_4k)", "",
            "The pod axis runs as outer data parallelism: per-chip work "
            "halves at fixed global batch; the extra cost is the cross-pod "
            "gradient all-reduce (and its share of the collective term).", ""]
    out.append("| arch | c_s 1pod | c_s 2pod | coll_s 1pod | coll_s 2pod | "
               "rf 1pod | rf 2pod |")
    out.append("|---|---|---|---|---|---|---|")
    by_key = {(r["arch"], r["shape"], r["mesh"]): r for r in rows
              if r["status"] == "ok"}
    archs = sorted({r["arch"] for r in rows})
    for a in archs:
        r1 = by_key.get((a, "train_4k", "16x16"))
        r2 = by_key.get((a, "train_4k", "2x16x16"))
        if not (r1 and r2):
            continue
        out.append(f"| {a} | {_fmt(r1['compute_s'])} | {_fmt(r2['compute_s'])} | "
                   f"{_fmt(r1['collective_s'])} | {_fmt(r2['collective_s'])} | "
                   f"{_fmt(r1['roofline_fraction'], 4)} | "
                   f"{_fmt(r2['roofline_fraction'], 4)} |")
    out.append("")
    return out


def perf_section(perf_rows_by_cell):
    out = ["## §Perf", "",
           "Hillclimb methodology: hypothesis → change → re-lower → measure "
           "(three roofline terms) → verdict.  The **paper-faithful "
           "baseline** (naive Table II attention, mb=1) and the "
           "**beyond-paper optimized** variant are reported separately.  "
           "`flash_sub` rows give the TPU-deployment memory term with the "
           "Pallas flash kernel substituted for the measured s^2 attention "
           "traffic (the XLA twin cannot keep tiles VMEM-resident; the "
           "kernel's traffic is modeled from its BlockSpecs).", ""]
    import os
    nar = os.path.join(os.path.dirname(__file__), "perf_narrative.md")
    if os.path.exists(nar):
        with open(nar) as f:
            out += [f.read(), ""]
    out += ["### Raw treatment measurements (per perf_*.jsonl)", ""]
    for cell, rows in perf_rows_by_cell.items():
        out.append(f"### {cell}")
        out.append("")
        out.append("| treatment | compute s | memory s | collective s | "
                   "dominant | rf | flash-sub mem s | flash-sub rf |")
        out.append("|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r["status"] != "ok":
                out.append(f"| {r.get('tag')} | ERROR: {r.get('error', '')[:60]} |")
                continue
            out.append(
                f"| {r.get('tag')} | {_fmt(r['compute_s'])} | "
                f"{_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} | "
                f"{r['dominant']} | {_fmt(r['roofline_fraction'], 4)} | "
                f"{_fmt(r.get('flash_sub_memory_s'))} | "
                f"{_fmt(r.get('flash_sub_roofline_fraction'), 4)} |")
        out.append("")
        out.append("Hypothesis log:")
        for r in rows:
            out.append(f"- **{r.get('tag')}**: {r.get('hypothesis', '')}")
        out.append("")
    return out


def train_attention_section(rows):
    """Fused-attention training sweep: step time per attn_impl on aligned vs
    unaligned shapes (`benchmarks/train_attention_sweep.py`)."""
    out = ["## §Training attention", "",
           "Full `train_step` (value_and_grad + AdamW) step times across "
           "attention impls and shape alignment.  `flash` runs the Pallas "
           "kernel pair (forward + fused custom-VJP backward); on a CPU "
           "container it executes in interpret mode, so compare the "
           "misalign ratio within an impl, not absolute times across impls "
           "(TPU hosts re-run with REPRO_KERNEL_INTERPRET=0).", ""]
    out.append("| impl | shape | seq | head_dim | us/step | loss | "
               "misalign ratio |")
    out.append("|---|---|---|---|---|---|---|")
    for r in rows:
        ratio = r.get("misalign_ratio")
        ratio_s = f"{ratio:.2f}x" if ratio is not None else "n/a"
        out.append(
            f"| {r['impl']} | {r['shape']} | {r['seq']} | {r['head_dim']} | "
            f"{r['us_per_step']:.0f} | {r['loss']:.3f} | {ratio_s} |")
    out.append("")
    return out


def mlp_fusion_section(rows):
    """Fused vs unfused MLP report: forward/grad times per impl on aligned
    vs 8h/3-misaligned d_ff (`benchmarks/mlp_fusion_sweep.py`)."""
    out = ["## §MLP fusion", "",
           "SwiGLU hidden (gate/up GEMM pair + silu*mul) per execution "
           "strategy of the linear-execution layer (`repro.models.linear`): "
           "`jnp` = XLA, `unfused` = two Pallas matmuls, `fused` = the "
           "single fused kernel (`kernels/fused_mlp`).  `grad` rows "
           "differentiate through each path (the fused one via its "
           "recompute-based custom-VJP backward).  CPU container: Pallas "
           "rows run in interpret mode — compare the misalign ratio within "
           "an impl and fused-vs-unfused at equal shape, not absolute "
           "times (TPU hosts re-run with REPRO_KERNEL_INTERPRET=0).", ""]
    out.append("| impl | d_ff | util | fwd us | grad us | fwd vs unfused | "
               "misalign ratio |")
    out.append("|---|---|---|---|---|---|---|")
    for r in rows:
        ratio = r.get("misalign_ratio")
        ratio_s = f"{ratio:.2f}x" if ratio is not None else "n/a"
        out.append(
            f"| {r['impl']} | {r['d_ff']} | {r['mxu_utilization']:.3f} | "
            f"{r['fwd_us']:.0f} | {r['grad_us']:.0f} | "
            f"{r['fwd_vs_unfused']:.2f}x | {ratio_s} |")
    out.append("")
    return out


def quant_section(rows):
    """Low-precision report: CPU-smoke kernel parity plus the tpu_v5e
    analytic dtype pricing and KV slots-per-GiB economics
    (`benchmarks/quant_sweep.py`)."""
    cpu = [r for r in rows if r["type"] in ("gemm_cpu", "mlp_cpu")]
    analytic = [r for r in rows if r["type"] == "analytic"]
    kv_slots = [r for r in rows if r["type"] == "kv_slots"]
    kv_cpu = [r for r in rows if r["type"] == "kv_cpu"]
    out = ["## §Low precision", "",
           "int8/fp8 execution (`kernels/quantized`, `linear_impl="
           "\"quantized\"`, `kv_dtype=\"int8\"`).  CPU container: kernel "
           "rows run in Pallas interpret mode, so their wall-clock proves "
           "parity, not speed — the deployment signal is the analytic "
           "dtype pricing (tpu_v5e roofline with dtype_bytes as an axis; "
           "bandwidth-only, so int8's MXU-rate bonus would only widen the "
           "win).  See docs/quantization-guide.md.", ""]
    if cpu:
        out.append("| kernel | shape | cpu us (interpret) | rel err vs f32 |")
        out.append("|---|---|---|---|")
        for r in cpu:
            shape = (f"{r['m']}x{r['k']}x{r['n']}" if "k" in r
                     else f"{r['m']}x{r['h']}x{r['f']}")
            out.append(f"| {r['impl']} | {shape} | {r['cpu_us']:.0f} | "
                       f"{r['rel_err']:.4f} |")
        out.append("")
    if analytic:
        out.append("| arch | mode | gemm | m,k,n | bound | recommended | "
                   "speedup | layers |")
        out.append("|---|---|---|---|---|---|---|---|")
        for r in analytic:
            out.append(
                f"| {r['arch']} | {r['mode']} | {r['name']} | "
                f"{r['m']},{r['k']},{r['n']} | {r['bound']} | "
                f"{r['recommended_dtype']} | {r['speedup']:.2f}x | "
                f"{r['count']} |")
        out.append("")
    if kv_cpu or kv_slots:
        out.append("KV cache at `kv_dtype=\"int8\"` (per-(token, head) f32 "
                   "scales ride alongside the int8 pool):")
        out.append("")
        for r in kv_cpu:
            out.append(f"- paged decode rel err vs f32 pool: "
                       f"{r['rel_err']:.4f} "
                       f"(pool {r['slots']}x{r['s_max']}x{r['nkv']}x{r['d']})")
        for r in kv_slots:
            out.append(
                f"- {r['arch']}: {r['slots_per_gib_auto']} -> "
                f"{r['slots_per_gib_int8']} slots/GiB at "
                f"max_seq={r['max_seq']} ({r['gain']:.2f}x)")
        out.append("")
    return out


def serve_section(rows):
    """Serving-engine latency report: aggregate tok/s is not the whole
    story — per-request TTFT and inter-token percentiles are what a serving
    SLO is written against, so they ride alongside (p50/p99)."""
    prefix_rows = [r for r in rows if "prefix_share" in r]
    rows = [r for r in rows if "pattern" in r]
    out = ["## §Serving", "",
           "Continuous-batching engine vs static batching "
           "(`benchmarks/serve_engine.py`, CPU smoke scale; both policies "
           "share jitted programs + slot pool, only the scheduler differs — "
           "see docs/serving-guide.md).  `steps` counts pool-wide decode "
           "steps: static pays for dead slots riding to each batch max.", ""]
    out.append("| pattern | policy | tok/s | TTFT p50 ms | TTFT p99 ms | "
               "ITL p50 ms | ITL p99 ms | decode steps |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in rows:
        out.append(
            f"| {r['pattern']} | {r['policy']} | {r['tok_s']:.1f} | "
            f"{r['ttft_p50_s']*1e3:.1f} | {r['ttft_p99_s']*1e3:.1f} | "
            f"{r['itl_p50_s']*1e3:.1f} | {r['itl_p99_s']*1e3:.1f} | "
            f"{r['decode_steps']} |")
    out.append("")
    by_pat = defaultdict(dict)
    for r in rows:
        by_pat[r["pattern"]][r["policy"]] = r
    gains = [(p, d["continuous"]["tok_s"] / d["static"]["tok_s"])
             for p, d in by_pat.items()
             if "continuous" in d and "static" in d and d["static"]["tok_s"]]
    if gains:
        out.append("**Continuous vs static aggregate tok/s:** "
                   + ", ".join(f"{p} {g:.2f}x" for p, g in gains) + ".")
        out.append("")
    out += failure_class_lines(rows)
    if prefix_rows:
        out += prefix_cache_section(prefix_rows)
    return out


def failure_class_lines(rows):
    """Failure-class breakdown next to the latency percentiles: every request
    lands in exactly one finish_reason bucket (docs/serving-guide.md,
    'Failure semantics & overload'); a healthy closed-loop run is all
    stop/length, so anything else here is signal."""
    reasons = defaultdict(int)
    preempt = resumes = 0
    for r in rows:
        for k, v in (r.get("finish_reasons") or {}).items():
            reasons[k] += v
        preempt += r.get("preemptions", 0)
        resumes += r.get("resumes", 0)
    if not reasons:
        return []
    parts = ", ".join(f"{k} {v}" for k, v in sorted(reasons.items()))
    out = [f"**Failure classes (all runs):** {parts}."]
    if preempt or resumes:
        out.append(f"**KV preemptions:** {preempt} "
                   f"({resumes} resumed exactly via the prefix cache).")
    out.append("")
    return out


def overload_section(summary):
    """Overload sweep (BENCH_overload.json): goodput + shed/timeout counts
    and p99 TTFT of completed requests as offered load scales past
    capacity — the graceful-degradation contract the CI gate enforces."""
    eng = summary.get("engine", {})
    shed = summary.get("shed_policy", {})
    out = ["### Overload (admission control under 1x/2x/4x offered load)",
           "",
           f"`benchmarks/overload_sweep.py`: {eng.get('slots', '?')} slots, "
           f"shed policy depth={shed.get('max_queue_depth')}, "
           f"TTFT SLO={shed.get('ttft_slo_steps')} steps.  Overload is shed "
           "at admission (no slot, no prefill); goodput = ok / admitted.  "
           "The CI gate requires 2x overload to complete crash-free with "
           "goodput >= 0.9.", ""]
    out.append("| offered load | ok | shed | timeout | goodput | "
               "TTFT ok p99 ms | tok/s |")
    out.append("|---|---|---|---|---|---|---|")
    for r in summary.get("loads", []):
        out.append(
            f"| {r['load']:g}x | {r['num_ok']} | {r['num_shed']} | "
            f"{r['num_timeout']} | {r['goodput']:.2f} | "
            f"{r['ttft_ok_p99_s']*1e3:.1f} | {r['tok_s']:.1f} |")
    out.append("")
    return out


def prefix_cache_section(rows):
    """Prefix-cache (block-table pool) vs the slot pool on a shared-prefix
    workload: hit rate + the TTFT split between cache-hit and cold requests
    is the number a system-prompt deployment cares about."""
    out = ["### Prefix cache (block-table pool vs slot pool)", "",
           "Shared-prefix workload (`benchmarks/serve_engine.py "
           "--prefix-share`); outputs asserted token-identical.  "
           "`ttft hit speedup` is the median per-request TTFT improvement "
           "of cache-hit requests vs the same requests on the slot pool.",
           ""]
    out.append("| engine | share | tok/s | hit rate | TTFT p50 ms "
               "| TTFT hit p50 ms | TTFT cold p50 ms | ttft hit speedup |")
    out.append("|---|---|---|---|---|---|---|---|")
    def _ms(v):
        # hit/cold splits are None when that request class is empty
        return "n/a" if v is None else f"{v * 1e3:.1f}"

    for r in rows:
        out.append(
            f"| {r['engine']} | {r['prefix_share']:.2f} | {r['tok_s']:.1f} "
            f"| {r['cache_hit_rate']:.2f} | {r['ttft_p50_s']*1e3:.1f} "
            f"| {_ms(r['ttft_hit_p50_s'])} "
            f"| {_ms(r['ttft_cold_p50_s'])} "
            f"| {r.get('ttft_hit_speedup', 0.0):.2f}x |")
    out.append("")
    return out


def obs_section(dump_dir):
    """Observability summary (spans / step percentiles / compiles / drift /
    metrics) from an `obs.export_all` dump — `repro.obs.view` renders it;
    this section just re-titles it for EXPERIMENTS.md."""
    from repro.obs import view
    out = ["## §Observability", "",
           f"From `{dump_dir}` (written by `repro.launch.serve --obs-dump`; "
           "drift = analytic/measured-profile prediction vs span-measured "
           "step time — see docs/observability-guide.md).", ""]
    # drop render_summary's own H1 title; keep its section structure
    out += [ln.replace("## ", "### ") for ln in view.render_summary(dump_dir)
            if not ln.startswith("# ")]
    out.append("")
    return out


def analysis_section(paths):
    """Static-analysis summary from the codesign lint engine
    (`repro.analysis`): per-rule counts plus every priced shape finding, so
    EXPERIMENTS.md records which measured inefficiencies were *predicted*
    from shapes alone (docs/static-analysis-guide.md has the rule catalog)."""
    from repro.analysis import analyze
    from repro.analysis.rules import RULES

    result = analyze(paths, registry_audit=True)
    out = ["## §Static analysis", "",
           f"`python -m repro.analysis {' '.join(paths)}` over "
           f"{result.files_scanned} files + the config registry "
           "(tpu_v5e target).  Errors gate CI; warns are tracked "
           "(smoke configs and runtime-mitigated shapes are downgraded "
           "by design).", ""]
    by_rule = defaultdict(list)
    for f in result.findings:
        by_rule[f.rule_id].append(f)
    out.append("| rule | name | severity | findings |")
    out.append("|---|---|---|---|")
    for rid in sorted(by_rule):
        rule = RULES[rid]
        worst = max(by_rule[rid],
                    key=lambda f: ("info", "warn", "error").index(f.severity))
        out.append(f"| {rid} | {rule.name} | {worst.severity} | "
                   f"{len(by_rule[rid])} |")
    out.append("")
    priced = [f for f in result.findings
              if f.rule_id.startswith("SHP") and "est." in f.fix_hint]
    if priced:
        out.append("Priced shape findings (analytic GEMM model):")
        out.append("")
        for f in priced:
            out.append(f"- **{f.rule_id}** [{f.arch}] {f.fix_hint}")
        out.append("")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="dryrun_results.jsonl")
    ap.add_argument("--perf", nargs="*", default=[])
    ap.add_argument("--serve", default=None,
                    help="serve_engine.jsonl from benchmarks.serve_engine")
    ap.add_argument("--overload", default=None,
                    help="BENCH_overload.json from benchmarks.overload_sweep")
    ap.add_argument("--train-attn", default=None,
                    help="train_attention.jsonl from "
                         "benchmarks.train_attention_sweep")
    ap.add_argument("--mlp-fusion", default=None,
                    help="mlp_fusion.jsonl from benchmarks.mlp_fusion_sweep")
    ap.add_argument("--quant", default=None,
                    help="quant.jsonl from benchmarks.quant_sweep")
    ap.add_argument("--obs", default=None, metavar="DUMPDIR",
                    help="observability dump dir from obs.export_all "
                         "(e.g. `repro.launch.serve --obs-dump`); embeds the "
                         "span/compile/drift summary")
    ap.add_argument("--analysis", nargs="*", default=None, metavar="PATH",
                    help="embed the repro.analysis static-analysis summary "
                         "(default scan path: src); pass paths to override")
    ap.add_argument("--out", default="EXPERIMENTS.md")
    args = ap.parse_args()

    dry = _load(args.dryrun) if os.path.exists(args.dryrun) else []
    perf = {}
    for p in args.perf:
        cell = p.split("perf_")[-1].split(".")[0]
        perf[cell] = _load(p)

    lines = ["# EXPERIMENTS", "",
             "Generated by `python -m benchmarks.report` from "
             "dryrun_results.jsonl / perf_*.jsonl / serve_engine.jsonl "
             "(regenerate any time).", ""]
    if dry:
        lines += dryrun_section(dry)
        lines += roofline_section(dry)
    if perf:
        lines += perf_section(perf)
    if args.train_attn:
        lines += train_attention_section(_load(args.train_attn))
    if args.mlp_fusion:
        lines += mlp_fusion_section(_load(args.mlp_fusion))
    if args.quant:
        lines += quant_section(_load(args.quant))
    if args.serve:
        lines += serve_section(_load(args.serve))
    if args.overload and os.path.exists(args.overload):
        with open(args.overload) as f:
            lines += overload_section(json.load(f))
    if args.obs:
        lines += obs_section(args.obs)
    if args.analysis is not None:
        lines += analysis_section(args.analysis or ["src"])
    with open(args.out, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {args.out} ({len(lines)} lines)")


if __name__ == "__main__":
    main()
