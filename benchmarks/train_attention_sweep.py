"""Training step-time sweep over attention impls and shape alignment.

The paper's headline claim is about *training* throughput: tile-aligned
model shapes keep the attention kernels on their fast paths.  This sweep
reproduces that end-to-end — a full `train.train_step` (value_and_grad +
AdamW) on a small LM — crossing:

  attn_impl  naive | blocked | flash   (flash = the Pallas kernel pair with
                                        its custom-VJP fused backward)
  shape      aligned (head_dim 64, seq a block multiple) vs
             unaligned (head_dim 80, seq off the 128 grid — the GPT-3 2.7B
             pathology of paper Fig. 1)

On this CPU container the flash rows run the kernels in Pallas interpret
mode, so absolute times are not TPU times; the aligned-vs-unaligned *ratio*
within an impl is the signal (padding + masked tail work), and on a TPU
host (REPRO_KERNEL_INTERPRET=0) the same sweep yields deployment numbers.

Emits harness CSV rows and, with --jsonl, records that `benchmarks.report`
renders into the training-attention section.

    PYTHONPATH=src python -m benchmarks.run --only train_attention
    PYTHONPATH=src python -m benchmarks.train_attention_sweep --jsonl train_attention.jsonl
"""
from __future__ import annotations

import argparse
import json

import jax

from .common import wall_us

IMPLS = ("naive", "blocked", "flash")
# (tag, seq, head_dim, aligned): aligned keeps both seq and head_dim on the
# (sublane, lane) grid; unaligned breaks both (the paper's h/a = 80 case)
SHAPES = [
    ("aligned_s256_d64", 256, 64, True),
    ("unaligned_s200_d80", 200, 80, False),
]
BATCH = 2


def _cell(seq: int, head_dim: int, impl: str):
    from repro.configs.base import ModelConfig, TrainConfig
    from repro.models import init_lm
    from repro.optim.adamw import init_opt
    from repro.train.train_step import make_train_step

    cfg = ModelConfig(name=f"sweep_{impl}", family="dense", num_layers=2,
                      d_model=4 * head_dim, num_heads=4, num_kv_heads=2,
                      d_ff=2 * 4 * head_dim, vocab_size=512,
                      head_dim=head_dim, attn_impl=impl, attn_block_kv=128,
                      dtype="float32")
    tc = TrainConfig(total_steps=4, warmup_steps=1)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt = init_opt(params, tc)
    step = make_train_step(cfg, tc)
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (BATCH, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                     (BATCH, seq), 0, cfg.vocab_size),
    }

    def one_step(params, opt, batch):
        p, o, metrics = step(params, opt, batch)
        return metrics["loss"]

    us = wall_us(one_step, params, opt, batch, iters=2, warmup=1)
    loss = float(one_step(params, opt, batch))
    return us, loss


def run(jsonl_path=None):
    rows, records = [], []
    for tag, seq, head_dim, aligned in SHAPES:
        for impl in IMPLS:
            us, loss = _cell(seq, head_dim, impl)
            rows.append((f"train_attention_sweep/{impl}_{tag}", round(us, 1),
                         f"loss={loss:.3f};aligned={int(aligned)}"))
            records.append({"impl": impl, "shape": tag, "seq": seq,
                            "head_dim": head_dim, "aligned": aligned,
                            "us_per_step": us, "loss": loss})
    # the co-design headline: what misalignment costs each impl
    by = {(r["impl"], r["aligned"]): r["us_per_step"] for r in records}
    for impl in IMPLS:
        if (impl, True) in by and (impl, False) in by and by[(impl, True)]:
            ratio = by[(impl, False)] / by[(impl, True)]
            rows.append((f"train_attention_sweep/{impl}_misalign_ratio",
                         0.0, f"{ratio:.2f}x"))
            for r in records:
                if r["impl"] == impl:
                    r["misalign_ratio"] = ratio
    if jsonl_path:
        with open(jsonl_path, "w") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default=None,
                    help="also write per-cell records for benchmarks.report")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(args.jsonl):
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
