"""Paper Fig. 1: single-layer throughput of GPT-3 2.7B shape variants.

C0 = Brown et al. original (a=32, head_dim 80); C1 (a=64, hd 40);
C2 (a=40, hd 64); C3 (a=20, hd 128) = the paper's recommended fix.
Paper reports C-variants up to ~1.39x over C0 on A100; we report the
TPU v5e analytic ordering + a tiny-scale CPU wall-clock trend check.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.gpt3_2p7b import VARIANTS
from repro.core import advisor
from repro.core.hardware import get_hardware

from .common import wall_us


def run():
    rows = []
    v5e, a100 = get_hardware("tpu_v5e"), get_hardware("a100")
    base_t = {}
    for hw_name, hw in (("v5e", v5e), ("a100", a100)):
        for tag, cfg in VARIANTS.items():
            t = advisor.step_time(cfg, hw=hw, microbatch=4)
            base_t[(hw_name, tag)] = t
        for tag in VARIANTS:
            sp = base_t[(hw_name, "c0")] / base_t[(hw_name, tag)]
            rows.append((f"case_gpt3/{hw_name}_{tag}", 0.0,
                         f"speedup_vs_c0={sp:.3f};"
                         f"tflops={advisor.score(VARIANTS[tag], hw=hw, microbatch=4):.1f}"))
    # paper's fix (a=20 on TPU / a=40 on A100) must be the fastest variant
    assert base_t[("v5e", "c3")] <= min(base_t[("v5e", t)] for t in VARIANTS)
    # CPU wall-clock smoke on a scaled-down layer: hd 128 vs hd 80
    from repro.models.attention import init_gqa, apply_gqa
    for tag, heads in (("c0s", 8), ("c3s", 5)):  # h=640: hd 80 vs 128
        cfg = dataclasses.replace(VARIANTS["c0"], d_model=640, num_heads=heads,
                                  num_kv_heads=heads, d_ff=2560, num_layers=1)
        p = init_gqa(jax.random.PRNGKey(0), cfg)
        x = jnp.ones((2, 512, 640), jnp.float32)
        us = wall_us(lambda p, x: apply_gqa(p, x, cfg,
                                            positions=jnp.arange(512))[0], p, x)
        rows.append((f"case_gpt3/cpu_smoke_{tag}", round(us, 1),
                     f"head_dim={640 // heads}"))
    return rows
