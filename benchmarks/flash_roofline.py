"""Paper Fig. 12: FlashAttention sweep over hidden size follows a roofline.

We report (i) the analytic arithmetic intensity / roofline position of the
flash kernel vs the naive score+AOV pair on v5e, and (ii) a CPU wall-clock
comparison of the XLA blocked twin vs naive attention at small scale, plus
the HLO-measured byte reduction (the actual mechanism).
"""

import jax
import jax.numpy as jnp

from repro.core.hardware import get_hardware

from .common import wall_us


def run():
    rows = []
    hw = get_hardware("tpu_v5e")
    b, a = 4, 128
    for h in (2048, 4096, 8192, 16384):
        hd, s = h // a, 2048
        flops = 4 * b * a * s * s * hd
        naive_bytes = 2 * (b * a * s * s * 4 + b * a * s * hd * 2) * 2
        flash_bytes = 3 * b * a * s * hd * 2 + b * a * s * hd * 2
        t_naive = max(flops / hw.peak_flops, naive_bytes / hw.hbm_bw)
        t_flash = max(flops / hw.peak_flops, flash_bytes / hw.hbm_bw)
        rows.append((f"flash_roofline/h{h}", 0.0,
                     f"naive_tflops={flops / t_naive / 1e12:.1f};"
                     f"flash_tflops={flops / t_flash / 1e12:.1f}"))
    # CPU wall-clock + HLO bytes: blocked vs naive on a small case
    from repro.models.attention import _sdpa
    from repro.models.blocked_attention import blocked_sdpa
    # s must be >> block for the O(s^2) vs O(s*block) gap to show
    q = jnp.ones((2, 1024, 4, 64), jnp.float32)
    k = jnp.ones((2, 1024, 2, 64), jnp.float32)
    v = jnp.ones((2, 1024, 2, 64), jnp.float32)
    us_naive = wall_us(lambda q, k, v: _sdpa(q, k, v, causal=True), q, k, v)
    us_blocked = wall_us(lambda q, k, v: blocked_sdpa(q, k, v, causal=True,
                                                      block_kv=128), q, k, v)
    rows.append(("flash_roofline/cpu_naive", round(us_naive, 1), ""))
    rows.append(("flash_roofline/cpu_blocked", round(us_blocked, 1), ""))
    # peak temp memory: the XLA twin never materializes the s^2 score matrix
    # (the O(s*block) HBM-traffic claim belongs to the Pallas kernel, whose
    # tiles live in VMEM; the XLA twin's tiles still cross fusion boundaries)
    c_naive = jax.jit(lambda q, k, v: _sdpa(q, k, v, causal=True)
                      ).lower(q, k, v).compile()
    c_blk = jax.jit(lambda q, k, v: blocked_sdpa(q, k, v, causal=True,
                                                 block_kv=128)
                    ).lower(q, k, v).compile()
    t_naive = c_naive.memory_analysis().temp_size_in_bytes
    t_blk = c_blk.memory_analysis().temp_size_in_bytes
    rows.append(("flash_roofline/peak_temp_naive_MB", 0.0, f"{t_naive / 1e6:.1f}"))
    rows.append(("flash_roofline/peak_temp_blocked_MB", 0.0, f"{t_blk / 1e6:.1f}"))
    rows.append(("flash_roofline/peak_temp_reduction", 0.0,
                 f"{t_naive / max(t_blk, 1):.1f}x"))
    assert t_blk < t_naive
    return rows
