"""Optimizer: AdamW convergence, 8-bit state fidelity, clipping, schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.optim.adamw import (apply_updates, clip_by_global_norm, init_opt,
                               lr_schedule)


def _rosenbrock_ish(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1 - x) ** 2) + 5 * jnp.sum((y - x ** 2) ** 2)


@pytest.mark.parametrize("optimizer", ["adamw", "adamw8bit"])
def test_converges_on_toy_problem(optimizer):
    tc = TrainConfig(optimizer=optimizer, learning_rate=0.05,
                     weight_decay=0.0, total_steps=300, warmup_steps=10,
                     grad_clip=10.0)
    params = {"x": jnp.zeros((130,)), "y": jnp.zeros((130,))}  # 130: pad path
    state = init_opt(params, tc)
    loss0 = float(_rosenbrock_ish(params))

    @jax.jit
    def step(p, s):
        g = jax.grad(_rosenbrock_ish)(p)
        return apply_updates(p, g, s, tc)

    for _ in range(300):
        params, state, m = step(params, state)
    assert float(_rosenbrock_ish(params)) < loss0 * 0.05


def test_8bit_tracks_fp32_closely():
    tc32 = TrainConfig(optimizer="adamw", learning_rate=0.01, weight_decay=0.0,
                       total_steps=100, warmup_steps=1)
    tc8 = TrainConfig(optimizer="adamw8bit", learning_rate=0.01,
                      weight_decay=0.0, total_steps=100, warmup_steps=1)
    p32 = {"w": jnp.ones((256,)) * 2.0}
    p8 = {"w": jnp.ones((256,)) * 2.0}
    s32, s8 = init_opt(p32, tc32), init_opt(p8, tc8)
    f = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(50):
        g32 = jax.grad(f)(p32)
        p32, s32, _ = apply_updates(p32, g32, s32, tc32)
        g8 = jax.grad(f)(p8)
        p8, s8, _ = apply_updates(p8, g8, s8, tc8)
    # same trajectory within quantization noise
    np.testing.assert_allclose(np.asarray(p8["w"]), np.asarray(p32["w"]),
                               atol=0.05)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((100,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0)
    new_norm = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert new_norm == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lr = lr_schedule(tc)
    assert float(lr(jnp.asarray(0))) < float(lr(jnp.asarray(10)))
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.asarray(100))) < float(lr(jnp.asarray(50)))
