"""End-to-end behaviour tests for the co-design system: the advisor's
predictions must line up with what the dry-run machinery measures, and the
full train->checkpoint->resume->serve lifecycle must hold together."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core.hlo_analysis import analyze_hlo


def test_registry_has_all_assigned_archs():
    names = set(list_archs())
    for a in ["zamba2-2.7b", "qwen1.5-4b", "nemotron-4-340b",
              "internlm2-1.8b", "command-r-plus-104b", "deepseek-v3-671b",
              "llama4-maverick-400b-a17b", "internvl2-76b", "whisper-small",
              "mamba2-780m"]:
        assert a in names


def test_full_configs_match_nameplate_params():
    targets = {"qwen1.5-4b": 4e9, "nemotron-4-340b": 340e9,
               "internlm2-1.8b": 1.8e9, "command-r-plus-104b": 104e9,
               "deepseek-v3-671b": 671e9,
               "llama4-maverick-400b-a17b": 400e9, "mamba2-780m": 0.78e9}
    for name, t in targets.items():
        p = get_config(name).param_count()
        assert 0.85 < p / t < 1.15, (name, p / t)


def test_llama4_active_params_match_a17b():
    a = get_config("llama4-maverick-400b-a17b").active_param_count()
    assert 0.85 < a / 17e9 < 1.15


def test_advisor_prediction_agrees_with_hlo_measurement():
    """System-level closure: the advisor predicts blocked attention cannot
    change FLOPs materially but slashes attention HBM traffic; verify on a
    small jitted model that HLO bytes drop while flops stay ~equal."""
    from repro.models import init_lm, lm_loss
    cfg = get_smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, num_layers=2)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((2, 256), jnp.int32),
             "labels": jnp.zeros((2, 256), jnp.int32)}

    def measure(c):
        txt = (jax.jit(lambda p, b: lm_loss(p, b, c)[0])
               .lower(params, batch).compile().as_text())
        return analyze_hlo(txt)

    naive = measure(cfg)
    blocked = measure(dataclasses.replace(cfg, attn_impl="blocked",
                                          attn_block_kv=64))
    assert blocked.flops == pytest.approx(naive.flops, rel=0.25)
    assert blocked.bytes < naive.bytes  # the whole point of §VI-C3


def test_dryrun_single_cell_subprocess():
    """The dry-run entry point works as a CLI on the smallest cell."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-small", "--shape", "decode_32k"],
        capture_output=True, text=True, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"}, timeout=560)
    assert '"status": "ok"' in r.stdout, r.stdout + r.stderr[-2000:]


def test_train_resume_lifecycle(tmp_path):
    """Train 6 steps, kill, resume to 10 — the resumed run must produce the
    same step-10 loss as an uninterrupted run (determinism across restart)."""
    from repro.data.pipeline import make_batch
    from repro.models import init_lm
    from repro.optim.adamw import init_opt
    from repro.train.train_step import make_train_step
    from repro.checkpoint.ckpt import Checkpointer

    cfg = get_smoke_config("internlm2-1.8b")
    tc = TrainConfig(total_steps=10, warmup_steps=1)
    shape = ShapeConfig("t", 32, 4, "train")
    step_fn = jax.jit(make_train_step(cfg, tc))

    def fresh():
        p = init_lm(jax.random.PRNGKey(0), cfg)
        return p, init_opt(p, tc)

    # uninterrupted
    p, o = fresh()
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, i).items()}
        p, o, m = step_fn(p, o, batch)
    want = float(m["loss"])

    # interrupted at 6 + resumed
    p, o = fresh()
    ck = Checkpointer(str(tmp_path))
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, i).items()}
        p, o, m = step_fn(p, o, batch)
    ck.save(6, p, o)
    p2, o2 = fresh()
    p2_np, o2_np, start = ck.restore(p2, o2)
    p2 = jax.tree.map(jnp.asarray, p2_np)
    o2 = jax.tree.map(jnp.asarray, o2_np)
    for i in range(start, 10):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, i).items()}
        p2, o2, m2 = step_fn(p2, o2, batch)
    got = float(m2["loss"])
    assert got == pytest.approx(want, abs=1e-5)
