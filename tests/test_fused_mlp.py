"""Gradient parity for the fused SwiGLU/MLP Pallas kernel.

The kernel pair (forward fusing the gate/up GEMMs with the elementwise
combine + recompute-based dx/dw backward, wired via jax.custom_vjp in
kernels/fused_mlp/ops.py) must produce the same values and gradients as the
unfused jnp reference across mlp types (swiglu/gelu/relu2), aligned and
8h/3-misaligned d_ff, bf16, tuned dispatch, and through a full model train
step with linear_impl="fused".
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_mlp.ops import fused_mlp_hidden
from repro.kernels.fused_mlp.ref import fused_mlp_hidden_ref
from repro.tuning import TuningCache, set_default_cache

KEY = jax.random.PRNGKey(13)


def _problem(m, h, f, dtype=jnp.float32):
    x = (jax.random.normal(KEY, (m, h)) * 0.5).astype(dtype)
    wg = (jax.random.normal(jax.random.fold_in(KEY, 1), (h, f)) * 0.2).astype(dtype)
    wu = (jax.random.normal(jax.random.fold_in(KEY, 2), (h, f)) * 0.2).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (m, f))
    return x, wg, wu, w


def _grads(fn, x, wg, wu, w):
    # weighted-sum loss: non-trivial cotangents on every output element
    loss = lambda x, wg, wu: (fn(x, wg, wu).astype(jnp.float32) * w).sum()
    return jax.grad(loss, argnums=(0, 1, 2))(x, wg, wu)


def _assert_grads_close(got, want, atol, rtol):
    for g, r, name in zip(got, want, ("dx", "dwg", "dwu")):
        g = np.asarray(g, np.float32)
        assert np.isfinite(g).all(), f"{name} has non-finite entries"
        np.testing.assert_allclose(g, np.asarray(r, np.float32),
                                   atol=atol, rtol=rtol, err_msg=name)


class TestFusedMlpParity:
    # f=341 is the 8h/3 heuristic for h=128 — the §VII-B misaligned shape;
    # m=200 additionally pads the token axis
    @pytest.mark.parametrize("m,h,f", [
        (128, 128, 256),   # aligned
        (256, 128, 341),   # 8h/3-misaligned d_ff: padding path
        (200, 96, 160),    # every dim off the 128 grid
    ])
    @pytest.mark.parametrize("mlp_type", ["swiglu", "gelu", "relu2"])
    def test_forward_matches_reference(self, m, h, f, mlp_type):
        x, wg, wu, _ = _problem(m, h, f)
        got = fused_mlp_hidden(x, wg, wu, mlp_type=mlp_type, interpret=True)
        want = fused_mlp_hidden_ref(x, wg, wu, mlp_type)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("m,h,f", [
        (128, 128, 256),
        (256, 128, 341),
    ])
    @pytest.mark.parametrize("mlp_type", ["swiglu", "gelu", "relu2"])
    def test_grads_match_reference(self, m, h, f, mlp_type):
        x, wg, wu, w = _problem(m, h, f)
        got = _grads(lambda x, wg, wu: fused_mlp_hidden(
            x, wg, wu, mlp_type=mlp_type, interpret=True), x, wg, wu, w)
        want = _grads(lambda x, wg, wu: fused_mlp_hidden(
            x, wg, wu, mlp_type=mlp_type, use_pallas=False), x, wg, wu, w)
        if mlp_type != "swiglu":  # w_gate unused: both sides must be zero
            assert float(np.abs(np.asarray(got[1])).max()) == 0.0
        _assert_grads_close(got, want, atol=5e-4, rtol=5e-4)

    def test_bf16_finite_and_close(self):
        x, wg, wu, w = _problem(128, 128, 341, jnp.bfloat16)
        got = _grads(lambda x, wg, wu: fused_mlp_hidden(
            x, wg, wu, interpret=True), x, wg, wu, w)
        want = _grads(lambda x, wg, wu: fused_mlp_hidden(
            x, wg, wu, use_pallas=False), x, wg, wu, w)
        _assert_grads_close(got, want, atol=5e-2, rtol=5e-2)

    def test_block_size_invariance(self):
        x, wg, wu, w = _problem(256, 128, 512)
        g1 = _grads(lambda x, wg, wu: fused_mlp_hidden(
            x, wg, wu, block_m=128, block_f=128, block_k=128,
            bwd_block_m=128, bwd_block_f=128, interpret=True), x, wg, wu, w)
        g2 = _grads(lambda x, wg, wu: fused_mlp_hidden(
            x, wg, wu, block_m=256, block_f=256, block_k=64,
            bwd_block_m=64, bwd_block_f=256, interpret=True), x, wg, wu, w)
        _assert_grads_close(g1, g2, atol=2e-5, rtol=2e-5)

    def test_leading_dims_flattened(self):
        # (b, s, h) input: same values as the flattened 2-D problem
        x, wg, wu, _ = _problem(128, 96, 160)
        out3 = fused_mlp_hidden(x.reshape(4, 32, 96), wg, wu, interpret=True)
        out2 = fused_mlp_hidden(x, wg, wu, interpret=True)
        assert out3.shape == (4, 32, 160)
        np.testing.assert_allclose(np.asarray(out3.reshape(128, 160)),
                                   np.asarray(out2), atol=1e-6, rtol=1e-6)


class TestTunedFusedDispatch:
    @pytest.fixture(autouse=True)
    def _reset_default_cache(self):
        yield
        set_default_cache(None)

    def test_autotune_then_tuned_grads_match(self):
        from repro.tuning.search import autotune_fused_mlp
        m, h, f = 128, 128, 256
        cache = TuningCache()
        cfg = autotune_fused_mlp(m, h, f, cache=cache, iters=1, warmup=1,
                                 max_candidates=2)
        assert cfg.op == "fused_mlp_swiglu"
        assert cache.get("fused_mlp_swiglu", (m, h, f), "float32",
                         cfg.hw_name) == cfg
        set_default_cache(cache)
        x, wg, wu, w = _problem(m, h, f)
        got = _grads(lambda x, wg, wu: fused_mlp_hidden(
            x, wg, wu, tuned=True, interpret=True), x, wg, wu, w)
        want = _grads(lambda x, wg, wu: fused_mlp_hidden(
            x, wg, wu, use_pallas=False), x, wg, wu, w)
        _assert_grads_close(got, want, atol=5e-4, rtol=5e-4)


class TestFusedImplInModel:
    def _cfg(self, **kw):
        from repro.configs.base import ModelConfig
        kw.setdefault("mlp_type", "swiglu")
        return ModelConfig(name="t", family="dense", num_layers=2,
                           d_model=128, num_heads=4, num_kv_heads=2,
                           d_ff=256, vocab_size=512, dtype="float32", **kw)

    @pytest.mark.parametrize("mlp_type", ["swiglu", "relu2"])
    def test_fused_impl_grads_match_jnp(self, mlp_type):
        from repro.models import lm_loss
        from repro.models.lm import init_lm
        cfg = self._cfg(mlp_type=mlp_type)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (2, 64), 0, 512),
                 "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                              (2, 64), 0, 512)}

        def grads(impl):
            c = dataclasses.replace(cfg, linear_impl=impl)
            return jax.grad(lambda p: lm_loss(p, batch, c)[0])(params)

        gn, gf = grads("jnp"), grads("fused")
        for a, b in zip(jax.tree.leaves(gn), jax.tree.leaves(gf)):
            a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
            assert np.isfinite(b).all()
            np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-3)

    @pytest.mark.parametrize("mlp_type", ["swiglu", "gelu"])
    def test_fused_impl_moe_experts_match_jnp(self, mlp_type):
        # the expert path: linear_impl="fused" routes the per-expert gate/up
        # pair through expert_fused_hidden (lax.map of the fused kernel)
        import dataclasses
        from repro.configs.base import ModelConfig
        from repro.models.moe import apply_moe, init_moe
        cfg = ModelConfig(name="t", family="moe", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=512, mlp_type=mlp_type, num_experts=4,
                          top_k=2, moe_d_ff=96, dtype="float32")
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64)) * 0.5

        def run(impl):
            c = dataclasses.replace(cfg, linear_impl=impl)
            y, aux = apply_moe(p, x, c)
            g = jax.grad(lambda p: apply_moe(p, x, c)[0].sum())(p)
            return y, g

        yj, gj = run("jnp")
        yf, gf = run("fused")
        np.testing.assert_allclose(np.asarray(yf), np.asarray(yj),
                                   atol=5e-4, rtol=5e-4)
        for a, b in zip(jax.tree.leaves(gj), jax.tree.leaves(gf)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, rtol=5e-3)

    def test_fused_impl_train_step(self):
        # causal train_step parity criterion: one optimizer step on the
        # fully-fused path moves the params and keeps the loss finite
        from repro.configs.base import TrainConfig
        from repro.models.lm import init_lm
        from repro.optim.adamw import init_opt
        from repro.train.train_step import make_train_step
        cfg = self._cfg(linear_impl="fused")
        tc = TrainConfig(total_steps=2, warmup_steps=1)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        opt = init_opt(params, tc)
        step = make_train_step(cfg, tc)
        key = jax.random.PRNGKey(2)
        batch = {"tokens": jax.random.randint(key, (2, 64), 0, 512),
                 "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                              (2, 64), 0, 512)}
        before = jax.tree.map(lambda p: np.asarray(p).copy(), params)
        params, opt, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        moved = jax.tree.map(lambda a, b: float(np.abs(np.asarray(a) - b).max()),
                             params, before)
        assert any(m > 0 for m in jax.tree.leaves(moved))
