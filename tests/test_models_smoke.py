"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; decode/prefill parity for cached inference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import make_batch
from repro.models import apply_lm, init_caches, init_lm
from repro.optim.adamw import init_opt
from repro.train.train_step import make_train_step

ARCHS = ["zamba2-2.7b", "qwen1.5-4b", "nemotron-4-340b", "internlm2-1.8b",
         "command-r-plus-104b", "deepseek-v3-671b",
         "llama4-maverick-400b-a17b", "internvl2-76b", "whisper-small",
         "mamba2-780m"]

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    shape = ShapeConfig("t", s, b, "train")
    return {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, 0).items()}


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = init_lm(KEY, cfg)
        batch = _batch(cfg)
        logits, _, _ = jax.jit(
            lambda p, b: apply_lm(p, b["tokens"], cfg,
                                  patch_embeds=b.get("patch_embeds"),
                                  encoder_frames=b.get("encoder_frames"))
        )(params, batch)
        s_expected = batch["tokens"].shape[1] + (
            cfg.num_patches if cfg.family == "vlm" else 0)
        assert logits.shape == (2, s_expected, cfg.padded_vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_train_step_runs_and_is_finite(self, arch):
        cfg = get_smoke_config(arch)
        tc = TrainConfig(total_steps=10, warmup_steps=1)
        params = init_lm(KEY, cfg)
        opt = init_opt(params, tc)
        step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
        params, opt, m = step(params, opt, _batch(cfg))
        assert np.isfinite(float(m["loss"]))
        assert float(m["grad_norm"]) > 0
        for leaf in jax.tree.leaves(params):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen1.5-4b",
                                  "mamba2-780m", "zamba2-2.7b",
                                  "deepseek-v3-671b"])
def test_decode_matches_full_forward(arch):
    """Prefill(s-1) + decode(1) must equal the full uncached forward at the
    last position — validates KV caches, MLA latent cache, SSM states.

    MoE runs with a drop-free capacity factor: capacity-based routing
    legitimately drops differently between a 64-token and a 1-token batch,
    which is a semantic property of Switch-style MoE, not a cache bug."""
    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = init_lm(KEY, cfg)
    b, s = 2, 32
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)

    full_logits, _, _ = apply_lm(params, toks, cfg)
    want = full_logits[:, -1]

    caches = init_caches(cfg, b, s, jnp.float32)
    _, caches, _ = apply_lm(params, toks[:, :-1], cfg, caches=caches,
                            cache_index=0)
    got_logits, _, _ = apply_lm(params, toks[:, -1:], cfg, caches=caches,
                                cache_index=s - 1, decode=True)
    got = got_logits[:, -1]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_blocked_attn_impl_matches_naive_in_model():
    cfg = get_smoke_config("internlm2-1.8b")
    params = init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
    l_naive, _, _ = apply_lm(params, toks, cfg)
    cfg_b = dataclasses.replace(cfg, attn_impl="blocked", attn_block_kv=32)
    l_blocked, _, _ = apply_lm(params, toks, cfg_b)
    np.testing.assert_allclose(np.asarray(l_naive), np.asarray(l_blocked),
                               atol=2e-4, rtol=2e-4)


def test_parallel_layers_structure():
    """§VI-C1: parallel blocks compute y = x + Attn(N(x)) + MLP(N(x))."""
    cfg = get_smoke_config("command-r-plus-104b")
    assert cfg.parallel_layers
    params = init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    logits, _, _ = apply_lm(params, toks, cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_decreases_e2e():
    cfg = get_smoke_config("internlm2-1.8b")
    tc = TrainConfig(total_steps=40, warmup_steps=4, learning_rate=1e-3)
    params = init_lm(KEY, cfg)
    opt = init_opt(params, tc)
    step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    shape = ShapeConfig("t", 64, 8, "train")
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
