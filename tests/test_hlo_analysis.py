"""HLO structural analyzer: trip-count multiplication, dot FLOPs,
collective byte census — validated against a known jit program."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_analysis import analyze_hlo, parse_module


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestAnalyzer:
    def test_plain_matmul_flops_exact(self):
        m, k, n = 128, 256, 64
        a = jnp.zeros((m, k), jnp.float32)
        b = jnp.zeros((k, n), jnp.float32)
        txt = _hlo(lambda a, b: a @ b, a, b)
        c = analyze_hlo(txt)
        assert c.flops == pytest.approx(2 * m * k * n, rel=1e-6)
        assert c.dots >= 1

    def test_scan_multiplies_by_trip_count(self):
        m = 64
        w = jnp.zeros((8, m, m), jnp.float32)  # 8 scanned layers

        def f(x, w):
            def body(h, wi):
                return jnp.tanh(h @ wi), None
            h, _ = jax.lax.scan(body, x, w)
            return h

        txt = _hlo(f, jnp.zeros((4, m)), w)
        c = analyze_hlo(txt)
        want = 8 * 2 * 4 * m * m  # trips x dot flops
        assert c.flops == pytest.approx(want, rel=0.01)
        assert 8 in c.loops.values()

    def test_nested_scan(self):
        m = 32
        w = jnp.zeros((3, 5, m, m), jnp.float32)

        def f(x, w):
            def outer(h, wo):
                def inner(h2, wi):
                    return h2 @ wi, None
                h, _ = jax.lax.scan(inner, h, wo)
                return h, None
            h, _ = jax.lax.scan(outer, x, w)
            return h

        txt = _hlo(f, jnp.zeros((2, m)), w)
        c = analyze_hlo(txt)
        want = 15 * 2 * 2 * m * m
        assert c.flops == pytest.approx(want, rel=0.01)

    def test_bytes_positive_and_reasonable(self):
        a = jnp.zeros((256, 256), jnp.float32)
        txt = _hlo(lambda a: jnp.tanh(a) + 1.0, a)
        c = analyze_hlo(txt)
        nbytes = 256 * 256 * 4
        assert nbytes <= c.bytes <= 6 * nbytes

    def test_parse_module_finds_entry(self):
        txt = _hlo(lambda x: x * 2, jnp.zeros((4,)))
        comps, entry = parse_module(txt)
        assert entry is not None and entry in comps


class TestCollectiveCensus:
    def test_psum_counted_as_all_reduce(self):
        import subprocess
        import sys
        import textwrap
        # collectives need >1 device: run in a subprocess with 4 host devices
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P, NamedSharding
            import sys
            sys.path.insert(0, "src")
            from repro.core.hlo_analysis import analyze_hlo
            mesh = jax.make_mesh((4,), ("d",))
            s = NamedSharding(mesh, P("d", None))
            x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
            def f(x):
                return jnp.sum(x @ x.T)
            txt = jax.jit(f, in_shardings=s).lower(x).compile().as_text()
            c = analyze_hlo(txt)
            assert c.coll_total > 0, "expected collective traffic"
            print("COLL_OK", c.coll_total)
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, cwd="/root/repo", timeout=300)
        assert "COLL_OK" in r.stdout, r.stdout + r.stderr
