"""Tier-1: the codesign lint engine (repro.analysis).

Fixture files under tests/fixtures/analysis/ demonstrate every rule firing
on a deliberately-bad example and being silenced by a `# repro: noqa[...]`
pragma; the registry golden checks pin the audit's behavior on the real
config registry (gpt3-smoke's vocab 251 is flagged, aligned production
configs pass, and nothing gates CI on the shipped tree).
"""
import io
import os
import subprocess
import sys

import pytest

from repro.analysis import RULES, analyze, audit_config, audit_registry
from repro.analysis.engine import AnalysisResult
from repro.analysis.findings import (Finding, severity_at_least,
                                     worst_severity)
from repro.analysis.reporters import render_json, render_text
from repro.analysis.source import load_source
from repro.configs.base import ModelConfig
from repro.core.hardware import get_hardware

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def fx(*parts):
    return os.path.join(FIXTURES, *parts)


def run(paths, **kw):
    kw.setdefault("registry_audit", False)
    return analyze(paths, **kw)


def rule_ids(result: AnalysisResult):
    return sorted({f.rule_id for f in result.findings})


# -- per-file passes on fixtures ---------------------------------------------


def test_kernel_bad_fires_all_per_file_rules():
    r = run([fx("kernel_bad.py")])
    assert rule_ids(r) == ["KRN101", "KRN102", "KRN103"]
    for f in r.findings:
        assert f.fix_hint  # every KRN finding carries a concrete fix


def test_kernel_ok_is_clean():
    assert run([fx("kernel_ok.py")]).findings == []


def test_kernel_noqa_suppresses_everything():
    assert run([fx("kernel_noqa.py")]).findings == []


def test_jit_bad_fires_all_jit_rules():
    r = run([fx("jit_bad.py")])
    ids = rule_ids(r)
    assert ids == ["JIT201", "JIT202", "JIT203", "JIT204"]
    # the jax.jit(step) factory-closure root is reached
    assert any(f.rule_id == "JIT203" and "'step'" in f.message
               for f in r.findings)
    # both JIT204 shapes: global decl and mutated-module-dict capture
    j204 = [f for f in r.findings if f.rule_id == "JIT204"]
    assert any("global" in f.message for f in j204)
    assert any("_CACHE" in f.message for f in j204)


def test_jit_ok_is_clean():
    assert run([fx("jit_ok.py")]).findings == []


def test_jit_noqa_suppresses():
    assert run([fx("jit_noqa.py")]).findings == []


def test_syntax_error_and_bad_pragma():
    r = run([fx("syntax_error.py"), fx("bad_pragma.py")])
    assert rule_ids(r) == ["ANA001", "ANA002"]


def test_docstring_pragma_examples_do_not_suppress():
    # scan_pragmas must only read real comments: the analysis package's own
    # docstrings *show* the noqa syntax and must neither suppress nor raise
    # ANA002.
    sf = load_source(os.path.join(SRC, "repro", "analysis", "source.py"))
    assert sf.suppressions.unknown == []


# -- cross-module tuned-op contract ------------------------------------------


def test_contract_bad_tree():
    r = run([fx("contract_bad")])
    ids = [f.rule_id for f in r.findings]
    assert ids.count("KRN104") == 1  # ghost_op never written
    assert ids.count("KRN105") == 1  # 3-element lookup vs 2-element write
    assert ids.count("KRN106") == 2  # no lattice + lattice without VMEM
    assert ids.count("KRN107") == 2  # dead_op, nolattice_op never consulted
    k104 = next(f for f in r.findings if f.rule_id == "KRN104")
    assert "ghost_op" in k104.message


def test_contract_needs_autotune_in_scope():
    # scanning only the ops side must not raise contract findings (the
    # search module defines the other half of the contract)
    r = run([fx("contract_bad", "kernels")])
    assert all(not f.rule_id.startswith("KRN10") or
               f.rule_id in ("KRN101", "KRN102", "KRN103")
               for f in r.findings)
    assert "KRN104" not in rule_ids(r)


# -- shape audit --------------------------------------------------------------


HW = get_hardware("tpu_v5e")


def _cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=1024,
                num_heads=8, num_kv_heads=8, d_ff=4096, vocab_size=50304)
    base.update(kw)
    return ModelConfig(**base)


def test_aligned_config_is_clean():
    assert audit_config(_cfg(), HW) == []


def test_vocab_misalignment_priced_and_warn_when_padded():
    raws = audit_config(_cfg(vocab_size=50257), HW)
    assert [r.rule_id for r in raws] == ["SHP101"]
    # padded_vocab_size mitigates at runtime -> warn, not error
    assert raws[0].severity == "warn"
    assert "50304" in raws[0].fix_hint
    assert "%" in raws[0].fix_hint  # priced through the GEMM model


def test_dff_misalignment_is_error_on_production():
    raws = audit_config(_cfg(d_ff=11007), HW)
    assert [r.rule_id for r in raws] == ["SHP103"]
    assert raws[0].severity == "error"
    assert "11008" in raws[0].fix_hint


def test_production_false_downgrades_to_warn():
    raws = audit_config(_cfg(d_ff=11007, production=False), HW)
    assert raws[0].severity == "warn"


def test_head_dim_misalignment_severity_split():
    # pow2 factor 16 < 64 -> error on a production config
    bad = audit_config(_cfg(d_model=2560, num_heads=32, num_kv_heads=32,
                            d_ff=10240), HW)
    assert any(r.rule_id == "SHP102" and r.severity == "error" for r in bad)
    # pow2 factor 64 -> warn (usable, sub-optimal)
    mid = audit_config(_cfg(d_model=768, num_heads=12, num_kv_heads=12,
                            d_ff=3072), HW)
    assert any(r.rule_id == "SHP102" and r.severity == "warn" for r in mid)


def test_wave_quantization_only_on_concurrent_tile_hw():
    gpu = get_hardware("a100")
    cfg = _cfg(d_ff=13000)
    assert not any(r.rule_id == "SHP106" for r in audit_config(cfg, HW))
    gpu_raws = audit_config(cfg, gpu)
    # may or may not trip the 0.90 threshold at this d_ff, but never on TPU
    for r in gpu_raws:
        if r.rule_id == "SHP106":
            assert r.severity == "warn"


# -- registry goldens ---------------------------------------------------------


def test_registry_golden_gpt3_smoke_vocab_flagged():
    findings = audit_registry(hw_name="tpu_v5e")
    smoke = [f for f in findings
             if f.arch == "gpt3-smoke" and f.rule_id == "SHP101"]
    assert len(smoke) == 1
    f = smoke[0]
    assert "251" in f.message
    assert "256" in f.fix_hint
    assert f.severity == "warn"  # smoke configs never gate
    assert f.file.endswith("gpt3_2p7b.py")
    assert f.line > 1  # anchored at the literal, not the file top


def test_registry_golden_aligned_configs_pass():
    findings = audit_registry(hw_name="tpu_v5e")
    flagged = {f.arch for f in findings}
    # lane-aligned production configs stay silent
    for name in ("qwen1.5-4b", "internlm2-1.8b", "command-r-plus-104b",
                 "llama4-maverick-400b-a17b"):
        assert name not in flagged or all(
            f.rule_id == "SHP101" for f in findings if f.arch == name)


def test_registry_audit_gates_nothing_on_shipped_tree():
    # the CI contract: no error-severity shape finding on the shipped
    # registry (zamba2's published head_dim 80 carries a justified noqa)
    findings = audit_registry(hw_name="tpu_v5e")
    assert worst_severity(findings) in (None, "info", "warn")


def test_registry_smoke_exclusion():
    with_smoke = audit_registry(hw_name="tpu_v5e", include_smoke=True)
    without = audit_registry(hw_name="tpu_v5e", include_smoke=False)
    assert len(without) < len(with_smoke)
    assert not any(f.arch.endswith("-smoke") for f in without)


# -- framework: severities, reporters, CLI ------------------------------------


def test_severity_order():
    assert severity_at_least("error", "warn")
    assert not severity_at_least("info", "warn")
    with pytest.raises(ValueError):
        Finding("f", 1, "X", "fatal", "m")


def test_every_rule_documented_and_typed():
    for rule in RULES.values():
        assert rule.default_severity in ("info", "warn", "error")
        assert rule.doc
        assert rule.pass_name in ("shape", "kernel", "jit", "engine")


def test_reporters_roundtrip():
    r = run([fx("kernel_bad.py")])
    text = io.StringIO()
    render_text(r.findings, text)
    out = text.getvalue()
    assert "KRN101" in out and "fix:" in out
    js = io.StringIO()
    render_json(r.findings, js, meta={"paths": ["x"]})
    import json

    doc = json.loads(js.getvalue())
    assert doc["counts"]["error"] == len(r.findings)
    assert {f["rule_id"] for f in doc["findings"]} == set(rule_ids(r))
    assert Finding.from_json(doc["findings"][0]).rule_id


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=FIXTURES)


def test_cli_gate_and_formats(tmp_path):
    # bad fixture at --fail-on error -> exit 1
    p = _cli("kernel_bad.py", "--no-registry-audit")
    assert p.returncode == 1
    assert "KRN101" in p.stdout
    # clean fixture -> exit 0
    p = _cli("kernel_ok.py", "--no-registry-audit")
    assert p.returncode == 0
    # warn threshold gates warns too
    p = _cli("bad_pragma.py", "--no-registry-audit", "--fail-on", "warn")
    assert p.returncode == 1
    # JSON artifact
    out = tmp_path / "report.json"
    p = _cli("kernel_bad.py", "--no-registry-audit", "--format", "json",
             "--output", str(out))
    import json

    doc = json.loads(out.read_text())
    assert doc["counts"]["error"] >= 3
    # rule catalog
    p = _cli("--list-rules")
    assert p.returncode == 0
    assert "SHP101" in p.stdout and "KRN103" in p.stdout


def test_cli_full_tree_gate_is_green():
    # the CI gate itself: the shipped tree passes at --fail-on error
    p = _cli("../../../src", "--fail-on", "error")
    assert p.returncode == 0, p.stdout + p.stderr
