"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import _fold, _unfold, flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.matmul.ops import alignment_report, matmul
from repro.kernels.matmul.ref import matmul_ref

KEY = jax.random.PRNGKey(7)


class TestMatmulKernel:
    @pytest.mark.parametrize("m,k,n", [
        (128, 128, 128), (256, 128, 384), (128, 512, 128),
        (200, 80, 72),       # misaligned: exercises the padding path
        (64, 64, 64), (384, 256, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matmul_sweep(self, m, k, n, dtype):
        a = jax.random.normal(KEY, (m, k), dtype)
        b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), dtype)
        got = matmul(a, b, interpret=True)
        want = matmul_ref(a, b)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=tol, rtol=tol)

    @pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (64, 128, 64)])
    def test_block_shapes(self, bm, bn, bk):
        a = jax.random.normal(KEY, (256, 256), jnp.float32)
        b = jax.random.normal(KEY, (256, 256), jnp.float32)
        got = matmul(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                                   atol=2e-4, rtol=2e-5)

    def test_alignment_report(self):
        r = alignment_report(4096, 80, 4096)
        assert not r["aligned"]
        assert r["mxu_utilization"] == pytest.approx(80 / 128, rel=1e-3)
        assert alignment_report(4096, 128, 4096)["aligned"]


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,s,a,kv,d", [
        (2, 256, 4, 4, 64),   # MHA
        (1, 256, 8, 2, 128),  # GQA 4:1
        (2, 128, 4, 1, 64),   # MQA
        (1, 200, 4, 2, 64),   # misaligned seq: padding path
        (1, 384, 2, 2, 32),   # small head_dim
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_flash_sweep(self, b, s, a, kv, d, causal):
        # non-causal unaligned shapes exercise the kernel's kv_len column
        # masking (padded keys no longer hide behind the causal rule)
        q = jax.random.normal(KEY, (b, s, a, d), jnp.float32) * 0.5
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kv, d)) * 0.5
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kv, d)) * 0.5
        got = flash_attention(q, k, v, causal=causal, interpret=True)
        want = _unfold(attention_ref(_fold(q), _fold(k), _fold(v),
                                     causal=causal), b, a)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_flash_bf16(self):
        b, s, a, d = 1, 256, 4, 64
        q = (jax.random.normal(KEY, (b, s, a, d)) * 0.5).astype(jnp.bfloat16)
        k = (jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, a, d)) * 0.5).astype(jnp.bfloat16)
        v = (jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, a, d)) * 0.5).astype(jnp.bfloat16)
        got = flash_attention(q, k, v, causal=True, interpret=True)
        want = _unfold(attention_ref(_fold(q), _fold(k), _fold(v), causal=True),
                       b, a)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=3e-2, rtol=3e-2)

    def test_block_size_invariance(self):
        b, s, a, d = 1, 512, 2, 64
        q = jax.random.normal(KEY, (b, s, a, d)) * 0.5
        k = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, a, d)) * 0.5
        v = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, a, d)) * 0.5
        o1 = flash_attention(q, k, v, block_q=128, block_kv=128, interpret=True)
        o2 = flash_attention(q, k, v, block_q=256, block_kv=64, interpret=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-5, rtol=2e-5)


class TestBlockedAttentionXLA:
    """The XLA twin (models/blocked_attention) must match both the naive
    reference and the Pallas kernel."""

    def test_matches_naive_and_kernel(self):
        from repro.models.attention import _sdpa
        from repro.models.blocked_attention import blocked_sdpa
        b, s, a, kv, d = 2, 256, 4, 2, 64
        q = jax.random.normal(KEY, (b, s, a, d)) * 0.5
        k = jax.random.normal(jax.random.fold_in(KEY, 5), (b, s, kv, d)) * 0.5
        v = jax.random.normal(jax.random.fold_in(KEY, 6), (b, s, kv, d)) * 0.5
        naive = _sdpa(q, k, v, causal=True)
        blocked = blocked_sdpa(q, k, v, causal=True, block_kv=64)
        pallas = flash_attention(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(naive),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(pallas), np.asarray(naive),
                                   atol=3e-5, rtol=3e-5)
