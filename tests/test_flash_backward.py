"""Gradient parity for the differentiable Pallas flash attention.

The kernel pair (forward with logsumexp residuals + fused dq/dkv backward,
wired via jax.custom_vjp in kernels/flash_attention/ops.py) must produce the
same gradients as the jnp reference across causal/non-causal, GQA head
ratios, unaligned (sq, skv) shapes, and through a full model train step —
plus the `blocked_sdpa` XLA twin, which differentiates natively.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.tuning import TuningCache, set_default_cache

KEY = jax.random.PRNGKey(11)


def _qkv(b, sq, skv, a, kv, d, dtype=jnp.float32):
    q = (jax.random.normal(KEY, (b, sq, a, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, 1), (b, skv, kv, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(KEY, 2), (b, skv, kv, d)) * 0.5).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (b, sq, a, d))
    return q, k, v, w


def _grads(fn, q, k, v, w):
    # weighted-sum loss: non-trivial cotangents on every output element
    loss = lambda q, k, v: (fn(q, k, v).astype(jnp.float32) * w).sum()
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


def _assert_grads_close(got, want, atol, rtol):
    for g, r, name in zip(got, want, ("dq", "dk", "dv")):
        g = np.asarray(g, np.float32)
        assert np.isfinite(g).all(), f"{name} has non-finite entries"
        np.testing.assert_allclose(g, np.asarray(r, np.float32),
                                   atol=atol, rtol=rtol, err_msg=name)


class TestFlashGradParity:
    @pytest.mark.parametrize("b,sq,skv,a,kv,d", [
        (2, 256, 256, 4, 4, 64),   # MHA, aligned
        (1, 256, 256, 8, 2, 128),  # GQA 4:1
        (2, 128, 128, 4, 1, 64),   # MQA
        (1, 200, 200, 4, 2, 64),   # unaligned sq == skv: padding path
        (1, 192, 136, 4, 2, 64),   # unaligned cross shape sq != skv
        (1, 384, 384, 2, 2, 32),   # small head_dim
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_grads_match_reference(self, b, sq, skv, a, kv, d, causal):
        if causal and sq != skv:
            pytest.skip("causal flash assumes self-attention (sq == skv)")
        q, k, v, w = _qkv(b, sq, skv, a, kv, d)
        got = _grads(lambda q, k, v: flash_attention(
            q, k, v, causal=causal, interpret=True), q, k, v, w)
        want = _grads(lambda q, k, v: flash_attention(
            q, k, v, causal=causal, use_pallas=False), q, k, v, w)
        _assert_grads_close(got, want, atol=2e-4, rtol=2e-4)

    def test_grads_bf16_finite_and_close(self):
        q, k, v, w = _qkv(1, 256, 256, 4, 2, 64, jnp.bfloat16)
        got = _grads(lambda q, k, v: flash_attention(
            q, k, v, causal=True, interpret=True), q, k, v, w)
        want = _grads(lambda q, k, v: flash_attention(
            q, k, v, causal=True, use_pallas=False), q, k, v, w)
        _assert_grads_close(got, want, atol=5e-2, rtol=5e-2)

    def test_backward_block_size_invariance(self):
        q, k, v, w = _qkv(1, 512, 512, 2, 2, 64)
        g1 = _grads(lambda q, k, v: flash_attention(
            q, k, v, bwd_block_q=128, bwd_block_kv=128, interpret=True),
            q, k, v, w)
        g2 = _grads(lambda q, k, v: flash_attention(
            q, k, v, bwd_block_q=256, bwd_block_kv=64, interpret=True),
            q, k, v, w)
        _assert_grads_close(g1, g2, atol=2e-5, rtol=2e-5)

    def test_padded_rows_zero_not_nan(self):
        # sq=200 pads 56 query rows and 56 kv columns inside the kernel; the
        # masked-row lse guard must keep every padded-path exp() finite and
        # the padding's gradient contribution exactly zero
        q, k, v, w = _qkv(1, 200, 200, 2, 2, 64)
        got = _grads(lambda q, k, v: flash_attention(
            q, k, v, causal=True, interpret=True), q, k, v, w)
        want = _grads(lambda q, k, v: flash_attention(
            q, k, v, causal=True, use_pallas=False), q, k, v, w)
        _assert_grads_close(got, want, atol=2e-4, rtol=2e-4)


class TestBlockedSdpaGrads:
    def test_blocked_sdpa_grad_parity(self):
        from repro.models.attention import _sdpa
        from repro.models.blocked_attention import blocked_sdpa
        b, s, a, kv, d = 2, 256, 4, 2, 64
        q, k, v, w = _qkv(b, s, s, a, kv, d)
        got = _grads(lambda q, k, v: blocked_sdpa(
            q, k, v, causal=True, block_kv=64), q, k, v, w)
        want = _grads(lambda q, k, v: _sdpa(q, k, v, causal=True), q, k, v, w)
        _assert_grads_close(got, want, atol=2e-5, rtol=2e-5)


class TestTunedBackwardDispatch:
    @pytest.fixture(autouse=True)
    def _reset_default_cache(self):
        yield
        set_default_cache(None)

    def test_autotune_flash_backward_then_tuned_grads_match(self):
        from repro.tuning.search import autotune_flash_backward
        b, s, a, d = 1, 128, 2, 64
        cache = TuningCache()
        cfg = autotune_flash_backward(b, s, a, d, cache=cache, iters=1,
                                      warmup=1, max_candidates=2)
        assert cfg.op == "flash_attention_bwd_causal"
        assert cache.get("flash_attention_bwd_causal", (b, s, s, a, d),
                         "float32", cfg.hw_name) == cfg
        set_default_cache(cache)
        q, k, v, w = _qkv(b, s, s, a, a, d)
        got = _grads(lambda q, k, v: flash_attention(
            q, k, v, tuned=True, interpret=True), q, k, v, w)
        want = _grads(lambda q, k, v: flash_attention(
            q, k, v, use_pallas=False), q, k, v, w)
        _assert_grads_close(got, want, atol=2e-4, rtol=2e-4)


class TestFlashImplInModel:
    def _cfg(self, **kw):
        from repro.configs.base import ModelConfig
        return ModelConfig(name="t", family="dense", num_layers=2,
                           d_model=128, num_heads=4, num_kv_heads=2,
                           d_ff=256, vocab_size=512, dtype="float32", **kw)

    def test_flash_impl_grads_match_naive(self):
        from repro.models import lm_loss
        from repro.models.lm import init_lm
        cfg = self._cfg()
        params = init_lm(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (2, 96), 0, 512),
                 "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                              (2, 96), 0, 512)}

        def grads(impl):
            c = dataclasses.replace(cfg, attn_impl=impl)
            return jax.grad(lambda p: lm_loss(p, batch, c)[0])(params)

        gn, gf = grads("naive"), grads("flash")
        for a, b in zip(jax.tree.leaves(gn), jax.tree.leaves(gf)):
            a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
            assert np.isfinite(b).all()
            np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-3)

    def test_flash_impl_train_step(self):
        from repro.configs.base import TrainConfig
        from repro.models.lm import init_lm
        from repro.optim.adamw import init_opt
        from repro.train.train_step import make_train_step
        cfg = self._cfg(attn_impl="flash")
        tc = TrainConfig(total_steps=2, warmup_steps=1)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        opt = init_opt(params, tc)
        step = make_train_step(cfg, tc)
        key = jax.random.PRNGKey(2)
        batch = {"tokens": jax.random.randint(key, (2, 64), 0, 512),
                 "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                              (2, 64), 0, 512)}
        before = jax.tree.map(lambda p: np.asarray(p).copy(), params)
        params, opt, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        # one optimizer step actually moved the parameters (all-zero grads
        # through the fused backward would leave them at their init values)
        moved = jax.tree.map(lambda a, b: float(np.abs(np.asarray(a) - b).max()),
                             params, before)
        assert any(m > 0 for m in jax.tree.leaves(moved))
