"""The unified linear-execution layer (repro.models.linear) and the >2-D
tuned-matmul cache-key regression.

`linear` must flatten (b, s, h) activations to the exact (m, k, n) key the
autotuner writes (a 3-D operand used to silently miss the cache), agree
with the jnp oracle on every impl, and differentiate through the Pallas
custom-VJP path.
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul.ops import matmul

# repro.kernels re-exports the matmul *function*, shadowing the submodule
# attribute — import the ops module by name for monkeypatching
matmul_ops = importlib.import_module("repro.kernels.matmul.ops")
from repro.models.linear import expert_linear, linear
from repro.tuning import TuningCache, set_default_cache

KEY = jax.random.PRNGKey(3)


class TestMatmulNdOperands:
    def test_3d_matches_2d(self):
        a = jax.random.normal(KEY, (2, 24, 64))
        b = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 96))
        got = matmul(a, b, interpret=True)
        assert got.shape == (2, 24, 96)
        want = matmul(a.reshape(48, 64), b, interpret=True).reshape(2, 24, 96)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6, rtol=1e-6)

    def test_tuned_3d_keys_flattened_shape(self, monkeypatch):
        # regression: a (b, s, h) operand must consult the cache with the
        # (b*s, h, n) key autotune_matmul writes, not miss silently
        seen = []
        real = matmul_ops._tuning_lookup

        def spy(op, shape, dtype, hw):
            seen.append((op, tuple(shape)))
            return real(op, shape, dtype, hw)

        monkeypatch.setattr(matmul_ops, "_tuning_lookup", spy)
        a = jax.random.normal(KEY, (2, 24, 64))
        b = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 96))
        matmul(a, b, tuned=True, interpret=True)
        assert seen == [("matmul", (48, 64, 96))]

    def test_tuned_3d_uses_cached_blocks(self):
        from repro.tuning.search import autotune_matmul
        cache = TuningCache()
        cfg = autotune_matmul(48, 64, 96, cache=cache, iters=1, warmup=1,
                              max_candidates=2)
        assert cfg.shape == (48, 64, 96)
        set_default_cache(cache)
        try:
            a = jax.random.normal(KEY, (2, 24, 64))
            b = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 96))
            got = matmul(a, b, tuned=True, interpret=True)
            want = jnp.einsum("bsk,kn->bsn", a, b)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=2e-4, rtol=2e-5)
        finally:
            set_default_cache(None)


class TestLinearDispatch:
    @pytest.mark.parametrize("impl", ["jnp", "pallas", "tuned", "fused"])
    def test_impl_parity_3d(self, impl):
        x = jax.random.normal(KEY, (2, 24, 64))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 96))
        got = linear(x, w, impl=impl)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(jnp.einsum("bsk,kn->bsn", x, w)),
                                   atol=2e-4, rtol=2e-4)

    def test_unknown_impl_raises(self):
        x = jnp.ones((4, 8))
        with pytest.raises(ValueError, match="linear_impl"):
            linear(x, jnp.ones((8, 8)), impl="cuda")

    def test_weight_cast_to_activation_dtype(self):
        x = jnp.ones((4, 8), jnp.bfloat16)
        w = jnp.ones((8, 8), jnp.float32)  # f32 master copy
        assert linear(x, w, impl="jnp").dtype == jnp.bfloat16
        assert linear(x, w, impl="pallas").dtype == jnp.bfloat16

    def test_pallas_grads_match_jnp(self):
        x = jax.random.normal(KEY, (2, 16, 64))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 96))

        def loss(impl):
            return jax.grad(
                lambda x, w: linear(x, w, impl=impl).sum(), argnums=(0, 1))
        gx_p, gw_p = loss("pallas")(x, w)
        gx_j, gw_j = loss("jnp")(x, w)
        np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_j),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_j),
                                   atol=2e-4, rtol=2e-4)


class TestExpertLinear:
    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_expert_parity(self, impl):
        x = jax.random.normal(KEY, (3, 16, 32))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 32, 48))
        got = expert_linear(x, w, impl=impl)
        want = jnp.einsum("emk,ekn->emn", x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-4)

    def test_expert_grads_match(self):
        x = jax.random.normal(KEY, (2, 16, 32))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 32, 48))

        def g(impl):
            return jax.grad(lambda x, w: expert_linear(
                x, w, impl=impl).sum(), argnums=(0, 1))(x, w)
        for a, b in zip(g("pallas"), g("jnp")):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-4)


class TestModelImplParity:
    def _cfg(self, **kw):
        from repro.configs.base import ModelConfig
        return ModelConfig(name="t", family="dense", num_layers=2,
                           d_model=128, num_heads=4, num_kv_heads=2,
                           d_ff=256, vocab_size=512, dtype="float32", **kw)

    def test_pallas_impl_logits_match_jnp(self):
        import dataclasses
        from repro.models import apply_lm
        from repro.models.lm import init_lm
        cfg = self._cfg()
        params = init_lm(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, 512)
        lj, _, _ = apply_lm(params, tokens, cfg)
        lp, _, _ = apply_lm(params, tokens,
                            dataclasses.replace(cfg, linear_impl="pallas"))
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lj),
                                   atol=2e-3, rtol=2e-3)


class TestActivationErrors:
    def test_unknown_activation_lists_valid_names(self):
        from repro.models.layers import activation
        with pytest.raises(ValueError) as e:
            activation("swish")
        msg = str(e.value)
        for name in ("gelu", "silu", "relu2"):
            assert name in msg
        assert "swish" in msg

    def test_known_activations_still_work(self):
        from repro.models.layers import activation
        x = jnp.array([-1.0, 0.5])
        for name in ("gelu", "silu", "relu2"):
            assert activation(name)(x).shape == x.shape
