"""Distribution tests on an 8-device host mesh (subprocess — the main test
process keeps 1 device)."""
import subprocess
import sys
import textwrap

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_smoke_config
from repro.models import init_lm
from repro.parallel import sharding as sh


def _run(code: str, timeout=560):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent(code))
    r = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd="/root/repo", timeout=timeout)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    return r.stdout


class TestParamSpecs:
    """Spec assignment is checkable without a multi-device runtime."""

    @pytest.mark.parametrize("arch", ["internlm2-1.8b", "deepseek-v3-671b",
                                      "mamba2-780m", "zamba2-2.7b",
                                      "whisper-small"])
    def test_specs_cover_every_leaf(self, arch):
        cfg = get_smoke_config(arch)
        params = jax.eval_shape(lambda k: init_lm(k, cfg),
                                jax.random.PRNGKey(0))
        mesh = jax.sharding.Mesh(
            __import__("numpy").array(jax.devices()[:1]).reshape(1, 1),
            ("data", "model"))
        specs = sh.param_specs(params, cfg, mesh)
        n_leaves = len(jax.tree.leaves(params))
        n_specs = len(jax.tree.leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P)))
        assert n_specs == n_leaves
        # rank compatibility: spec never longer than leaf rank
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) <= leaf.ndim, (leaf.shape, spec)

    def test_moe_experts_sharded_on_model(self):
        cfg = get_smoke_config("deepseek-v3-671b")
        params = jax.eval_shape(lambda k: init_lm(k, cfg),
                                jax.random.PRNGKey(0))
        mesh = jax.sharding.Mesh(
            __import__("numpy").array(jax.devices()[:1]).reshape(1, 1),
            ("data", "model"))
        specs = sh.param_specs(params, cfg, mesh)
        seg1 = specs["seg1"]  # MoE segment
        assert seg1["moe"]["w_up"][1] == "model"  # (L, E, h, f): E on model


class TestMultiDevice:
    def test_train_step_parity_single_vs_mesh(self):
        """Same seed, same data: loss on a (2, 4) mesh must equal the
        single-device loss (SPMD correctness end-to-end)."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import TrainConfig, ShapeConfig, MeshConfig
            from repro.configs.registry import get_smoke_config
            from repro.models import init_lm
            from repro.optim.adamw import init_opt
            from repro.train.train_step import make_train_step
            from repro.data.pipeline import make_batch
            from repro.parallel import sharding as sh

            cfg = get_smoke_config('internlm2-1.8b')
            tc = TrainConfig(total_steps=10, warmup_steps=1)
            shape = ShapeConfig('t', 32, 8, 'train')
            key = jax.random.PRNGKey(0)

            def run(mesh_cfg):
                params = init_lm(key, cfg)
                opt = init_opt(params, tc)
                if mesh_cfg:
                    mesh = sh.make_mesh(mesh_cfg)
                    sh.set_activation_context(('data',))
                    pspecs = sh.param_specs(params, cfg, mesh)
                    params = jax.device_put(params, sh.to_shardings(pspecs, mesh))
                    om = sh.param_specs(opt.m, cfg, mesh)
                    ov = sh.param_specs(opt.v, cfg, mesh)
                    opt = type(opt)(opt.step,
                                    jax.device_put(opt.m, sh.to_shardings(om, mesh)),
                                    jax.device_put(opt.v, sh.to_shardings(ov, mesh)))
                    bspec = sh.batch_specs(cfg, mesh)
                    ctx = mesh
                else:
                    sh.clear_activation_context()
                    bspec = None
                    import contextlib; ctx = contextlib.nullcontext()
                step = jax.jit(make_train_step(cfg, tc, batch_spec=bspec),
                               donate_argnums=(0, 1))
                losses = []
                with ctx:
                    for i in range(3):
                        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, i).items()}
                        params, opt, m = step(params, opt, batch)
                        losses.append(float(m['loss']))
                return losses

            l1 = run(None)
            l2 = run(MeshConfig(data=2, model=4))
            print('single:', l1)
            print('mesh:  ', l2)
            assert np.allclose(l1, l2, atol=2e-3), (l1, l2)
            print('PARITY_OK')
        """)
        assert "PARITY_OK" in out

    def test_decode_on_mesh(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.registry import get_smoke_config
            from repro.configs.base import MeshConfig
            from repro.models import init_lm, init_caches
            from repro.serving.serve_step import make_prefill_step, make_decode_step
            from repro.parallel import sharding as sh

            cfg = get_smoke_config('internlm2-1.8b')
            params = init_lm(jax.random.PRNGKey(0), cfg)
            mesh = sh.make_mesh(MeshConfig(data=2, model=4))
            sh.set_activation_context(('data',))
            pspecs = sh.param_specs(params, cfg, mesh)
            params_m = jax.device_put(params, sh.to_shardings(pspecs, mesh))
            toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
            prefill = jax.jit(make_prefill_step(cfg, 24))
            decode = jax.jit(make_decode_step(cfg))
            with mesh:
                logits, caches = prefill(params_m, {'tokens': toks})
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                logits2, caches = decode(params_m, tok, caches, jnp.asarray(16, jnp.int32))
            # single-device reference
            sh.clear_activation_context()
            l_ref, c_ref = jax.jit(make_prefill_step(cfg, 24))(params, {'tokens': toks})
            t_ref = jnp.argmax(l_ref, -1)[:, None].astype(jnp.int32)
            l2_ref, _ = jax.jit(make_decode_step(cfg))(params, t_ref, c_ref, jnp.asarray(16, jnp.int32))
            assert np.allclose(np.asarray(logits2, np.float32),
                               np.asarray(l2_ref, np.float32), atol=2e-3)
            print('DECODE_MESH_OK')
        """)
        assert "DECODE_MESH_OK" in out

    def test_elastic_checkpoint_reshape(self):
        """Save on a (2,4) mesh, restore onto (4,2) — elastic restart."""
        out = _run("""
            import tempfile, jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import MeshConfig, TrainConfig
            from repro.configs.registry import get_smoke_config
            from repro.models import init_lm
            from repro.checkpoint.ckpt import Checkpointer
            from repro.parallel import sharding as sh

            cfg = get_smoke_config('internlm2-1.8b')
            params = init_lm(jax.random.PRNGKey(0), cfg)
            mesh_a = sh.make_mesh(MeshConfig(data=2, model=4))
            pa = jax.device_put(params, sh.to_shardings(sh.param_specs(params, cfg, mesh_a), mesh_a))
            with tempfile.TemporaryDirectory() as d:
                ck = Checkpointer(d)
                ck.save(1, pa)
                mesh_b = sh.make_mesh(MeshConfig(data=4, model=2))
                restored, _, step = ck.restore(params)
                pb = jax.device_put(restored, sh.to_shardings(sh.param_specs(params, cfg, mesh_b), mesh_b))
                for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
                    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            print('ELASTIC_OK')
        """)
        assert "ELASTIC_OK" in out
