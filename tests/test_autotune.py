"""Autotuning subsystem: candidate lattice, cache round-trip, tuned kernel
dispatch, and measurement-calibrated advisor predictions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import advisor
from repro.core.gemm_model import GEMM, MeasuredProfile, estimate
from repro.core.hardware import get_hardware
from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.tuning import (TunedConfig, TuningCache, flash_candidates,
                          flash_vmem_bytes, matmul_candidates,
                          matmul_vmem_bytes, set_default_cache)
from repro.tuning.candidates import lane_granule, sublane_granule
from repro.tuning.search import autotune_flash_attention, autotune_matmul

HW = get_hardware("tpu_v5e")


@pytest.fixture(autouse=True)
def _reset_default_cache():
    yield
    set_default_cache(None)


class TestCandidateLattice:
    @pytest.mark.parametrize("m,k,n,dtype_bytes", [
        (256, 256, 256, 4), (512, 1024, 512, 2), (200, 80, 72, 2),
        (4096, 4096, 4096, 2), (64, 64, 64, 4),
    ])
    def test_matmul_candidates_aligned_and_within_vmem(self, m, k, n, dtype_bytes):
        cands = matmul_candidates(m, k, n, HW, dtype_bytes)
        assert cands, "lattice must never be empty"
        sub, lane = sublane_granule(HW, dtype_bytes), lane_granule(HW)
        for bm, bn, bk in cands:
            assert bm % sub == 0, (bm, sub)
            assert bn % lane == 0 and bk % lane == 0
            assert matmul_vmem_bytes(bm, bn, bk, dtype_bytes) <= HW.sram_bytes

    def test_matmul_default_always_present(self):
        assert (128, 128, 128) in matmul_candidates(4096, 4096, 4096, HW, 2)
        assert (128, 128, 128) in matmul_candidates(
            4096, 4096, 4096, HW, 2, max_candidates=3)

    @pytest.mark.parametrize("sq,skv,d", [(256, 256, 64), (1024, 2048, 128),
                                          (130, 130, 80)])
    def test_flash_candidates_aligned_and_within_vmem(self, sq, skv, d):
        cands = flash_candidates(sq, skv, d, HW, 2)
        assert cands
        sub, lane = sublane_granule(HW, 2), lane_granule(HW)
        for bq, bkv in cands:
            assert bq % sub == 0 and bkv % lane == 0
            assert flash_vmem_bytes(bq, bkv, d, 2) <= HW.sram_bytes

    def test_max_candidates_cap(self):
        cands = matmul_candidates(2048, 2048, 2048, HW, 2, max_candidates=5)
        assert len(cands) <= 5


class TestCacheRoundTrip:
    def _cfg(self):
        return TunedConfig(op="matmul", shape=(256, 512, 256), dtype="float32",
                           hw_name="tpu_v5e",
                           blocks={"block_m": 256, "block_n": 128, "block_k": 512},
                           time_us=123.4, baseline_us=246.8, candidates_tried=6)

    def test_save_load_identical(self, tmp_path):
        path = str(tmp_path / "cache.json")
        cache = TuningCache()
        cache.put(self._cfg())
        cache.save(path)
        loaded = TuningCache.load(path)
        assert len(loaded) == 1
        got = loaded.get("matmul", (256, 512, 256), "float32", "tpu_v5e")
        assert got == self._cfg()
        assert got.speedup_vs_default == pytest.approx(2.0)

    def test_missing_file_is_empty(self, tmp_path):
        cache = TuningCache.load(str(tmp_path / "absent.json"))
        assert len(cache) == 0
        assert cache.get("matmul", (1, 1, 1), "float32", "tpu_v5e") is None

    def test_wrong_key_misses(self, tmp_path):
        cache = TuningCache()
        cache.put(self._cfg())
        assert cache.get("matmul", (256, 512, 256), "bfloat16", "tpu_v5e") is None
        assert cache.get("matmul", (256, 512, 256), "float32", "a100") is None


class TestTunedDispatch:
    def test_autotune_then_tuned_matmul_matches_ref(self, tmp_path):
        m, k, n = 128, 128, 128
        cache = TuningCache()
        cfg = autotune_matmul(m, k, n, dtype=jnp.float32, cache=cache,
                              iters=1, warmup=1, max_candidates=3)
        assert cfg.candidates_tried >= 1
        assert cache.get("matmul", (m, k, n), "float32", "tpu_v5e") == cfg
        path = str(tmp_path / "cache.json")
        cache.save(path)
        set_default_cache(path)
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        got = matmul(a, b, tuned=True, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                                   atol=2e-4, rtol=2e-5)

    def test_tuned_matmul_uses_cached_blocks(self):
        # a non-default block config must be honored and stay correct
        cache = TuningCache()
        cache.put(TunedConfig(op="matmul", shape=(128, 256, 128),
                              dtype="float32", hw_name="tpu_v5e",
                              blocks={"block_m": 128, "block_n": 128,
                                      "block_k": 256},
                              time_us=1.0))
        set_default_cache(cache)
        a = jax.random.normal(jax.random.PRNGKey(2), (128, 256))
        b = jax.random.normal(jax.random.PRNGKey(3), (256, 128))
        got = matmul(a, b, tuned=True, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                                   atol=2e-4, rtol=2e-5)

    def test_tuned_cache_miss_keeps_defaults(self):
        set_default_cache(TuningCache())
        a = jax.random.normal(jax.random.PRNGKey(4), (64, 64))
        b = jax.random.normal(jax.random.PRNGKey(5), (64, 64))
        got = matmul(a, b, tuned=True, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                                   atol=2e-4, rtol=2e-5)

    def test_autotune_then_tuned_flash_matches_ref(self):
        b, s, heads, d = 1, 128, 2, 64
        cache = TuningCache()
        autotune_flash_attention(b, s, heads, d, cache=cache, iters=1,
                                 warmup=1, max_candidates=2)
        set_default_cache(cache)
        key = jax.random.PRNGKey(6)
        q = jax.random.normal(key, (b, s, heads, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, heads, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, heads, d))
        got = flash_attention(q, k, v, tuned=True, interpret=True)
        want = flash_attention(q, k, v, use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-4)

    def test_autotune_then_tuned_paged_decode_matches_ref(self):
        from repro.kernels.flash_attention.ops import paged_decode
        from repro.kernels.flash_attention.ref import paged_decode_ref
        from repro.tuning.search import autotune_paged_decode
        b, slots, s_max, nkv, heads, d = 3, 4, 128, 2, 4, 32
        cache = TuningCache()
        cfg = autotune_paged_decode(b, slots, s_max, nkv, heads, d,
                                    cache=cache, iters=1, warmup=1,
                                    max_candidates=2)
        assert cfg.blocks["block_kv"] % lane_granule(HW) == 0
        assert cache.get("paged_decode", (b, slots, s_max, nkv, heads, d),
                         "float32", "tpu_v5e") == cfg
        set_default_cache(cache)
        key = jax.random.PRNGKey(8)
        q = jax.random.normal(key, (b, heads, d))
        kp = jax.random.normal(jax.random.fold_in(key, 1),
                               (slots, s_max, nkv, d))
        vp = jax.random.normal(jax.random.fold_in(key, 2),
                               (slots, s_max, nkv, d))
        slot_idx = jnp.asarray([2, 0, 3], jnp.int32)
        lengths = jnp.asarray([5, 64, 128], jnp.int32)
        got = paged_decode(q, kp, vp, slot_idx, lengths, tuned=True,
                           interpret=True)
        want = paged_decode_ref(q, kp, vp, slot_idx, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-4)


class TestMeasuredProfile:
    def _cache(self, time_us=100.0):
        cache = TuningCache()
        cache.put(TunedConfig(op="matmul", shape=(512, 512, 512),
                              dtype="bfloat16", hw_name="tpu_v5e",
                              blocks={"block_m": 512, "block_n": 512,
                                      "block_k": 512},
                              time_us=time_us, baseline_us=2 * time_us))
        return cache

    def test_exact_hit_uses_measured_time(self):
        prof = MeasuredProfile.from_cache(self._cache(), "tpu_v5e")
        e = estimate(GEMM("g", 512, 512, 512), profile=prof)
        assert e.bound == "measured"
        assert e.time_s == pytest.approx(100e-6)
        # batch and count scale the per-call measurement
        e4 = estimate(GEMM("g", 512, 512, 512, batch=2, count=2), profile=prof)
        assert e4.time_s == pytest.approx(400e-6)

    def test_miss_is_calibrated_analytic(self):
        prof = MeasuredProfile.from_cache(self._cache(), "tpu_v5e")
        g = GEMM("g", 300, 300, 300)
        analytic = estimate(g).time_s
        blended = estimate(g, profile=prof).time_s
        assert blended == pytest.approx(analytic * prof.calibration)

    def test_empty_cache_gives_no_profile(self):
        assert MeasuredProfile.from_cache(TuningCache(), "tpu_v5e") is None

    def test_propose_uses_profile(self):
        cfg = ModelConfig(name="p", family="dense", num_layers=4, d_model=2560,
                          num_heads=32, num_kv_heads=32, d_ff=10240,
                          vocab_size=50257, mlp_type="gelu")
        set_default_cache(self._cache())
        props = advisor.propose(cfg, microbatch=4)
        analytic = advisor.advise(cfg, microbatch=4)
        assert props and analytic
        # profile-grounded predictions still rank and stay positive
        assert all(p.predicted_speedup > 0 for p in props)
        # absolute step times differ under the profile's calibration
        prof = MeasuredProfile.from_cache(self._cache(), "tpu_v5e")
        assert prof.calibration != pytest.approx(1.0)
        t_cal = advisor.step_time(cfg, profile=prof)
        t_ana = advisor.step_time(cfg)
        assert t_cal == pytest.approx(t_ana * prof.calibration, rel=1e-6)

    def test_propose_without_cache_matches_advise(self):
        cfg = ModelConfig(name="p", family="dense", num_layers=2, d_model=1024,
                          num_heads=8, num_kv_heads=8, d_ff=4096,
                          vocab_size=32000, mlp_type="gelu")
        set_default_cache(TuningCache())
        props = advisor.propose(cfg)
        base = advisor.advise(cfg)
        assert [p.change for p in props] == [p.change for p in base]
        for a, b in zip(props, base):
            assert a.predicted_speedup == pytest.approx(b.predicted_speedup)
