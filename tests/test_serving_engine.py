"""Serving engine: token parity vs greedy_generate, paged kernel vs oracle,
bucket policy invariants, scheduler behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.kernels.flash_attention.ops import paged_decode
from repro.kernels.flash_attention.ref import paged_decode_ref
from repro.models import init_lm
from repro.serving.engine import (Engine, Request, RequestQueue,
                                  SamplingParams, make_policy,
                                  synthetic_requests)
from repro.serving.serve_step import greedy_generate

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_smoke_config("internlm2-1.8b")
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


class TestPagedDecodeKernel:
    """Pallas paged decode vs the jnp oracle: slot gather, per-slot lengths,
    dead slots, block sizes that do and don't divide the pool depth."""

    @pytest.mark.parametrize("block_kv", [32, 64, 128, 200])
    def test_vs_ref(self, block_kv):
        slots, s_max, nkv, d, g, b = 8, 128, 2, 32, 3, 5
        q = jax.random.normal(KEY, (b, nkv * g, d)) * 0.5
        kp = jax.random.normal(jax.random.fold_in(KEY, 1),
                               (slots, s_max, nkv, d)) * 0.5
        vp = jax.random.normal(jax.random.fold_in(KEY, 2),
                               (slots, s_max, nkv, d)) * 0.5
        slot_idx = jnp.asarray([3, 0, 7, 5, 1], jnp.int32)  # permuted gather
        lengths = jnp.asarray([17, 0, 128, 1, 64], jnp.int32)  # 0 = dead
        got = paged_decode(q, kp, vp, slot_idx, lengths,
                           block_kv=block_kv, interpret=True)
        want = paged_decode_ref(q, kp, vp, slot_idx, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_dead_slot_is_zero(self):
        slots, s_max, nkv, d = 4, 64, 1, 16
        q = jax.random.normal(KEY, (2, 2, d))
        kp = jax.random.normal(KEY, (slots, s_max, nkv, d))
        vp = jax.random.normal(KEY, (slots, s_max, nkv, d))
        out = paged_decode(q, kp, vp, jnp.asarray([0, 1], jnp.int32),
                           jnp.asarray([0, 8], jnp.int32), interpret=True)
        assert np.all(np.asarray(out)[0] == 0.0)
        assert np.any(np.asarray(out)[1] != 0.0)


class TestBucketPolicy:
    def test_tile_aligned_and_bounded(self):
        cfg = get_smoke_config("internlm2-1.8b")
        pol = make_policy(cfg, max_batch=3, max_prompt=48, max_seq=96)
        # f32 smoke config on TPU lattice: sublane granule 8, lane 128
        assert pol.num_slots % 8 == 0 and pol.num_slots >= 3
        assert all(b % 8 == 0 for b in pol.prompt_buckets)
        assert pol.prompt_buckets[-1] >= 48  # lattice covers max_prompt
        assert pol.seq_max % 128 == 0 and pol.seq_max >= 96
        assert pol.num_programs == 1 + len(pol.prompt_buckets)
        # snapping: every prompt length maps to a bucket >= it
        for n in (1, 7, 8, 9, 33, 48):
            assert pol.prompt_bucket(n) >= n

    def test_oversized_prompt_rejected(self):
        cfg = get_smoke_config("internlm2-1.8b")
        pol = make_policy(cfg, max_batch=2, max_prompt=16)
        with pytest.raises(ValueError):
            pol.prompt_bucket(17)


class TestRequestQueue:
    def test_arrival_order_and_clock(self):
        reqs = [Request(rid=i, tokens=np.ones(4, np.int32), max_new_tokens=1,
                        arrival_s=t) for i, t in enumerate([0.3, 0.0, 0.1])]
        q = RequestQueue(reqs)
        assert q.pop_ready(0.0).rid == 1
        assert q.pop_ready(0.05) is None     # rid 2 arrives at 0.1
        assert q.pop_ready(0.2).rid == 2
        assert q.next_arrival_s() == 0.3
        assert q.pop_ready(1.0).rid == 0 and len(q) == 0

    def test_push_keeps_arrival_order(self):
        q = RequestQueue([Request(rid=0, tokens=np.ones(2, np.int32),
                                  max_new_tokens=1, arrival_s=10.0)])
        q.push(Request(rid=1, tokens=np.ones(2, np.int32),
                       max_new_tokens=1, arrival_s=0.0))
        assert q.next_arrival_s() == 0.0     # earlier arrival jumps ahead
        assert q.pop_ready(0.0).rid == 1


class TestEngineParity:
    """Continuous batching must not change what gets generated: engine
    outputs are token-identical to the reference greedy loop, per request,
    under mixed prompt lengths, staggered arrivals, and slot reuse."""

    def _check(self, cfg, params, reqs, done):
        assert [c.rid for c in done] == [r.rid for r in reqs]
        for r, c in zip(reqs, done):
            want = np.asarray(greedy_generate(
                params, cfg, jnp.asarray(r.tokens[None]),
                r.max_new_tokens))[0]
            assert np.array_equal(np.asarray(c.tokens), want), f"rid {r.rid}"

    def test_token_parity_with_queueing(self, smoke_lm):
        cfg, params = smoke_lm
        # 10 requests through an 8-slot pool: queueing + slot reuse
        reqs = synthetic_requests(10, pattern="burst", min_prompt=4,
                                  max_prompt=30, min_new=3, max_new=12,
                                  vocab=cfg.vocab_size, seed=3)
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=16)
        done, stats = eng.run(reqs)
        assert stats.prefills == 10 and stats.total_generated == sum(
            r.max_new_tokens for r in reqs)
        self._check(cfg, params, reqs, done)

    def test_token_parity_staggered_arrivals(self, smoke_lm):
        cfg, params = smoke_lm
        reqs = synthetic_requests(6, pattern="uniform", min_prompt=4,
                                  max_prompt=24, min_new=3, max_new=8,
                                  vocab=cfg.vocab_size, step_s=2e-3, seed=9)
        assert reqs[-1].arrival_s > 0  # actually staggered
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=8)
        done, _ = eng.run(reqs)
        self._check(cfg, params, reqs, done)

    def test_token_parity_paged_kernel(self, smoke_lm):
        cfg, params = smoke_lm
        reqs = synthetic_requests(5, pattern="burst", min_prompt=4,
                                  max_prompt=24, min_new=3, max_new=6,
                                  vocab=cfg.vocab_size, seed=11)
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=8,
                     use_paged_kernel=True)
        assert eng.cfg.attn_impl == "paged"
        done, _ = eng.run(reqs)
        self._check(cfg, params, reqs, done)

    def test_static_policy_same_tokens_more_steps(self, smoke_lm):
        cfg, params = smoke_lm
        reqs = synthetic_requests(12, pattern="burst", min_prompt=4,
                                  max_prompt=24, min_new=2, max_new=10,
                                  vocab=cfg.vocab_size, seed=13)
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=16)
        done_c, stats_c = eng.run(reqs, policy="continuous")
        done_s, stats_s = eng.run(reqs, policy="static")
        for a, b in zip(done_c, done_s):
            assert a.tokens == b.tokens
        # static drains the pool between batches: strictly more pool-wide
        # decode steps for the same tokens (the continuous-batching win)
        assert stats_s.decode_steps > stats_c.decode_steps

    def test_temperature_sampling_reproducible(self, smoke_lm):
        cfg, params = smoke_lm
        reqs = [Request(rid=i, tokens=np.arange(4 + i, dtype=np.int32) % 50,
                        max_new_tokens=5,
                        sampling=SamplingParams(temperature=0.8, seed=42 + i))
                for i in range(3)]
        eng = Engine(params, cfg, max_batch=4, max_prompt=16, max_new=8)
        d1, _ = eng.run(reqs)
        d2, _ = eng.run(reqs, policy="static")
        # per-request PRNG streams: same tokens regardless of scheduling
        for a, b in zip(d1, d2):
            assert a.tokens == b.tokens
        assert all(0 <= t < cfg.padded_vocab_size
                   for c in d1 for t in c.tokens)

    def test_slot_reuse_never_leaks_stale_kv(self, smoke_lm):
        """Regression: a released slot keeps its KV bytes (release only zeros
        the length); the next occupant must never attend the previous
        occupant's tokens.  Fill every slot deep, then re-occupy every slot
        shallow and decode past the prompt — any leak changes the tokens."""
        cfg, params = smoke_lm
        eng = Engine(params, cfg, max_batch=2, max_prompt=32, max_new=8)
        n = eng.policy.num_slots
        rng = np.random.RandomState(3)
        deep = [Request(rid=i, tokens=rng.randint(
                    0, cfg.vocab_size, size=28).astype(np.int32),
                    max_new_tokens=2) for i in range(n)]
        eng.run(deep)
        assert eng.pool.num_free == n
        assert all(l == 0 for l in eng.pool.lengths)
        shallow = [Request(rid=100 + i, tokens=rng.randint(
                       0, cfg.vocab_size, size=4).astype(np.int32),
                       max_new_tokens=6) for i in range(n)]
        done, _ = eng.run(shallow)
        self._check(cfg, params, shallow, done)

    def test_unsupported_family_rejected(self):
        cfg = get_smoke_config("mamba2-780m")
        with pytest.raises(NotImplementedError):
            Engine(params=None, cfg=cfg)

    def test_inadmissible_request_rejected_without_wedging(self, smoke_lm):
        """An inadmissible request becomes a rejected Completion — run()
        must not raise, leak a slot, or wedge subsequent service."""
        cfg, params = smoke_lm
        eng = Engine(params, cfg, max_batch=2, max_prompt=16, max_new=8)
        bad = [Request(rid=0, tokens=np.ones(8, np.int32),
                       max_new_tokens=eng.policy.seq_max)]  # depth overflow
        done, stats = eng.run(bad)
        assert [c.finish_reason for c in done] == ["rejected"]
        assert done[0].tokens == [] and done[0].ttft_s is None
        assert stats.num_rejected == 1 and stats.num_ok == 0
        assert eng.pool.num_free == eng.policy.num_slots  # no slot leaked
        ok = [Request(rid=1, tokens=np.ones(8, np.int32), max_new_tokens=3)]
        done, _ = eng.run(ok)  # engine still serves after the rejection
        assert len(done) == 1 and len(done[0].tokens) == 3

    def test_calibrate_with_bucket_at_pool_edge(self, smoke_lm):
        cfg, params = smoke_lm
        # top bucket (128) lands exactly on lane alignment: warm prompts at
        # bucket width must still fit the pool's generation headroom
        eng = Engine(params, cfg, max_batch=2, max_prompt=124, max_new=4)
        assert eng.policy.seq_max >= eng.policy.prompt_buckets[-1] + 4
        assert eng.calibrate_step_s() > 0.0
