"""Checkpoint save/restore/rotate + data-pipeline sharding invariants."""
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import make_batch
from repro.models import init_lm
from repro.optim.adamw import init_opt

KEY = jax.random.PRNGKey(0)


class TestCheckpointer:
    def _setup(self, d):
        cfg = get_smoke_config("internlm2-1.8b")
        params = init_lm(KEY, cfg)
        opt = init_opt(params, TrainConfig())
        return cfg, params, opt, Checkpointer(d, keep=2)

    def test_roundtrip_exact(self):
        with tempfile.TemporaryDirectory() as d:
            cfg, params, opt, ck = self._setup(d)
            ck.save(3, params, opt)
            p2, o2, step = ck.restore(params, opt)
            assert step == 3
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
                np.testing.assert_array_equal(np.asarray(a), b)
            for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
                np.testing.assert_array_equal(np.asarray(a), b)

    def test_rotation_keeps_newest(self):
        with tempfile.TemporaryDirectory() as d:
            cfg, params, opt, ck = self._setup(d)
            for s in (1, 2, 3, 4):
                ck.save(s, params)
            assert ck.all_steps() == [3, 4]

    def test_async_save(self):
        with tempfile.TemporaryDirectory() as d:
            cfg, params, opt, ck = self._setup(d)
            ck.save(7, params, opt, blocking=False)
            ck.wait()
            assert ck.latest_step() == 7

    def test_restore_missing_raises(self):
        with tempfile.TemporaryDirectory() as d:
            cfg, params, opt, ck = self._setup(d)
            with pytest.raises(FileNotFoundError):
                ck.restore(params)

    def test_atomicity_no_partial_dirs(self):
        with tempfile.TemporaryDirectory() as d:
            cfg, params, opt, ck = self._setup(d)
            ck.save(1, params)
            assert not [x for x in os.listdir(d) if x.startswith(".tmp")]


class TestDataPipeline:
    def test_host_shards_are_disjoint_rows(self):
        cfg = get_smoke_config("internlm2-1.8b")
        shape = ShapeConfig("t", 32, 8, "train")
        full = make_batch(cfg, shape, 5, process_index=0, process_count=1)
        h0 = make_batch(cfg, shape, 5, process_index=0, process_count=2)
        h1 = make_batch(cfg, shape, 5, process_index=1, process_count=2)
        assert h0["tokens"].shape[0] == 4 and h1["tokens"].shape[0] == 4
        np.testing.assert_array_equal(
            np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])

    def test_replacement_host_reproduces_shard(self):
        """Straggler/elastic story: any host can recompute any shard."""
        cfg = get_smoke_config("internlm2-1.8b")
        shape = ShapeConfig("t", 16, 8, "train")
        a = make_batch(cfg, shape, 9, process_index=3, process_count=4)
        b = make_batch(cfg, shape, 9, process_index=3, process_count=4)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_vlm_and_audio_extras(self):
        vlm = get_smoke_config("internvl2-76b")
        shape = ShapeConfig("t", 32, 2, "train")
        b = make_batch(vlm, shape, 0)
        assert b["patch_embeds"].shape == (2, vlm.num_patches, vlm.d_model)
        assert b["tokens"].shape[1] == 32 - vlm.num_patches
        wh = get_smoke_config("whisper-small")
        b = make_batch(wh, shape, 0)
        assert b["encoder_frames"].shape == (2, wh.encoder_seq, wh.d_model)
