"""Prefix caching + copy-on-write block sharing: serving-correctness suite.

Locks down the block-table KV pool (kv_pool.BlockPool / PagedPool) and the
prefix-cache engine path end to end:

  * block-table decode kernel vs the jnp oracle (permuted tables, dead rows,
    block_kv sweep, non-divisor clamp);
  * the BlockPool host state machine — prefix-hit sharing, full-hit COW,
    LRU eviction, exhaustion rollback — plus a seeded random driver with
    shadow block contents that asserts COW never lets one sequence observe
    another's writes (runs even without hypothesis; the hypothesis twin
    lives in test_property.py);
  * the engine contract: greedy outputs with prefix_cache=True are
    byte-identical to the non-cached engine, on an 80% shared / 20% cold
    workload, across divergence after a shared prefix, and under eviction
    pressure.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.kernels.flash_attention.ops import paged_decode_blocktable
from repro.kernels.flash_attention.ref import (gather_block_kv,
                                               paged_decode_blocktable_ref)
from repro.models import init_lm
from repro.serving.engine import (BlockPool, BucketPolicy, Engine, PagedPool,
                                  PoolExhausted, Request, synthetic_requests)
from repro.serving.serve_step import greedy_generate

KEY = jax.random.PRNGKey(11)


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_smoke_config("internlm2-1.8b")
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


class TestBlockTableKernel:
    """Pallas block-table decode vs the jnp oracle: physical indirection,
    shared blocks across rows, dead rows, kv blocking that does and doesn't
    divide the physical block size."""

    def _inputs(self, b=5, nb=12, bs=16, nkv=2, g=3, d=32):
        q = jax.random.normal(KEY, (b, nkv * g, d)) * 0.5
        kp = jax.random.normal(jax.random.fold_in(KEY, 1),
                               (nb, bs, nkv, d)) * 0.5
        vp = jax.random.normal(jax.random.fold_in(KEY, 2),
                               (nb, bs, nkv, d)) * 0.5
        # permuted, partially *shared* tables (rows 0 and 2 share block 7)
        tables = jnp.asarray([[7, 3, 1, 0],
                              [2, 8, 9, 4],
                              [7, 5, 0, 0],
                              [10, 0, 0, 0],
                              [11, 6, 3, 2]], jnp.int32)
        lengths = jnp.asarray([50, 64, 17, 0, 33], jnp.int32)  # 0 = dead row
        return q, kp, vp, tables, lengths

    @pytest.mark.parametrize("block_kv", [None, 8, 16, 12])
    def test_vs_ref(self, block_kv):
        q, kp, vp, tables, lengths = self._inputs()
        # block_kv=12 doesn't divide bs=16: the wrapper clamps to gcd
        got = paged_decode_blocktable(q, kp, vp, tables, lengths,
                                      block_kv=block_kv, interpret=True)
        want = paged_decode_blocktable_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_dead_row_is_zero(self):
        q, kp, vp, tables, lengths = self._inputs()
        out = np.asarray(paged_decode_blocktable(q, kp, vp, tables, lengths,
                                                 interpret=True))
        assert np.all(out[3] == 0.0)
        assert np.any(out[0] != 0.0)

    def test_ref_matches_contiguous_gather(self):
        """The oracle itself: gathering blocks into a contiguous view and
        attending there equals attending through the table."""
        q, kp, vp, tables, lengths = self._inputs()
        kc = gather_block_kv(kp, tables)
        vc = gather_block_kv(vp, tables)
        assert kc.shape == (5, 4 * 16, 2, 32)
        got = paged_decode_blocktable_ref(q, kp, vp, tables, lengths)
        # re-pose the gathered views as a pool of 1-token blocks with per-row
        # identity tables: the indirection must be invisible
        ident = (jnp.arange(64)[None] +
                 jnp.arange(5)[:, None] * 64).astype(jnp.int32)
        want = paged_decode_blocktable_ref(
            q, kc.reshape(5 * 64, 1, 2, 32), vc.reshape(5 * 64, 1, 2, 32),
            ident, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)

    def test_jnp_fallback_path(self):
        q, kp, vp, tables, lengths = self._inputs()
        got = paged_decode_blocktable(q, kp, vp, tables, lengths,
                                      use_pallas=False)
        want = paged_decode_blocktable_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6, rtol=1e-6)


class TestBlockPoolStateMachine:
    """Deterministic transitions of the pure-host block pool."""

    def test_prefix_hit_shares_full_blocks(self):
        pool = BlockPool(16, 4)
        toks = list(range(10))                     # 2 full blocks + tail
        a, cows = pool.alloc_sequence(toks)
        assert not cows and a.num_cached == 0 and len(a.table) == 3
        pool.commit(a, toks)
        b, cows = pool.alloc_sequence(toks[:8] + [99, 98])
        assert not cows
        assert b.num_cached == 8                   # both full blocks shared
        assert b.table[:2] == a.table[:2] and b.table[2] != a.table[2]
        assert pool.ref[a.table[0]] == 2 and pool.ref[a.table[1]] == 2
        pool.check()

    def test_partial_chain_match_stops_at_divergence(self):
        pool = BlockPool(16, 4)
        a, _ = pool.alloc_sequence(list(range(8)))
        pool.commit(a, list(range(8)))
        # same first block, different second: chained hash stops after one
        b, _ = pool.alloc_sequence([0, 1, 2, 3, 9, 9, 9, 9])
        assert b.num_cached == 4 and b.table[0] == a.table[0]
        # same *contents* in block 1 but different parent chain: no hit
        c, _ = pool.alloc_sequence([5, 5, 5, 5] + list(range(4, 8)))
        assert c.num_cached == 0
        pool.check()

    def test_full_hit_cow_forks_tail(self):
        pool = BlockPool(16, 4)
        toks = list(range(8))
        a, _ = pool.alloc_sequence(toks)
        pool.commit(a, toks)
        b, cows = pool.alloc_sequence(toks)        # identical prompt
        # the final token is recomputed into a private fork: the shared
        # original is never written
        assert b.num_cached == 7
        assert len(cows) == 1
        assert cows[0].src == a.table[1] and cows[0].dst == b.table[1]
        assert b.table[0] == a.table[0] and b.table[1] != a.table[1]
        assert pool.ref[b.table[1]] == 1 and pool.ref[a.table[1]] == 1
        pool.check()

    def test_release_keeps_cache_warm_then_lru_evicts(self):
        pool = BlockPool(4, 2)
        a, _ = pool.alloc_sequence([1, 2, 3, 4])
        pool.commit(a, [1, 2, 3, 4])
        pool.release(a)
        assert pool.num_cached_blocks == 2 and pool.num_free_blocks == 2
        b, _ = pool.alloc_sequence([1, 2, 3, 4, 5])   # warm: both blocks hit
        assert b.num_cached == 4
        pool.release(b)
        # 8 distinct tokens -> 4 fresh blocks: free list drains, then the
        # LRU cached-free blocks are evicted
        c, _ = pool.alloc_sequence([7, 8, 9, 10, 11, 12, 13, 14])
        assert pool.evictions >= 2
        pool.check()
        pool.release(c)
        d, _ = pool.alloc_sequence([1, 2, 3, 4, 5])   # cache was evicted
        assert d.num_cached == 0
        pool.check()

    def test_exhaustion_rolls_back_cleanly(self):
        pool = BlockPool(2, 2)
        a, _ = pool.alloc_sequence([1, 2, 3, 4])
        pool.commit(a, [1, 2, 3, 4])
        ref_before = list(pool.ref)
        with pytest.raises(PoolExhausted):
            # hits block [1,2] (ref++), then needs 2 fresh blocks: none left
            pool.alloc_sequence([1, 2, 5, 6, 7, 8])
        assert pool.ref == ref_before              # hit refs rolled back
        pool.check()
        # the pool still works after the failed admission
        pool.release(a)
        b, _ = pool.alloc_sequence([1, 2, 9, 10])
        assert b.num_cached == 2
        pool.check()

    def test_prepare_append_boundary_cow_and_unregister(self):
        pool = BlockPool(8, 2)
        a, _ = pool.alloc_sequence([1, 2, 3])      # blocks: [full, tail]
        pool.commit(a, [1, 2, 3])
        # decode-time divergence: fork shares every block; the forked tail
        # must be COW'd before either writer touches it
        b = pool.fork(a)
        assert pool.ref[a.table[1]] == 2
        cow = pool.prepare_append(b)
        assert cow is not None and cow.src == a.table[1]
        assert b.table[1] != a.table[1] and pool.ref[a.table[1]] == 1
        pool.advance(b)
        # a's tail is private again: appending needs no copy
        assert pool.prepare_append(a) is None
        pool.advance(a)
        # boundary: position 4 opens a fresh private block
        assert a.length == 4 and len(a.table) == 2
        assert pool.prepare_append(a) is None and len(a.table) == 3
        pool.check()

    def test_prepare_append_unregisters_written_tail(self):
        pool = BlockPool(8, 2)
        a, _ = pool.alloc_sequence([1, 2, 3])
        # speculative commit claims the tail block through token 4: a write
        # at position 3 would corrupt that cache entry, so prepare_append
        # must un-register it first
        pool.commit(a, [1, 2, 3, 4])
        assert pool.prepare_append(a) is None
        pool.advance(a)
        pool.check()
        b, _ = pool.alloc_sequence([1, 2, 3, 4])
        assert b.num_cached == 2                   # only block 0 still hits
        pool.check()

    @pytest.mark.parametrize("seed", range(6))
    def test_random_driver_shadow_contents(self, seed):
        """Seeded alloc/fork/append/release storm with shadow block contents:

        * every write lands in a block with refcount exactly 1 (COW);
        * prefix-hit blocks hold exactly the prompt's tokens;
        * at every step, every live sequence reads back its own tokens —
          no sequence ever observes another's writes;
        * pool.check() invariants hold after every transition, including
          after PoolExhausted rollbacks.
        """
        drive_block_pool(seed, steps=120, num_blocks=24, block_size=4)


def drive_block_pool(seed, *, steps, num_blocks, block_size):
    """The random state-machine driver (shared shape with the hypothesis
    interpreter in test_property.py)."""
    rng = np.random.RandomState(seed)
    bs = block_size
    pool = BlockPool(num_blocks, bs)
    mem = {b: [None] * bs for b in range(num_blocks)}   # shadow KV contents
    live = []                                           # (seq, tokens)
    vocab = 40
    prefixes = [rng.randint(0, vocab, size=bs * k).tolist() for k in (1, 2, 3)]

    def write(seq, pos, tok):
        blk = seq.table[pos // bs]
        assert pool.ref[blk] == 1, \
            f"seed {seed}: write to shared block {blk} (ref {pool.ref[blk]})"
        mem[blk][pos % bs] = tok

    def apply_cow(c):
        mem[c.dst] = list(mem[c.src])

    for step in range(steps):
        op = rng.choice(4, p=[0.4, 0.3, 0.1, 0.2])
        if op == 0:                                     # admit a prompt
            base = prefixes[rng.randint(3)] if rng.rand() < 0.7 else []
            tail = rng.randint(0, vocab,
                               size=rng.randint(1, 3 * bs)).tolist()
            tokens = base + tail
            try:
                seq, cows = pool.alloc_sequence(tokens)
            except PoolExhausted:
                pool.check()                            # rollback left it sane
                if live:
                    s, _ = live.pop(rng.randint(len(live)))
                    pool.release(s)
                continue
            for c in cows:
                apply_cow(c)
            p = seq.num_cached
            for j in range(p // bs):                    # hit content is right
                assert mem[seq.table[j]] == tokens[j * bs:(j + 1) * bs]
            for pos in range(p, len(tokens)):           # suffix prefill
                write(seq, pos, tokens[pos])
            pool.commit(seq, tokens)
            live.append((seq, list(tokens)))
        elif op == 1 and live:                          # one decode append
            seq, tokens = live[rng.randint(len(live))]
            try:
                c = pool.prepare_append(seq)
            except PoolExhausted:
                pool.check()
                continue
            if c is not None:
                apply_cow(c)
            tok = int(rng.randint(0, vocab))
            write(seq, seq.length, tok)
            pool.advance(seq)
            tokens.append(tok)
        elif op == 2 and live:                          # fork (divergence)
            seq, tokens = live[rng.randint(len(live))]
            live.append((pool.fork(seq), list(tokens)))
        elif op == 3 and live:                          # release
            s, _ = live.pop(rng.randint(len(live)))
            pool.release(s)
        pool.check()
        for seq, tokens in live:                        # isolation: each seq
            for pos in range(seq.length):               # reads its own tokens
                got = mem[seq.table[pos // bs]][pos % bs]
                assert got == tokens[pos], (seed, step, seq.sid, pos)
    return pool


class TestPagedPoolDevice:
    """PagedPool: the device mirror of the host state machine."""

    @pytest.fixture(scope="class")
    def tiny(self):
        cfg = get_smoke_config("internlm2-1.8b")
        return cfg

    def test_gather_scatter_roundtrip_respects_shared_blocks(self, tiny):
        pool = PagedPool(tiny, num_rows=2, seq_max=16, dtype=jnp.float32,
                         block_size=4)
        toks = list(range(8))
        seq = pool.alloc_sequence(0, toks)
        # cache leaves carry the scanned layer dim first: blocks are axis 1
        k0 = pool.caches[0]["k"]
        marked = k0.at[:, seq.table[0]].set(7.0).at[:, seq.table[1]].set(3.0)
        pool.caches[0]["k"] = marked
        pool.commit(0, toks)
        # second row shares block 0; scatter from its first private block
        # must leave the shared block untouched
        seq2 = pool.alloc_sequence(1, toks[:4] + [9, 9, 9, 9])
        assert seq2.num_cached == 4 and seq2.table[0] == seq.table[0]
        contig = pool.gather(1)
        np.testing.assert_allclose(
            np.asarray(contig[0]["k"][0, 0, :4]), 7.0)  # hit KV visible
        zeroed = jax.tree.map(jnp.zeros_like, contig)
        pool.scatter(1, zeroed, seq2.num_cached // pool.block_size)
        k = np.asarray(pool.caches[0]["k"])
        assert np.all(k[:, seq.table[0]] == 7.0)        # shared: untouched
        assert np.all(k[:, seq2.table[1]] == 0.0)       # private: rewritten
        assert np.all(k[:, seq.table[1]] == 3.0)        # other row: untouched
        pool.blocks.check()

    def test_row_release_realloc_never_leaks(self, tiny):
        pool = PagedPool(tiny, num_rows=2, seq_max=16, dtype=jnp.float32,
                         block_size=4)
        row = pool.alloc()
        pool.alloc_sequence(row, list(range(12)))
        pool.release(row)
        assert pool.lengths == [0, 0] and pool.num_free == 2
        row = pool.alloc()
        seq = pool.alloc_sequence(row, [50, 51])
        # fresh table, fresh length: nothing of the previous occupant remains
        assert seq.num_cached == 0 and pool.lengths[row] == 2
        tab = pool.tables()
        assert tab.shape == (2, 4)
        assert np.all(tab[1 - row] == pool.garbage)     # dead row: garbage
        assert np.all(tab[row, 1:] == pool.garbage)     # unallocated tail
        pool.blocks.check()


def _run_pair(cfg, params, reqs, *, policy=None, block_size=8, **kw):
    """Run the same workload through the slot engine and the prefix-cache
    engine; assert byte-identical greedy tokens; return the paged stats."""
    base = Engine(params, cfg, policy=policy, **kw)
    done_b, _ = base.run(reqs)
    eng = Engine(params, cfg, policy=policy, prefix_cache=True,
                 block_size=block_size, **kw)
    done_p, stats = eng.run(reqs)
    assert [c.rid for c in done_p] == [c.rid for c in done_b]
    for a, b in zip(done_b, done_p):
        assert a.tokens == b.tokens, f"rid {a.rid}: cache changed tokens"
    eng.pool.blocks.check()
    assert eng.pool.num_free == eng.policy.num_slots    # all rows released
    return eng, stats, done_p


class TestPrefixCacheEngine:
    """The contract: prefix caching is invisible in the tokens."""

    def test_token_identity_shared_prefix_workload(self, smoke_lm):
        cfg, params = smoke_lm
        # 80% of requests share a 16-token system prefix (2 full blocks at
        # block_size=8); 20% are cold
        reqs = synthetic_requests(10, pattern="burst", min_prompt=20,
                                  max_prompt=30, min_new=3, max_new=8,
                                  vocab=cfg.vocab_size, prefix_share=0.8,
                                  shared_prefix_len=16, seed=5)
        eng, stats, done1 = _run_pair(cfg, params, reqs, max_batch=4,
                                      max_prompt=32, max_new=8)
        assert stats.cache_hit_requests >= 2
        assert stats.cached_tokens >= 16 * stats.cache_hit_requests
        assert 0.0 < stats.cache_hit_rate < 1.0
        assert stats.prompt_tokens == sum(r.prompt_len for r in reqs)
        # rerun on the warm cache: every previously-seen prompt now hits,
        # and the tokens still don't change
        done2, stats2 = eng.run(reqs)
        for a, b in zip(done1, done2):
            assert a.tokens == b.tokens, f"rid {a.rid}: warm rerun diverged"
        assert stats2.cache_hit_rate > stats.cache_hit_rate
        assert stats2.cache_hit_requests == len(reqs)
        eng.pool.blocks.check()

    def test_divergence_after_shared_prefix(self, smoke_lm):
        cfg, params = smoke_lm
        rng = np.random.RandomState(0)
        P = rng.randint(0, cfg.vocab_size, size=16).astype(np.int32)
        reqs = [
            Request(rid=0, tokens=np.concatenate(
                [P, np.asarray([3, 5, 7], np.int32)]), max_new_tokens=6),
            Request(rid=1, tokens=np.concatenate(
                [P, np.asarray([11, 13], np.int32)]), max_new_tokens=6),
            Request(rid=2, tokens=P.copy(), max_new_tokens=6),  # full hit
        ]
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=8,
                     prefix_cache=True, block_size=8)
        done, _ = eng.run(reqs)
        for r, c in zip(reqs, done):
            want = np.asarray(greedy_generate(
                params, cfg, jnp.asarray(r.tokens[None]),
                r.max_new_tokens))[0]
            assert np.array_equal(np.asarray(c.tokens), want), f"rid {r.rid}"
        by = {c.rid: c for c in done}
        assert by[0].cached_tokens == 0             # cold, registers P
        assert by[1].cached_tokens == 16            # shares both P blocks
        assert by[2].cached_tokens == 15            # full hit: COW clamps n-1
        eng.pool.blocks.check()

    def test_token_identity_under_eviction_pressure(self, smoke_lm):
        cfg, params = smoke_lm
        # shrink the pool: 8 rows x 32 deep / block 8 = 32 physical blocks,
        # then stream 16 distinct prompts so released cache blocks must be
        # evicted to admit newcomers
        pol = BucketPolicy(num_slots=8, prompt_buckets=(8, 16, 24),
                           seq_max=32)
        reqs = synthetic_requests(16, pattern="burst", min_prompt=17,
                                  max_prompt=24, min_new=2, max_new=5,
                                  vocab=cfg.vocab_size, seed=21)
        eng, stats, _ = _run_pair(cfg, params, reqs, policy=pol,
                                  max_batch=8, max_prompt=24, max_new=8)
        assert eng.pool.blocks.num_blocks == 32
        assert eng.pool.blocks.evictions > 0        # pressure actually hit
        assert stats.num_requests == 16

    def test_prefix_cache_with_paged_kernel(self, smoke_lm):
        cfg, params = smoke_lm
        reqs = synthetic_requests(5, pattern="burst", min_prompt=18,
                                  max_prompt=28, min_new=3, max_new=6,
                                  vocab=cfg.vocab_size, prefix_share=0.8,
                                  shared_prefix_len=16, seed=17)
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=8,
                     prefix_cache=True, block_size=8, use_paged_kernel=True)
        assert eng.cfg.attn_impl == "paged"
        done, stats = eng.run(reqs)
        for r, c in zip(reqs, done):
            want = np.asarray(greedy_generate(
                params, cfg, jnp.asarray(r.tokens[None]),
                r.max_new_tokens))[0]
            assert np.array_equal(np.asarray(c.tokens), want), f"rid {r.rid}"
        assert stats.cache_hit_requests >= 1
        eng.pool.blocks.check()
