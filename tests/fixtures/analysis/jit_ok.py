"""Fixture: the hygienic twin of jit_bad.py — zero findings.

Instrumentation lives in the un-jitted wrapper; in-trace labels use
jax.named_scope; RNG is threaded jax.random keys; the trace-time constant
dict is never mutated.
"""
import time

import jax
import jax.numpy as jnp

from repro import obs

_SCALES = {"default": 1.0}  # read-only: a legitimate trace-time constant


@jax.jit
def _step(x, key):
    with jax.named_scope("step"):
        noise = jax.random.normal(key, x.shape)
        return x * _SCALES["default"] + noise


def step(x, key):
    t0 = time.perf_counter()
    with obs.span("step"):
        y = _step(x, key)
    obs.histogram("step_time_s", time.perf_counter() - t0)
    return y
