"""Fixture: every JIT rule fires on this file."""
import functools
import time

import jax
import jax.numpy as jnp

from repro import obs

_CACHE = {}


@jax.jit
def traced_obs(x):
    # JIT201: obs call inside traced code
    with obs.span("inner"):
        return x * 2


@functools.partial(jax.jit, static_argnames=("n",))
def traced_clock(x, n):
    # JIT202: host clock freezes to a trace-time constant
    return x + time.time()


def make_step():
    def step(x, scales=[1.0]):  # JIT203: mutable default on a traced def
        _CACHE["last"] = 1  # writes keep _CACHE "mutated" for JIT204
        return x * scales[0]

    return jax.jit(step)


@jax.jit
def traced_capture(x):
    # JIT204: reads a module-level mutable that the module mutates
    return x + len(_CACHE)


@jax.jit
def traced_global(x):
    # JIT204: global declaration inside traced code
    global _CACHE
    _CACHE = {}
    return x
