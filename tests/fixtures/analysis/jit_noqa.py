"""Fixture: jit_bad.py's hazards, pragma-suppressed line by line."""
import time

import jax

from repro import obs


@jax.jit
def traced_obs(x):
    with obs.span("inner"):  # repro: noqa[JIT201]
        return x * 2


@jax.jit
def traced_clock(x):
    return x + time.time()  # repro: noqa[JIT202]
