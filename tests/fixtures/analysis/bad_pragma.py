"""Fixture: a typo'd suppression raises ANA002 instead of silently
suppressing nothing."""

X = 1  # repro: noqa[KRN999]
