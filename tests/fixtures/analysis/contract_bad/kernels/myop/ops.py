"""Fixture: tuned-op lookups that break the cross-module contract."""
from ...tuning.cache import lookup


def run_myop(x, w, hw):
    m, k = x.shape
    _, n = w.shape
    # KRN105: 3-element shape key; the autotuner persists a 2-element one
    cfg = lookup("myop", (m, k, n), x.dtype, hw)
    # KRN104: no autotune entry point ever writes ghost_op
    ghost = lookup("ghost_op", (m, n), x.dtype, hw)
    return cfg, ghost
