"""Fixture: autotune entry points that break the tuned-op contract."""
from .cache import TunedConfig
from .candidates import myop_candidates, orphan_candidates


def autotune_myop(m, k, n, hw):
    best = None
    for bm, bn in myop_candidates(m, k, n):
        best = (bm, bn)
    # KRN105 counterpart: persists a 2-element shape key
    return TunedConfig(op="myop", shape=(m, k), block=best)


def autotune_dead(m, n, hw):
    best = None
    for blk in orphan_candidates(m, n):
        best = blk
    # KRN107: nothing ever looks dead_op up
    return TunedConfig(op="dead_op", shape=(m, n), block=best)


def autotune_nolattice(m, n, hw):
    # KRN106: persists without sweeping a *_candidates lattice
    return TunedConfig(op="nolattice_op", shape=(m, n), block=(128, 128))
