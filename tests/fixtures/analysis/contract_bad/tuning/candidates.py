"""Fixture: one lattice with a VMEM feasibility bound, one without."""


def myop_vmem_bytes(bm, bn, dtype_bytes=2):
    return 2 * bm * bn * dtype_bytes


def myop_candidates(m, k, n, vmem_budget=16 * 2 ** 20):
    out = []
    for bm in (128, 256):
        for bn in (128, 256):
            if myop_vmem_bytes(bm, bn) <= vmem_budget:
                out.append((bm, bn))
    return out


def orphan_candidates(m, n):
    # KRN106 (via autotune_dead): no *_vmem_bytes feasibility model
    return [(128, 128), (256, 256)]
