"""Fixture: every per-file KRN rule fires on this file."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    # KRN102: dot without preferred_element_type
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...])
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bad_matmul(x, w):
    m, k = x.shape
    _, n = w.shape
    grid = (m // 128, n // 128, k // 128)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((128, 128), lambda i, j, s: (i, s)),
            # KRN103: 2-arg index map against a rank-3 grid
            pl.BlockSpec((128, 128), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((128, 128), lambda i, j, s: (i, j)),
        # KRN101: bf16 accumulator scratch
        scratch_shapes=[pltpu.VMEM((128, 128), jnp.bfloat16)],
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
    )(x, w)
