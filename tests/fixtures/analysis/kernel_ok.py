"""Fixture: the aligned twin of kernel_bad.py — zero findings."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def good_matmul(x, w):
    m, k = x.shape
    _, n = w.shape
    grid = (m // 128, n // 128, k // 128)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((128, 128), lambda i, j, s: (i, s)),
            pl.BlockSpec((128, 128), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((128, 128), lambda i, j, s: (i, j)),
        scratch_shapes=[pltpu.VMEM((128, 128), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
    )(x, w)
