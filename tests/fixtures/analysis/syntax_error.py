"""Fixture: ANA001 — does not parse."""
def broken(:
    pass
