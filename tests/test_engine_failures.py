"""Failure semantics, overload shedding, KV preemption, and the chaos
harness: Engine.run must never raise for a per-request problem, and every
degradation path must keep unaffected requests token-identical.

Scheduler-level tests drive admission control with a stub pool (pure host
logic, no model).  Engine-level tests use the module smoke model; the chaos
soak at the bottom is the acceptance check: >= 4 fault types over >= 64
requests, invariants asserted after every step, unaffected outputs diffed
token-for-token against a fault-free run.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import init_lm
from repro.serving.engine import (Engine, FaultEvent, FaultPlan, Request,
                                  RequestQueue, Scheduler, ShedPolicy,
                                  chaos_soak, synthetic_requests)


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_smoke_config("internlm2-1.8b")
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _req(rid, plen=8, new=4, **kw):
    return Request(rid=rid, tokens=np.ones(plen, np.int32),
                   max_new_tokens=new, **kw)


class _StubPool:
    """Just enough pool for the Scheduler: rows + a block budget where a
    prompt needs ceil(plen/8) of `blocks`."""

    def __init__(self, rows=4, blocks=8):
        self.rows, self.blocks = rows, blocks
        self.num_active = 0

    @property
    def num_free(self):
        return self.rows

    def can_admit(self, plen):
        return self.rows > 0 and -(-plen // 8) <= self.blocks

    def alloc(self):
        self.rows -= 1
        return self.rows


class TestLookaheadAdmission:
    def test_small_request_admitted_behind_blocking_head(self):
        # head wants 13 blocks (> 8 available): without lookahead it would
        # head-of-line-block the admissible small requests behind it
        q = RequestQueue([_req(0, plen=100), _req(1, plen=8), _req(2, plen=8)])
        sched = Scheduler(q, _StubPool(rows=2, blocks=8))
        admits, sheds = sched.admissions(0.0)
        assert [r.rid for r, _ in admits] == [1, 2] and not sheds
        assert q.peek(0).rid == 0       # blocked head stays queued, in order

    def test_fifo_preserved_within_window(self):
        # all admissible: strict FIFO, the window must not reorder
        q = RequestQueue([_req(i) for i in range(4)])
        sched = Scheduler(q, _StubPool(rows=4, blocks=99))
        admits, _ = sched.admissions(0.0)
        assert [r.rid for r, _ in admits] == [0, 1, 2, 3]

    def test_window_bounds_the_scan(self):
        # 4 blocking requests fill the window: the admissible 5th is beyond
        # the lookahead and must NOT be admitted (bounded unfairness)
        q = RequestQueue([_req(i, plen=100) for i in range(4)] + [_req(9)])
        sched = Scheduler(q, _StubPool(rows=2, blocks=8),
                          shed=ShedPolicy(lookahead=4))
        admits, _ = sched.admissions(0.0)
        assert admits == [] and len(q) == 5
        wider = Scheduler(q, _StubPool(rows=2, blocks=8),
                          shed=ShedPolicy(lookahead=5))
        admits, _ = wider.admissions(0.0)
        assert [r.rid for r, _ in admits] == [9]


class TestShedVerdicts:
    def test_max_queue_wait_shed(self):
        q = RequestQueue([_req(0, max_queue_wait_s=0.1),
                          _req(1, max_queue_wait_s=10.0)])
        sched = Scheduler(q, _StubPool(rows=0))   # nothing admissible
        _, sheds = sched.admissions(5.0)          # both waited 5s
        assert [(s.req.rid, s.reason) for s in sheds] == [(0, "shed")]

    def test_unreachable_deadline_is_timeout(self):
        q = RequestQueue([_req(0, deadline_s=1.0)])
        sched = Scheduler(q, _StubPool(rows=0),
                          shed=ShedPolicy(step_s=0.5))
        _, sheds = sched.admissions(0.9)          # 0.9 + 0.5 > 1.0
        assert [(s.req.rid, s.reason) for s in sheds] == [(0, "timeout")]
        q2 = RequestQueue([_req(0, deadline_s=1.0)])
        _, sheds2 = Scheduler(q2, _StubPool(rows=0),
                              shed=ShedPolicy(step_s=0.5)).admissions(0.2)
        assert sheds2 == []                       # still reachable: kept

    def test_ttft_slo_and_depth_shed(self):
        q = RequestQueue([_req(i) for i in range(6)])
        sched = Scheduler(q, _StubPool(rows=0),
                          shed=ShedPolicy(max_queue_depth=2, ttft_slo_s=10.0,
                                          step_s=0.0))
        _, sheds = sched.admissions(1.0)
        # depth 6 > 2: newest-first shedding keeps the two most senior
        assert sorted(s.req.rid for s in sheds) == [2, 3, 4, 5]
        assert all(s.reason == "shed" for s in sheds)
        assert [q.peek(i).rid for i in range(len(q))] == [0, 1]
        _, sheds = sched.admissions(20.0)         # now every wait > SLO
        assert sorted(s.req.rid for s in sheds) == [0, 1]


class TestRejectionIsolation:
    def test_oversized_prompt_in_healthy_batch(self, smoke_lm):
        """Regression: one bad request used to raise out of run() and abort
        the whole batch.  Now it is a rejected Completion and the healthy
        requests' tokens are exactly what they are without it."""
        cfg, params = smoke_lm
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=8)
        healthy = synthetic_requests(6, pattern="burst", min_prompt=4,
                                     max_prompt=24, min_new=3, max_new=6,
                                     vocab=cfg.vocab_size, seed=3)
        want = {c.rid: c.tokens for c in eng.run(healthy)[0]}
        bad = [Request(rid=90, tokens=np.ones(999, np.int32),
                       max_new_tokens=4),                      # oversized
               Request(rid=91, tokens=np.full(6, -3, np.int32),
                       max_new_tokens=4),                      # garbage ids
               Request(rid=92, tokens=np.ones(8, np.int32),
                       max_new_tokens=0)]                      # empty budget
        mixed = list(healthy)
        mixed[2:2] = bad                                       # mid-batch
        done, stats = eng.run(mixed)
        by_rid = {c.rid: c for c in done}
        for b in bad:
            c = by_rid[b.rid]
            assert c.finish_reason == "rejected" and c.tokens == []
            assert c.ttft_s is None and c.detail
        for r in healthy:
            assert by_rid[r.rid].tokens == want[r.rid], f"rid {r.rid}"
        assert stats.num_rejected == 3 and stats.num_ok == len(healthy)
        assert stats.goodput == 1.0            # rejects don't count against
        assert eng.pool.num_free == eng.policy.num_slots


class TestDeadlineTimeout:
    def test_mid_decode_timeout_returns_partial(self, smoke_lm):
        cfg, params = smoke_lm
        eng = Engine(params, cfg, max_batch=4, max_prompt=16, max_new=32)
        full = [_req(0, plen=8, new=24)]
        want = eng.run(full)[0][0].tokens
        # deadline after the first token but far before 24 tokens finish:
        # epsilon picked after a timed probe would flake — instead pin the
        # deadline between TTFT and completion using the engine's own clock
        probe, _ = eng.run(full)
        ttft, total = probe[0].ttft_s, probe[0].done_s - probe[0].arrival_s
        deadline = ttft + (total - ttft) / 3
        done, stats = eng.run([_req(0, plen=8, new=24, deadline_s=deadline)])
        c = done[0]
        assert c.finish_reason == "timeout" and 0 < len(c.tokens) < 24
        assert c.tokens == want[:len(c.tokens)]   # exact partial prefix
        assert stats.num_timeout == 1
        assert eng.pool.num_free == eng.policy.num_slots


class TestPreemption:
    def test_cow_exhaustion_mid_decode_preempts_and_resumes(self, smoke_lm):
        """Engine-level PoolExhausted during prepare_append on a starved
        block pool: the youngest sequence is preempted with exact rollback
        and later resumed; every survivor's tokens match a roomy-pool run,
        and the block-pool invariants hold throughout."""
        cfg, params = smoke_lm
        roomy = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=32,
                       prefix_cache=True, block_size=8)
        reqs = synthetic_requests(8, pattern="burst", min_prompt=12,
                                  max_prompt=28, min_new=24, max_new=30,
                                  vocab=cfg.vocab_size, seed=5)
        want = {c.rid: c.tokens for c in roomy.run(reqs)[0]}
        # 8 rows x up to ceil(58/8)=8 blocks each want 64 blocks; give 24
        tight = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=32,
                       prefix_cache=True, block_size=8, num_blocks=24)
        done, stats = tight.run(reqs, check_invariants=True)
        assert stats.preemptions > 0, "starved pool must preempt"
        assert stats.resumes > 0, "some preempted request must resume"
        resumed_ok = 0
        for c in done:
            if c.ok:
                assert c.tokens == want[c.rid], f"rid {c.rid} diverged"
                resumed_ok += c.preemptions > 0
            else:
                assert c.finish_reason == "preempted-retry-exhausted"
                assert c.tokens == want[c.rid][:len(c.tokens)], \
                    f"rid {c.rid}: partial not an exact prefix"
        assert resumed_ok > 0, "a preempted request must finish exactly"
        tight.pool.blocks.check()
        assert tight.pool.num_free == tight.policy.num_slots

    def test_forced_steal_preempts_token_identically(self, smoke_lm):
        """FaultPlan block steal on an otherwise-roomy pool: preemption is
        purely fault-induced, and every request still finishes with exactly
        the fault-free tokens (exact rollback + seeded sampler resume)."""
        cfg, params = smoke_lm
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=16,
                     prefix_cache=True, block_size=8, num_blocks=32)
        reqs = synthetic_requests(8, pattern="burst", min_prompt=12,
                                  max_prompt=28, min_new=10, max_new=14,
                                  vocab=cfg.vocab_size, temperature=0.7,
                                  seed=7)
        want = {c.rid: c.tokens for c in eng.run(reqs)[0]}
        plan = FaultPlan(seed=0, events=[
            FaultEvent(step=2, kind="steal_blocks", blocks=28),
            FaultEvent(step=6, kind="cow_storm")], hold_steps=4)
        done, stats = eng.run(reqs, faults=plan, check_invariants=True)
        assert stats.preemptions > 0
        for c in done:
            if c.ok:
                assert c.tokens == want[c.rid], f"rid {c.rid}"
            else:
                assert c.tokens == want[c.rid][:len(c.tokens)]
        assert any(c.preemptions > 0 and c.ok for c in done)


class TestChaosSoak:
    def test_soak_64_requests_4_fault_kinds(self, smoke_lm):
        """Acceptance: seeded plan with all five fault kinds over a
        64-request workload — zero uncaught exceptions, BlockPool.check()
        after every step, token-identical outputs for unaffected rids."""
        cfg, params = smoke_lm
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=8,
                     prefix_cache=True, block_size=8, num_blocks=48)
        reqs = synthetic_requests(64, pattern="bursty", min_prompt=4,
                                  max_prompt=28, min_new=3, max_new=7,
                                  vocab=cfg.vocab_size, prefix_share=0.4,
                                  shared_prefix_len=16, seed=11)
        plan = FaultPlan.generate(23, [r.rid for r in reqs], num_steps=40,
                                  oversized=2, garbage=2, deadline=2,
                                  steals=2, storms=2, steal_blocks=24,
                                  hold_steps=6)
        assert len(plan.kinds_used) >= 4, plan.kinds_used
        result = chaos_soak(eng, reqs, plan)
        assert result.ok, "\n".join(result.violations)
        assert result.chaos_stats.num_rejected == 4
        assert result.chaos_stats.num_timeout >= 2
        # determinism: the same seed replays the exact same plan
        again = FaultPlan.generate(23, [r.rid for r in reqs], num_steps=40,
                                   oversized=2, garbage=2, deadline=2,
                                   steals=2, storms=2, steal_blocks=24,
                                   hold_steps=6)
        assert again.request_faults == plan.request_faults
        assert again.events == plan.events


class TestStatsAccounting:
    def test_finish_reason_counts_round_trip(self, smoke_lm):
        cfg, params = smoke_lm
        eng = Engine(params, cfg, max_batch=4, max_prompt=16, max_new=8)
        reqs = [_req(0), _req(1),
                Request(rid=2, tokens=np.ones(99, np.int32),
                        max_new_tokens=4),
                dataclasses.replace(_req(3), deadline_s=0.0)]
        done, stats = eng.run(reqs)
        js = stats.to_json()
        assert js["finish_reasons"] == {"length": 2, "rejected": 1,
                                        "timeout": 1}
        assert js["num_ok"] == 2 and js["num_rejected"] == 1
        assert js["num_timeout"] == 1
        # admitted = 4 - 1 rejected - 0 shed = 3; ok = 2
        assert js["goodput"] == pytest.approx(2 / 3)
        assert sum(js["finish_reasons"].values()) == len(done)
