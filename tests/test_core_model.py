"""Core co-design engine: paper formulas, quantization math, advisor case
studies (Fig. 1, §VII-B, Fig. 20)."""

import pytest

from repro.configs.base import ModelConfig
from repro.core import (advisor, gemm_model, quantization as q,
                        transformer_gemms as tg)
from repro.core.hardware import A100_40GB, TPU_V5E


def vanilla(h=2560, L=32, a=32, v=50257, s=2048):
    return ModelConfig(name="vanilla", family="dense", num_layers=L,
                       d_model=h, num_heads=a, num_kv_heads=a, d_ff=4 * h,
                       vocab_size=v, mlp_type="gelu", norm_type="layernorm")


class TestPaperFormulas:
    def test_param_count_matches_paper_formula(self):
        # paper §III-C: P = 12 h^2 L + 13 h L + (v + s) h.  Our count covers
        # the same terms (untied head ~ +vh); require agreement within 3%.
        h, L, v, s = 2560, 32, 50257, 2048
        cfg = vanilla(h, L, v=v, s=s)
        paper = 12 * h * h * L + 13 * h * L + (v + s) * h
        ours = cfg.param_count() - v * h  # paper assumes tied head
        assert abs(ours - paper) / paper < 0.03

    def test_forward_flops_formula(self):
        # paper: 24 b s h^2 (1 + s/6h) per layer
        h, b, s = 2560, 4, 2048
        cfg = vanilla(h, L=1)
        gemms = tg.layer_gemms(cfg, b, s)
        got = sum(g.flops for g in gemms)
        want = tg.vanilla_forward_flops(h, b, s)
        assert abs(got - want) / want < 0.01

    def test_table2_gemm_shapes(self):
        # Table II: QKV transform is (b s, h) x (h, 3h/t)
        cfg = vanilla()
        b, s, t = 4, 2048, 4
        gemms = {g.name: g for g in tg.layer_gemms(cfg, b, s, t=t)}
        qkv = gemms["qkv_transform"]
        assert (qkv.m, qkv.k, qkv.n) == (b * s, cfg.d_model, 3 * cfg.d_model // t)
        score = gemms["attn_score"]
        assert score.batch == b * cfg.num_heads // t
        assert (score.m, score.k, score.n) == (s, cfg.head_dim, s)


class TestQuantization:
    def test_tile_utilization_aligned_is_one(self):
        assert q.tile_utilization(256, 256, 256, TPU_V5E) == pytest.approx(1.0)

    def test_tile_utilization_misaligned(self):
        # head_dim 80 -> padded to 128 lanes: utilization 80/128
        u = q.tile_utilization(4096, 4096, 80, TPU_V5E)
        assert u == pytest.approx(80 / 128, rel=1e-6)

    def test_wave_quantization_gpu(self):
        # 109 blocks on 108 SMs -> half the throughput of 108 blocks
        hw = A100_40GB
        full = q.wave_efficiency(128 * 9, 256 * 12, hw)  # 108 tiles
        spill = q.wave_efficiency(128 * 9, 256 * 12 + 256, hw)  # a 109th tile
        assert full == pytest.approx(1.0)
        assert spill < 0.6

    def test_wave_free_constraint(self):
        hw = A100_40GB
        assert q.wave_free(128 * 54, 256 * 2, hw)  # 108 tiles exactly
        assert not q.wave_free(128 * 54 + 1, 256 * 2, hw)

    def test_tpu_has_no_wave_quantization(self):
        assert q.wave_efficiency(100, 100, TPU_V5E) == 1.0

    def test_shard_quantization(self):
        assert q.shard_quantization(64, 16) == 1.0
        assert q.shard_quantization(20, 16) == pytest.approx(20 / 32)


class TestGemmModel:
    def test_aligned_beats_misaligned(self):
        g_al = gemm_model.GEMM("a", 4096, 128, 4096, batch=32)
        g_mis = gemm_model.GEMM("b", 4096, 80, 4096, batch=32)
        e_al = gemm_model.estimate(g_al)
        e_mis = gemm_model.estimate(g_mis)
        assert e_al.achieved_tflops > e_mis.achieved_tflops

    def test_memory_bound_small_gemm(self):
        e = gemm_model.estimate(gemm_model.GEMM("small", 128, 128, 128))
        assert e.bound in ("memory", "overhead")

    def test_compute_bound_big_gemm(self):
        e = gemm_model.estimate(gemm_model.GEMM("big", 8192, 8192, 8192))
        assert e.bound == "compute"


class TestAdvisorCaseStudies:
    def test_gpt3_case_study(self):
        # Fig. 1: the 2.7B shape (a=32, head_dim 80) has a faster nearby
        # shape with a=20 (head_dim 128); paper reports ~1.18-1.39x.
        c0 = vanilla()
        props = advisor.advise(c0, microbatch=4)
        changes = {p.change: p for p in props}
        a20 = [p for p in props if "heads 32 -> 20" in p.change]
        assert a20, f"a=20 proposal missing: {list(changes)}"
        assert 1.05 < a20[0].predicted_speedup < 1.6
        assert abs(a20[0].param_delta) < 1e-6

    def test_vocab_padding_proposal(self):
        c0 = vanilla()
        props = advisor.advise(c0, microbatch=4)
        vp = [p for p in props if "pad vocab" in p.change]
        assert vp and vp[0].config.vocab_size == 50304  # the nanoGPT number
        assert vp[0].predicted_speedup >= 1.0

    def test_swiglu_dff_search(self):
        # §VII-B: SwiGLU 8h/3 misaligns; advisor proposes a lane-aligned d_ff
        h = 4096
        cfg = ModelConfig(name="sw", family="dense", num_layers=32,
                          d_model=h, num_heads=32, num_kv_heads=32,
                          d_ff=int(8 * h / 3),  # 10922: misaligned
                          vocab_size=32000, mlp_type="swiglu")
        props = advisor.advise(cfg)
        dff = [p for p in props if "d_ff" in p.change]
        assert dff
        best = dff[0].config.d_ff
        assert best % 128 == 0
        # llama-2-7b chose 11008 = 86*128 in exactly this range
        assert 10624 <= best <= 11264

    def test_check_alignment_flags_misalignment(self):
        bad = {f.rule: f for f in advisor.check_alignment(vanilla())}
        assert bad["vocab_alignment"].severity == "bad"
        assert bad["head_dim_alignment"].severity == "bad"

    def test_best_combined_stacks_fixes(self):
        p = advisor.best_combined(vanilla())
        assert p.predicted_speedup > 1.1
        # head_dim ends lane-aligned; vocab padding is enforced structurally
        # by ModelConfig.padded_vocab_size (its tile win on TPU is ~0.1%, so
        # the greedy ranker may not pick it — unlike the GPU kernel-selection
        # cliff the paper reports)
        assert (p.config.d_model // p.config.num_heads) % 64 == 0


class TestArchGemmEnumeration:
    @pytest.mark.parametrize("arch", [
        "zamba2-2.7b", "qwen1.5-4b", "nemotron-4-340b", "internlm2-1.8b",
        "command-r-plus-104b", "deepseek-v3-671b",
        "llama4-maverick-400b-a17b", "internvl2-76b", "whisper-small",
        "mamba2-780m"])
    def test_model_gemms_nonempty_all_archs(self, arch):
        from repro.configs.registry import get_config
        cfg = get_config(arch)
        gemms = tg.model_gemms(cfg, b=1, s=512, t=16, mode="train")
        assert len(gemms) > cfg.num_layers  # at least one GEMM per layer
        assert all(g.flops > 0 for g in gemms)
        decode = tg.model_gemms(cfg, b=4, s=512, t=16, mode="decode")
        assert sum(g.flops for g in decode) < sum(g.flops for g in gemms)
