"""Pipeline parallelism: GPipe schedule over a host-device mesh axis must
reproduce the sequential layer stack exactly."""
import subprocess
import sys
import textwrap


def _run(code: str, timeout=560):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent(code))
    r = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd="/root/repo", timeout=timeout)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    return r.stdout


def test_pipeline_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply, split_layers_into_stages

        L, S, M, B, D = 8, 4, 6, 2, 16   # layers, stages, microbatches
        mesh = jax.make_mesh((S, 2), ("pod", "data"))
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (L, D, D)) * (0.5 / D ** 0.5)
        x = jax.random.normal(jax.random.fold_in(key, 1), (M, B, D))

        def layer(wi, h):
            return jnp.tanh(h @ wi)

        def stage_fn(params, h):   # params: (L/S, D, D)
            def body(h, wi):
                return layer(wi, h), None
            h, _ = jax.lax.scan(body, h, params)
            return h

        # sequential reference
        def seq(x1):
            def body(h, wi):
                return layer(wi, h), None
            h, _ = jax.lax.scan(body, x1, w)
            return h
        want = jax.vmap(seq)(x)

        staged = split_layers_into_stages({"w": w}, S)["w"]
        got = pipeline_apply(stage_fn, staged, x, mesh, axis="pod")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_pipeline_rejects_indivisible_layers():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.parallel.pipeline import split_layers_into_stages
        try:
            split_layers_into_stages({"w": jnp.zeros((7, 4, 4))}, 2)
            print("NO_ERROR")
        except AssertionError as e:
            print("RULE_ENFORCED", "paper" in str(e))
    """)
    assert "RULE_ENFORCED True" in out
