"""MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.mlp import apply_mlp
from repro.models.moe import _capacity, apply_moe, init_moe

KEY = jax.random.PRNGKey(5)


def moe_cfg(**kw):
    base = dict(name="m", family="moe", num_layers=2, d_model=32,
                num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                mlp_type="swiglu", num_experts=4, top_k=2, moe_d_ff=48,
                moe_capacity_factor=8.0, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_identical_experts_equal_single_mlp():
    """With every expert's weights identical and no drops, MoE(x) == MLP(x)
    (gates renormalize to 1)."""
    cfg = moe_cfg()
    p = init_moe(KEY, cfg)
    for k in ("w_up", "w_gate", "w_down"):
        p[k] = jnp.broadcast_to(p[k][0], p[k].shape)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.5
    y, aux = apply_moe(p, x, cfg)

    mlp_params = {"w_gate": p["w_gate"][0], "w_up": p["w_up"][0],
                  "w_down": p["w_down"][0]}
    cfg_dense = dataclasses.replace(cfg, d_ff=cfg.moe_d_ff)
    want = apply_mlp(mlp_params, x.reshape(-1, cfg.d_model), cfg_dense)
    if cfg.num_shared_experts:
        want = want + apply_mlp(p["shared"], x.reshape(-1, cfg.d_model), cfg_dense)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(want), atol=1e-4, rtol=1e-4)


def test_aux_loss_bounds():
    """Load-balance loss is >= 1 (perfectly balanced) and finite."""
    cfg = moe_cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model))
    _, aux = apply_moe(p, x, cfg)
    assert np.isfinite(float(aux))
    assert float(aux) >= 0.99  # E * sum(f_i P_i) >= 1 by Cauchy-Schwarz


def test_capacity_drops_are_bounded():
    """With tiny capacity, output is still finite and shaped."""
    cfg = moe_cfg(moe_capacity_factor=0.25)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model))
    y, _ = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_capacity_alignment():
    """Capacity is rounded up to the 8-row sublane tile (paper alignment)."""
    cfg = moe_cfg()
    assert _capacity(1024, cfg) % 8 == 0


def test_grad_flows_through_dispatch():
    cfg = moe_cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 32, cfg.d_model)) * 0.5

    def loss(p):
        y, aux = apply_moe(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.sum(jnp.abs(t))) for t in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # router must receive gradient (through the gate weights)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
