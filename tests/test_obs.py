"""Observability layer: tracer / metrics / watchdog units, and the engine
integration contract — steady-state serving after calibration performs ZERO
unexpected recompiles (armed watchdog passes), and an injected out-of-lattice
shape demonstrably fires it."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.registry import get_smoke_config
from repro.models import init_lm
from repro.obs import view
from repro.obs.trace import NULL_SPAN, Tracer
from repro.serving.engine import (Completion, Engine, EngineStats,
                                  synthetic_requests)
from repro.tuning.cache import TunedConfig
from repro.tuning.measure import wall_us


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with obs disabled and empty — the rest of
    the suite must never see leaked spans/metrics."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_smoke_config("internlm2-1.8b")
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


class TestTracer:
    def test_disabled_is_shared_noop(self):
        s = obs.span("anything", rid=1)
        assert s is NULL_SPAN and s.dur_s == 0.0
        with s:
            pass
        obs.instant("nothing")
        assert obs.get_tracer().events() == []

    def test_span_nesting_and_chrome_validity(self):
        obs.enable(annotate_device=False)
        with obs.span("outer", cat="engine", rid=7):
            with obs.span("inner", cat="sample"):
                pass
        evs = obs.get_tracer().events()
        by_name = {e["name"]: e for e in evs}
        inner, outer = by_name["inner"], by_name["outer"]
        # depth comes from the per-thread span stack; containment from the
        # shared monotonic clock
        assert outer["args"]["depth"] == 0 and inner["args"]["depth"] == 1
        assert inner["ts"] >= outer["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
        assert outer["args"]["rid"] == 7

        chrome = obs.get_tracer().to_chrome()
        # valid Chrome trace-event JSON: metadata header + X events with
        # ts/dur/pid/tid, JSON-round-trippable, displayTimeUnit present
        assert chrome["displayTimeUnit"] == "ms"
        assert chrome["traceEvents"][0]["ph"] == "M"
        for e in chrome["traceEvents"][1:]:
            assert e["ph"] in ("X", "i")
            assert e["ts"] >= 0 and "pid" in e and "tid" in e
            if e["ph"] == "X":
                assert e["dur"] >= 0
        assert json.loads(json.dumps(chrome)) == chrome

    def test_instant_and_bounded_buffer(self):
        tr = Tracer(capacity=4, annotate_device=False)
        for i in range(6):
            tr.instant("tick", i=i)
        evs = tr.events()
        assert len(evs) == 4 and tr.dropped == 2
        assert all(e["ph"] == "i" and e["s"] == "t" for e in evs)
        assert evs[0]["args"]["i"] == 2  # oldest two fell off

    def test_save_is_loadable(self, tmp_path):
        obs.enable(annotate_device=False)
        with obs.span("step"):
            pass
        path = tmp_path / "trace.json"
        obs.get_tracer().save(str(path))
        trace = json.loads(path.read_text())
        assert any(e["ph"] == "X" and e["name"] == "step"
                   for e in trace["traceEvents"])


class TestMetrics:
    def test_instruments(self):
        obs.counter("t.count").inc()
        obs.counter("t.count").inc(2)
        obs.gauge("t.depth").set(5)
        h = obs.histogram("t.lat")
        h.observe_many([1.0, 2.0, 3.0, 4.0])
        snap = obs.get_metrics().snapshot()
        assert snap["counters"]["t.count"] == 3
        assert snap["gauges"]["t.depth"] == 5.0
        s = snap["histograms"]["t.lat"]
        assert s["count"] == 4 and s["sum"] == 10.0 and s["min"] == 1.0
        assert s["p50"] == pytest.approx(2.5)
        json.dumps(snap)  # snapshot must be JSON-serializable as-is

    def test_prometheus_text(self):
        obs.counter("engine.tokens_generated").inc(42)
        obs.gauge("engine.queue_depth").set(3)
        obs.histogram("engine.decode_step_s").observe(0.25)
        text = obs.get_metrics().to_prometheus()
        assert "# TYPE engine_tokens_generated counter" in text
        assert "engine_tokens_generated 42" in text
        assert "# TYPE engine_queue_depth gauge" in text
        assert 'engine_decode_step_s{quantile="0.5"} 0.25' in text
        assert "engine_decode_step_s_count 1" in text


class TestCompileWatch:
    def test_records_arming_and_mirror(self):
        obs.enable(annotate_device=False)

        def watched(x):
            return x * 2.0 + 1.0

        f = jax.jit(watched)
        with obs.CompileWatch() as watch:
            jax.block_until_ready(f(jnp.ones((4,), jnp.float32)))
            recs = [r for r in watch.records if "watched" in r.name]
            assert recs and recs[0].wall_s > 0 and not recs[0].armed
            n = len(watch.records)
            # jit cache hit: same shape must not record a compile
            jax.block_until_ready(f(jnp.ones((4,), jnp.float32)))
            assert len(watch.records) == n
            watch.check()  # not armed -> never raises

            watch.arm()
            with pytest.raises(obs.UnexpectedCompile):
                f(jnp.ones((5,), jnp.float32))
            assert watch.violations and watch.violations[-1].armed
            with pytest.raises(obs.UnexpectedCompile):
                watch.check()
            watch.disarm()

            d = watch.to_json()
            assert d["records"] and d["violations"]
            assert d["backend_compiles"] >= 1
        # mirrored into the metrics registry while obs was enabled
        snap = obs.get_metrics().snapshot()
        assert snap["counters"]["compile.count"] >= 2
        assert snap["counters"]["compile.violations"] >= 1


class TestDrift:
    def test_report_normalizes_by_median_ratio(self):
        mon = obs.DriftMonitor(hw_name="test_hw")
        mon.add_site("a", 0.001)
        mon.add_site("b", 0.001)
        for _ in range(3):
            mon.observe("a", 0.002)  # ratio 2.0
        mon.observe("b", 0.001)      # ratio 1.0
        rows = {r["site"]: r for r in mon.report()}
        assert rows["a"]["ratio"] == pytest.approx(2.0)
        assert rows["b"]["ratio"] == pytest.approx(1.0)
        med = 1.5  # median of [2.0, 1.0]
        assert rows["a"]["rel_drift"] == pytest.approx(2.0 / med)
        assert rows["b"]["rel_drift"] == pytest.approx(1.0 / med)

    def test_unknown_site_and_empty_sites(self, tmp_path):
        mon = obs.DriftMonitor()
        mon.add_site("never_observed", 0.5)
        mon.observe("surprise", 0.1)  # auto-created, predicted 0
        rows = mon.report()
        assert [r["site"] for r in rows] == ["surprise"]
        assert rows[0]["ratio"] is None and rows[0]["rel_drift"] is None
        mon.save(str(tmp_path / "drift.json"))
        d = json.loads((tmp_path / "drift.json").read_text())
        assert d["rows"][0]["site"] == "surprise"


class TestEngineStatsSplits:
    def _comp(self, rid, cached):
        return Completion(rid=rid, prompt_len=8, tokens=[1, 2],
                          arrival_s=0.0, first_token_s=0.1, done_s=0.2,
                          itl_s=[0.05], cached_tokens=cached)

    def test_all_cold(self):
        st = EngineStats.collect([self._comp(0, 0), self._comp(1, 0)], 1.0)
        assert st.ttft_hit_p50_s is None
        assert st.ttft_cold_p50_s == pytest.approx(0.1)

    def test_all_hit(self):
        st = EngineStats.collect([self._comp(0, 4)], 1.0)
        assert st.ttft_cold_p50_s is None
        assert st.ttft_hit_p50_s == pytest.approx(0.1)
        assert st.cache_hit_requests == 1

    def test_empty_run(self):
        st = EngineStats.collect([], 0.0)
        assert st.tok_s == 0.0
        assert st.ttft_hit_p50_s is None and st.ttft_cold_p50_s is None
        json.dumps(st.to_json())


class TestMeasureSamples:
    def test_return_samples(self):
        def f(x):
            return x + 1.0

        x = jnp.ones((8,), jnp.float32)
        mean, samples = wall_us(f, x, iters=3, warmup=1, return_samples=True)
        assert len(samples) == 3 and all(s > 0 for s in samples)
        assert mean == pytest.approx(sum(samples) / 3)
        # default path unchanged: a bare float
        assert isinstance(wall_us(f, x, iters=2, warmup=1), float)

    def test_tuned_config_std_roundtrip(self):
        cfg = TunedConfig(op="matmul", shape=(8, 8, 8), dtype="float32",
                          hw_name="test", blocks={"block_m": 8},
                          time_us=10.0, time_us_std=1.5)
        back = TunedConfig.from_json(cfg.to_json())
        assert back.time_us_std == 1.5
        # pre-std cache files load with the 0.0 default
        old = {k: v for k, v in cfg.to_json().items() if k != "time_us_std"}
        assert TunedConfig.from_json(old).time_us_std == 0.0


class TestEngineObservability:
    """The acceptance contract, end to end on the smoke model: calibrate ->
    arm -> serve with zero unexpected recompiles, spans/metrics/drift line up
    with the engine's own counters, the dump renders, and an out-of-lattice
    shape fires the armed watchdog."""

    def test_steady_state_and_armed_fire(self, smoke_lm, tmp_path):
        cfg, params = smoke_lm
        eng = Engine(params, cfg, max_batch=2, max_prompt=16, max_new=8)
        watch = obs.CompileWatch().install()
        try:
            eng.calibrate_step_s()  # warms every (bucket, decode) program
            warm = len(watch.records)
            assert warm >= eng.policy.num_programs  # prefills + decode (+aux)

            obs.enable(annotate_device=False)
            obs.reset()  # counters below are per-run, not per-process
            watch.arm()
            reqs = synthetic_requests(4, pattern="burst", min_prompt=4,
                                      max_prompt=16, min_new=2, max_new=8,
                                      vocab=cfg.vocab_size, seed=3)
            done, stats = eng.run(reqs)
            watch.check()  # ZERO unexpected recompiles in steady state
            assert len(watch.records) == warm and not watch.violations
            watch.disarm()

            # spans mirror the engine's own counters one-to-one
            evs = obs.get_tracer().events()
            spans = [e for e in evs if e["ph"] == "X"]
            by = lambda n: [e for e in spans if e["name"] == n]
            assert len(by("decode_step")) == stats.decode_steps > 0
            assert len(by("prefill")) == stats.prefills == len(done)
            assert len(by("admit")) == stats.prefills
            # every sample span nests inside an admit or decode_step interval
            parents = by("decode_step") + by("admit")
            for s in by("sample"):
                assert s["args"]["depth"] >= 1
                assert any(p["ts"] <= s["ts"] and
                           s["ts"] + s["dur"] <= p["ts"] + p["dur"]
                           for p in parents)

            snap = obs.get_metrics().snapshot()
            assert snap["counters"]["engine.tokens_generated"] == \
                stats.total_generated == sum(len(c.tokens) for c in done)
            assert snap["counters"]["engine.decode_steps"] == stats.decode_steps
            assert snap["counters"]["engine.requests_completed"] == len(done)
            assert snap["histograms"]["engine.decode_step_s"]["count"] == \
                stats.decode_steps

            # drift accumulated one observation per decode step
            rows = {r["site"]: r for r in eng.drift.report()}
            assert rows["decode_step"]["count"] == stats.decode_steps
            assert any(s.startswith("prefill_") for s in rows)

            # the dump round-trips through export_all and the view CLI
            dump = str(tmp_path / "dump")
            paths = obs.export_all(dump, drift=eng.drift, watch=watch)
            assert sorted(paths) == ["compiles", "drift", "metrics",
                                     "prometheus", "trace"]
            trace = json.loads(open(paths["trace"]).read())
            assert any(e["ph"] == "X" and e["name"] == "decode_step"
                       for e in trace["traceEvents"])
            lines = view.render_summary(dump)
            text = "\n".join(lines)
            assert "decode_step" in text and "Compiles" in text
            assert view.main([dump]) == 0

            # inject an out-of-lattice shape: the armed watchdog must fire
            # from inside the offending jit call
            watch.arm()
            bucket = eng.policy.prompt_buckets[0]
            bad = np.zeros((1, bucket + 3), np.int32)  # width off the lattice
            with pytest.raises(obs.UnexpectedCompile):
                eng._prefills[bucket](params, jnp.asarray(bad),
                                      jnp.asarray(1, jnp.int32))
            assert watch.violations
            watch.disarm()
        finally:
            watch.uninstall()
