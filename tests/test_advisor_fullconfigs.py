"""Advisor findings on the REAL assigned configs at production parallelism —
regression-locks the paper's rules against the model zoo."""
import pytest

from repro.configs.registry import get_config
from repro.core import advisor

TP = 16


def _findings(arch):
    return {f.rule: f for f in advisor.check_alignment(get_config(arch), tp=TP)}


def test_llama4_vocab_misaligned():
    # 202048 % 128 == 64 — a real misalignment in a production model
    f = _findings("llama4-maverick-400b-a17b")
    assert f["vocab_alignment"].severity != "ok"
    assert "202112" in f["vocab_alignment"].message
    # and the config's structural padding fixes it
    assert get_config("llama4-maverick-400b-a17b").padded_vocab_size % 128 == 0


def test_llama4_heads_dont_divide_tp():
    f = _findings("llama4-maverick-400b-a17b")
    assert f["heads_div_tp"].severity == "bad"  # 40 % 16 != 0


def test_qwen_heads_dont_divide_tp():
    # the §Perf qwen hillclimb lever: a=20 vs tp=16
    f = _findings("qwen1.5-4b")
    assert f["heads_div_tp"].severity == "bad"


def test_zamba2_head_dim_misaligned():
    # 2560/32 = 80 — the same misalignment as the paper's GPT-3 2.7B study
    f = _findings("zamba2-2.7b")
    assert f["head_dim_alignment"].severity == "bad"


def test_whisper_shard_width_under_lane_tile():
    # 768/16 = 48 < 128 — the §Perf whisper cell's root cause
    f = _findings("whisper-small")
    assert f["hidden_shard_alignment"].severity != "ok"


def test_deepseek_expert_rules_pass():
    f = _findings("deepseek-v3-671b")
    assert f["experts_div_ep"].severity == "ok"       # 256 % 16 == 0
    assert f["expert_dff_alignment"].severity == "ok"  # 2048 % 128 == 0


def test_nemotron_is_well_codesigned():
    # NVIDIA's 340B follows the paper's rules: 4h MLP, aligned shards
    f = _findings("nemotron-4-340b")
    assert f["dff_shard_alignment"].severity == "ok"
    assert f["hidden_shard_alignment"].severity == "ok"
    assert f["vocab_alignment"].severity == "ok"      # 256000 % 128 == 0


def test_mamba2_ssd_shapes_aligned():
    f = _findings("mamba2-780m")
    assert f["ssm_state_alignment"].severity == "ok"   # N=128
    assert f["ssm_chunk_alignment"].severity == "ok"   # Q=256


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "internvl2-76b",
                                  "command-r-plus-104b"])
def test_advisor_always_has_param_preserving_proposals(arch):
    props = advisor.advise(get_config(arch), tp=TP, param_tolerance=0.03)
    assert props, arch
    for p in props:
        assert abs(p.param_delta) <= 0.03 + 1e-9
