"""Low-precision execution: quant helpers, int8/fp8 Pallas GEMM parity,
int8 fused-MLP, quantized linear dispatch, mixed-dtype tuning keys, and the
pre-PR tuning-cache JSON format regression."""
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.kernels.quantized.ops import (fp8_matmul, int8_fused_mlp_hidden,
                                         int8_matmul)
from repro.models import apply_lm, init_lm
from repro.models.linear import (QUANT_WEIGHT_KEYS, QuantizedLinear, linear,
                                 quantize_linear_params, quantized_mlp)
from repro.quant import (FP8_DTYPES, QuantizedTensor, dequantize_int8,
                         fp8_round_trip, kv_bytes_per_token, quantize_int8,
                         quantize_weight)
from repro.tuning import TuningCache, set_default_cache
from repro.tuning.cache import cache_key, mixed_dtype
from repro.tuning.search import (autotune_fp8_matmul, autotune_int8_fused_mlp,
                                 autotune_int8_matmul)

KEY = jax.random.PRNGKey(0)
FIXTURES = pathlib.Path(__file__).parent / "fixtures"


@pytest.fixture(autouse=True)
def _reset_default_cache():
    yield
    set_default_cache(None)


# -- quant helpers -----------------------------------------------------------

class TestQuantHelpers:
    def test_int8_round_trip(self):
        x = jax.random.normal(KEY, (16, 64))
        q, scale = quantize_int8(x)
        assert q.dtype == jnp.int8
        assert scale.shape == (16, 1)
        back = dequantize_int8(q, scale)
        # symmetric absmax: worst-case error is half a quantization step
        step = np.asarray(scale).max()
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   atol=0.51 * step)

    def test_quantize_weight_per_output_channel(self):
        # give column j dynamic range ~(j+1): per-channel scales must track it
        w = jax.random.normal(KEY, (32, 8)) * jnp.arange(1.0, 9.0)
        qt = quantize_weight(w)
        assert isinstance(qt, QuantizedTensor)
        assert qt.q.dtype == jnp.int8 and qt.q.shape == w.shape
        assert qt.scale.shape == (1, 8) and qt.axis == -2
        assert bool(jnp.all(qt.scale[0, 1:] > qt.scale[0, :-1] * 0.5))
        back = qt.q.astype(jnp.float32) * qt.scale
        rel = np.abs(np.asarray(back - w)).max() / np.abs(np.asarray(w)).max()
        assert rel < 0.01

    @pytest.mark.parametrize("fp8", FP8_DTYPES)
    def test_fp8_round_trip(self, fp8):
        x = jax.random.normal(KEY, (8, 32))
        y = fp8_round_trip(x, fp8)
        assert y.dtype == x.dtype
        # e4m3 has a 3-bit mantissa -> ~6% worst-case relative rounding
        np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                                   rtol=0.13, atol=1e-3)

    def test_unknown_dtypes_raise(self):
        x = jnp.ones((4, 4))
        with pytest.raises(ValueError, match="unknown quant dtype"):
            quantize_weight(x, "int4")
        with pytest.raises(ValueError, match="unknown fp8 dtype"):
            fp8_round_trip(x, "float8_bogus")

    def test_kv_bytes_per_token(self):
        # bf16 baseline: 2 bytes/elem for K and V
        assert kv_bytes_per_token(8, 128) == 2 * 8 * 128 * 2
        # int8: 1 byte/elem + one f32 scale per (token, head) for K and V
        assert kv_bytes_per_token(8, 128, "int8") == 2 * 8 * 128 + 2 * 8 * 4
        # halving only approaches 2x as head_dim grows past the scale overhead
        assert (kv_bytes_per_token(8, 128) / kv_bytes_per_token(8, 128, "int8")
                > 1.9)


# -- int8 / fp8 GEMM kernels -------------------------------------------------

def _operands(m, k, n, dtype=jnp.float32):
    a = (jax.random.normal(KEY, (m, k)) * 0.5).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(KEY, 1), (k, n)) * 0.5
         ).astype(dtype)
    return a, w


class TestInt8Matmul:
    @pytest.mark.parametrize("shape", [(128, 128, 128), (40, 72, 56),
                                       (96, 200, 136)])
    def test_pallas_matches_jnp_ref(self, shape):
        a, w = _operands(*shape)
        got = int8_matmul(a, w, interpret=True)
        want = int8_matmul(a, w, use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_close_to_f32_gemm(self):
        a, w = _operands(64, 128, 64)
        got = np.asarray(int8_matmul(a, w, interpret=True))
        want = np.asarray(a @ w)
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < 0.02  # quantization noise only

    def test_block_size_invariance(self):
        a, w = _operands(100, 72, 60)
        base = np.asarray(int8_matmul(a, w, interpret=True))
        for bm, bn, bk in [(32, 32, 32), (64, 128, 64), (256, 256, 256)]:
            got = np.asarray(int8_matmul(a, w, block_m=bm, block_n=bn,
                                         block_k=bk, interpret=True))
            np.testing.assert_allclose(got, base, atol=3e-5, rtol=3e-5)

    def test_prequantized_weight_matches_float_weight(self):
        a, w = _operands(32, 64, 48)
        got = int8_matmul(a, quantize_weight(w), interpret=True)
        want = int8_matmul(a, w, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-6, rtol=3e-6)

    def test_raw_int8_weight_rejected(self):
        a = jnp.ones((8, 16))
        wq = jnp.ones((16, 8), jnp.int8)
        with pytest.raises(ValueError, match="QuantizedTensor"):
            int8_matmul(a, wq)


class TestFp8Matmul:
    @pytest.mark.parametrize("fp8", FP8_DTYPES)
    def test_pallas_matches_jnp_ref(self, fp8):
        a, w = _operands(48, 72, 40)
        got = fp8_matmul(a, w, fp8_dtype=fp8, interpret=True)
        want = fp8_matmul(a, w, fp8_dtype=fp8, use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_close_to_f32_gemm(self):
        a, w = _operands(32, 128, 32)
        got = np.asarray(fp8_matmul(a, w, interpret=True))
        want = np.asarray(a @ w)
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < 0.05

    def test_unknown_fp8_dtype_raises(self):
        a, w = _operands(8, 8, 8)
        with pytest.raises(ValueError, match="fp8"):
            fp8_matmul(a, w, fp8_dtype="float8_bogus")


class TestInt8FusedMlp:
    @pytest.mark.parametrize("mlp_type", ["swiglu", "gelu"])
    @pytest.mark.parametrize("shape", [(64, 64, 128), (40, 72, 88)])
    def test_pallas_matches_jnp_ref(self, mlp_type, shape):
        m, h, f = shape
        x = jax.random.normal(KEY, (m, h)) * 0.5
        wg = (jax.random.normal(jax.random.fold_in(KEY, 1), (h, f)) * 0.5
              if mlp_type == "swiglu" else None)
        wu = jax.random.normal(jax.random.fold_in(KEY, 2), (h, f)) * 0.5
        got = int8_fused_mlp_hidden(x, wg, wu, mlp_type=mlp_type,
                                    interpret=True)
        want = int8_fused_mlp_hidden(x, wg, wu, mlp_type=mlp_type,
                                     use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-5, rtol=5e-5)

    def test_close_to_float_reference(self):
        from repro.kernels.fused_mlp.ref import fused_mlp_hidden_ref
        m, h, f = 32, 64, 96
        x = jax.random.normal(KEY, (m, h)) * 0.5
        wg = jax.random.normal(jax.random.fold_in(KEY, 1), (h, f)) * 0.5
        wu = jax.random.normal(jax.random.fold_in(KEY, 2), (h, f)) * 0.5
        got = np.asarray(int8_fused_mlp_hidden(x, wg, wu, interpret=True))
        want = np.asarray(fused_mlp_hidden_ref(x, wg, wu, "swiglu"))
        denom = np.abs(want).max()
        assert np.abs(got - want).max() / denom < 0.03


# -- mixed-dtype tuning keys + tuned dispatch --------------------------------

class TestMixedDtypeTuning:
    def test_mixed_dtype_key(self):
        assert mixed_dtype("bfloat16", "int8") == "bfloat16xint8"
        assert mixed_dtype("float32", "float8_e4m3fn") == "float32xfloat8_e4m3fn"
        assert cache_key("int8_matmul", (8, 16, 32),
                         mixed_dtype("float32", "int8"),
                         "tpu_v5e") == "int8_matmul/8x16x32/float32xint8/tpu_v5e"

    def test_autotune_int8_matmul_writes_mixed_key(self):
        cache = TuningCache()
        cfg = autotune_int8_matmul(64, 64, 64, cache=cache, iters=1,
                                   warmup=0, max_candidates=2)
        assert cfg.op == "int8_matmul" and cfg.dtype == "float32xint8"
        assert cache.get("int8_matmul", (64, 64, 64), "float32xint8",
                         cfg.hw_name) is not None

    def test_autotune_fp8_matmul_writes_mixed_key(self):
        cache = TuningCache()
        cfg = autotune_fp8_matmul(64, 64, 64, cache=cache, iters=1,
                                  warmup=0, max_candidates=2)
        assert cfg.dtype == "float32xfloat8_e4m3fn"

    def test_autotune_int8_fused_mlp_writes_mixed_key(self):
        cache = TuningCache()
        cfg = autotune_int8_fused_mlp(64, 64, 64, cache=cache, iters=1,
                                      warmup=0, max_candidates=2)
        assert cfg.op == "int8_fused_mlp_swiglu"
        assert cfg.dtype == "float32xint8"

    def test_tuned_dispatch_consults_mixed_key(self, monkeypatch):
        """int8_matmul(tuned=True) must look up the mixed activationxweight
        key, not the plain activation dtype."""
        from repro.kernels.quantized import ops as qops
        seen = []
        real = qops._tuning_lookup

        def spy(op, shape, dtype, hw):
            seen.append((op, shape, dtype))
            return real(op, shape, dtype, hw)

        monkeypatch.setattr(qops, "_tuning_lookup", spy)
        a, w = _operands(32, 32, 32)
        int8_matmul(a, w, tuned=True, interpret=True)
        assert seen == [("int8_matmul", (32, 32, 32), "float32xint8")]

    def test_tuned_hit_applies_cached_blocks(self):
        cache = TuningCache()
        cfg = autotune_int8_matmul(64, 64, 64, cache=cache, iters=1,
                                   warmup=0, max_candidates=3)
        set_default_cache(cache)
        a, w = _operands(64, 64, 64)
        got = int8_matmul(a, w, tuned=True, interpret=True)
        want = int8_matmul(a, w, block_m=cfg.blocks["block_m"],
                           block_n=cfg.blocks["block_n"],
                           block_k=cfg.blocks["block_k"], interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestCacheFormatRegression:
    """Cache files written before the low-precision PR must load unchanged
    and survive a save/load round trip byte-compatibly — mixed-dtype entries
    extend the key vocabulary, not the schema."""

    def test_prequant_fixture_round_trips(self, tmp_path):
        src = FIXTURES / "tuning_cache_prequant.json"
        cache = TuningCache.load(str(src))
        assert len(cache.entries) == 3
        got = cache.get("matmul", (512, 512, 512), "bfloat16", "tpu_v5e")
        assert got is not None
        assert got.blocks == {"block_k": 128, "block_m": 512, "block_n": 128}
        assert got.time_us == pytest.approx(812.4)
        assert got.baseline_us == pytest.approx(1034.9)

        out = tmp_path / "rt.json"
        cache.save(str(out))
        with open(src) as f:
            want = json.load(f)
        with open(out) as f:
            have = json.load(f)
        assert have == want

    def test_mixed_entries_coexist_with_prequant_entries(self, tmp_path):
        cache = TuningCache.load(str(FIXTURES / "tuning_cache_prequant.json"))
        autotune_int8_matmul(64, 64, 64, cache=cache, iters=1, warmup=0,
                             max_candidates=1)
        out = tmp_path / "mixed.json"
        cache.save(str(out))
        re = TuningCache.load(str(out))
        assert re.get("matmul", (512, 512, 512), "bfloat16",
                      "tpu_v5e") is not None
        assert re.get("int8_matmul", (64, 64, 64), "float32xint8",
                      re.by_op("int8_matmul")[0].hw_name) is not None


# -- linear dispatch ---------------------------------------------------------

class TestQuantizedLinearDispatch:
    def test_forward_close_to_jnp(self):
        x = jax.random.normal(KEY, (2, 8, 64)) * 0.5
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 32)) * 0.5
        got = np.asarray(linear(x, w, impl="quantized"))
        want = np.asarray(linear(x, w, impl="jnp"))
        assert got.shape == (2, 8, 32)
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < 0.02

    def test_frozen_weight_matches_float_weight(self):
        x = jax.random.normal(KEY, (16, 32))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (32, 24))
        got = linear(x, quantize_weight(w), impl="quantized")
        want = linear(x, w, impl="quantized")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-6, rtol=3e-6)

    def test_straight_through_gradients(self):
        x = jax.random.normal(KEY, (8, 32))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (32, 16))

        def loss(x, w):
            return jnp.sum(linear(x, w, impl="quantized") ** 2)

        dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
        assert dx.shape == x.shape and dw.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(dx))) and bool(jnp.all(jnp.isfinite(dw)))
        # straight-through: must be close to the float-path gradients
        fdx, fdw = jax.grad(lambda x, w: jnp.sum((x @ w) ** 2),
                            argnums=(0, 1))(x, w)
        for got, want in ((dx, fdx), (dw, fdw)):
            got, want = np.asarray(got), np.asarray(want)
            assert np.abs(got - want).max() / np.abs(want).max() < 0.05

    def test_frozen_weight_gradient_flows_to_activation(self):
        x = jax.random.normal(KEY, (8, 32))
        qt = quantize_weight(jax.random.normal(KEY, (32, 16)))
        dx = jax.grad(
            lambda x: jnp.sum(linear(x, qt, impl="quantized") ** 2))(x)
        assert bool(jnp.all(jnp.isfinite(dx)))

    def test_unknown_impl_raises(self):
        with pytest.raises(ValueError, match="unknown linear_impl 'int4'"):
            linear(jnp.ones((4, 8)), jnp.ones((8, 4)), impl="int4")

    def test_quantize_linear_params_filters_by_name(self):
        params = {
            "blocks": [{"attn": {"wq": jnp.ones((8, 8)),
                                 "wo": jnp.ones((8, 8))},
                        "mlp": {"w_up": jnp.ones((8, 16)),
                                "norm_gain": jnp.ones((8,))}}],
            "embed": jnp.ones((32, 8)),          # not a GEMM weight leaf
            "lm_head": jnp.ones((8, 32)),
            "conv_kernel": jnp.ones((4, 8)),     # 2-D but not in the key set
        }
        q = quantize_linear_params(params)
        blk = q["blocks"][0]
        assert isinstance(blk["attn"]["wq"], QuantizedLinear)
        assert isinstance(blk["attn"]["wo"], QuantizedLinear)
        assert isinstance(blk["mlp"]["w_up"], QuantizedLinear)
        assert isinstance(q["lm_head"], QuantizedLinear)
        # non-GEMM leaves pass through untouched
        assert not isinstance(q["embed"], QuantizedLinear)
        assert not isinstance(q["conv_kernel"], QuantizedLinear)
        assert not isinstance(blk["mlp"]["norm_gain"], QuantizedLinear)
        assert "conv_kernel" not in QUANT_WEIGHT_KEYS

    def test_quantized_mlp_matches_reference(self):
        cfg = get_smoke_config("internlm2-1.8b")
        h, f = cfg.d_model, cfg.d_ff
        p = {"w_gate": jax.random.normal(KEY, (h, f)) * 0.3,
             "w_up": jax.random.normal(jax.random.fold_in(KEY, 1),
                                       (h, f)) * 0.3,
             "w_down": jax.random.normal(jax.random.fold_in(KEY, 2),
                                         (f, h)) * 0.3}
        x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 4, h)) * 0.5
        got = np.asarray(quantized_mlp(x, p, cfg))
        from repro.kernels.fused_mlp.ref import fused_mlp_hidden_ref
        hid = fused_mlp_hidden_ref(x.reshape(-1, h), p["w_gate"], p["w_up"],
                                   cfg.mlp_type)
        want = np.asarray((hid @ p["w_down"]).reshape(2, 4, h))
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < 0.05


# -- end-to-end on registry configs (acceptance) -----------------------------

@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen1.5-4b"])
class TestQuantizedModelEndToEnd:
    """linear_impl="quantized" must run a full forward and a full backward on
    real registry configs (smoke-scaled) — the acceptance criterion for the
    dispatch layer."""

    def test_forward_and_grad(self, arch):
        cfg = dataclasses.replace(get_smoke_config(arch),
                                  linear_impl="quantized")
        params = init_lm(KEY, cfg)
        toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
        logits, _, _ = apply_lm(params, toks, cfg)
        assert logits.shape == (1, 8, cfg.padded_vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

        def loss(p):
            lg, _, _ = apply_lm(p, toks, cfg)
            return jnp.mean(lg.astype(jnp.float32) ** 2)

        grads = jax.grad(loss)(params)
        flat = jax.tree_util.tree_leaves(grads)
        assert flat and all(
            bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
            for g in flat if g.dtype != jax.dtypes.float0)
