"""Quantized KV cache: int8 pool leaves with per-(token, head) scales, the
dequantizing paged decode kernels, model-level greedy parity, and engine
serving with kv_dtype="int8"."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, get_smoke_config
from repro.kernels.flash_attention.ops import (paged_decode,
                                               paged_decode_blocktable)
from repro.kernels.flash_attention.ref import (paged_decode_blocktable_ref,
                                               paged_decode_ref)
from repro.models import apply_lm, init_caches, init_lm
from repro.models.blocks import KV_DTYPES, kv_cache_dtype
from repro.quant import dequantize_kv, kv_bytes_per_token, quantize_kv
from repro.serving.engine import Engine, synthetic_requests
from repro.serving.serve_step import greedy_generate

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_smoke_config("internlm2-1.8b")
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


# -- quantize_kv / cache structure -------------------------------------------

class TestQuantizeKV:
    def test_round_trip(self):
        x = jax.random.normal(KEY, (2, 16, 4, 32))  # (b, s, nkv, d)
        q, scale = quantize_kv(x)
        assert q.dtype == jnp.int8 and q.shape == x.shape
        assert scale.dtype == jnp.float32 and scale.shape == (2, 16, 4)
        back = dequantize_kv(q, scale, jnp.float32)
        # per-(token, head) absmax: half a step of that slice's range
        step = np.asarray(scale).max()
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   atol=0.51 * step)

    def test_kv_cache_dtype_resolution(self):
        cfg = get_smoke_config("internlm2-1.8b")
        assert kv_cache_dtype(cfg, jnp.bfloat16) == jnp.bfloat16
        cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
        assert kv_cache_dtype(cfg8, jnp.bfloat16) == jnp.int8
        bad = dataclasses.replace(cfg, kv_dtype="int4")
        with pytest.raises(ValueError, match="unknown kv_dtype 'int4'"):
            kv_cache_dtype(bad, jnp.bfloat16)
        assert "int8" in KV_DTYPES and "auto" in KV_DTYPES

    def test_mla_rejects_int8(self):
        cfg = get_smoke_config("deepseek-v3-671b")  # MLA attention
        assert cfg.attn_type == "mla"
        cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
        with pytest.raises(ValueError, match="mla"):
            kv_cache_dtype(cfg8, jnp.bfloat16)

    def test_int8_cache_leaves(self):
        cfg = dataclasses.replace(get_smoke_config("internlm2-1.8b"),
                                  kv_dtype="int8")
        b, s_max = 2, 16
        caches = init_caches(cfg, b, s_max, jnp.float32)
        seg = caches[0]
        n = seg["k"].shape[0]
        assert seg["k"].dtype == jnp.int8 and seg["v"].dtype == jnp.int8
        assert seg["k_scale"].dtype == jnp.float32
        assert seg["k_scale"].shape == (n, b, s_max, cfg.num_kv_heads)
        assert seg["v_scale"].shape == seg["k_scale"].shape

    def test_kv_bytes_halve_pool_cost(self):
        # full-size config: at real head_dims the per-(token, head) scale
        # overhead is small next to the payload halving
        cfg = get_config("internlm2-1.8b")
        d = cfg.d_model // cfg.num_heads
        bf16 = kv_bytes_per_token(cfg.num_kv_heads, d)
        int8 = kv_bytes_per_token(cfg.num_kv_heads, d, "int8")
        # slots-per-GiB scales by the inverse ratio; scale overhead keeps it
        # just under the ideal 2x
        gib = 1 << 30
        slots_bf16 = gib // (bf16 * cfg.num_layers * 128)
        slots_int8 = gib // (int8 * cfg.num_layers * 128)
        assert 1.7 < slots_int8 / slots_bf16 <= 2.0


# -- dequantizing paged kernels ----------------------------------------------

def _quant_pools(slots, s_max, nkv, d):
    kp = jax.random.normal(KEY, (slots, s_max, nkv, d)) * 0.5
    vp = jax.random.normal(jax.random.fold_in(KEY, 1),
                           (slots, s_max, nkv, d)) * 0.5
    kq, ks = quantize_kv(kp)
    vq, vs = quantize_kv(vp)
    return (kq, ks, vq, vs,
            dequantize_kv(kq, ks, jnp.float32),
            dequantize_kv(vq, vs, jnp.float32))


class TestQuantizedPagedDecode:
    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_slot_variant_vs_dequantized_ref(self, use_pallas):
        slots, s_max, nkv, d, b = 8, 128, 2, 32, 4
        kq, ks, vq, vs, kd, vd = _quant_pools(slots, s_max, nkv, d)
        q = jax.random.normal(jax.random.fold_in(KEY, 2), (b, nkv * 3, d))
        slot_idx = jnp.asarray([5, 0, 7, 2], jnp.int32)
        lengths = jnp.asarray([17, 0, 128, 64], jnp.int32)  # 0 = dead slot
        got = paged_decode(q, kq, vq, slot_idx, lengths, k_scale=ks,
                           v_scale=vs, block_kv=64, interpret=True,
                           use_pallas=use_pallas)
        want = paged_decode_ref(q, kd, vd, slot_idx, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)
        assert np.all(np.asarray(got)[1] == 0.0)  # dead slot stays zero

    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_blocktable_variant_vs_dequantized_ref(self, use_pallas):
        nb, bs, nkv, d, b, max_blocks = 12, 32, 2, 32, 3, 4
        kq, ks, vq, vs, kd, vd = _quant_pools(nb, bs, nkv, d)
        q = jax.random.normal(jax.random.fold_in(KEY, 3), (b, nkv * 2, d))
        tables = jnp.asarray([[3, 7, 1, 0], [11, 0, 0, 0], [2, 4, 6, 8]],
                             jnp.int32)
        lengths = jnp.asarray([100, 20, 128], jnp.int32)
        got = paged_decode_blocktable(q, kq, vq, tables, lengths, k_scale=ks,
                                      v_scale=vs, interpret=True,
                                      use_pallas=use_pallas)
        want = paged_decode_blocktable_ref(q, kd, vd, tables, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_quant_close_to_float_pool(self):
        """End-to-end quantization noise on attention outputs stays small."""
        slots, s_max, nkv, d, b = 4, 64, 2, 32, 2
        kq, ks, vq, vs, _, _ = _quant_pools(slots, s_max, nkv, d)
        kp = jax.random.normal(KEY, (slots, s_max, nkv, d)) * 0.5
        vp = jax.random.normal(jax.random.fold_in(KEY, 1),
                               (slots, s_max, nkv, d)) * 0.5
        q = jax.random.normal(jax.random.fold_in(KEY, 2), (b, nkv, d))
        idx = jnp.asarray([0, 3], jnp.int32)
        lens = jnp.asarray([64, 32], jnp.int32)
        got = np.asarray(paged_decode(q, kq, vq, idx, lens, k_scale=ks,
                                      v_scale=vs, interpret=True))
        want = np.asarray(paged_decode_ref(q, kp, vp, idx, lens))
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < 0.05


# -- model-level greedy parity -----------------------------------------------

def _greedy(params, cfg, toks, n_new):
    b, s = toks.shape
    caches = init_caches(cfg, b, s + n_new, jnp.float32)
    logits, caches, _ = apply_lm(params, toks, cfg, caches=caches,
                                 cache_index=0)
    out = []
    nxt = jnp.argmax(logits[:, -1], axis=-1)
    for i in range(n_new):
        out.append(nxt)
        logits, caches, _ = apply_lm(params, nxt[:, None], cfg,
                                     caches=caches, cache_index=s + i,
                                     decode=True)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
    return np.stack([np.asarray(t) for t in out], axis=1)


class TestModelGreedyParity:
    def test_int8_kv_tracks_f32_kv(self, smoke_lm):
        """A random-init model has near-uniform logits, so token-exact greedy
        parity over a long horizon is not a meaningful bar — what must hold
        is that the quantized cache perturbs logits only at quantization-noise
        scale, and the leading greedy tokens (before noise-level ties can
        flip) agree exactly."""
        cfg, params = smoke_lm
        cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
        toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
        b, s = toks.shape

        # prefill logits under both caches: quantization-noise-level delta
        want_lg, _, _ = apply_lm(params, toks, cfg,
                                 caches=init_caches(cfg, b, s, jnp.float32),
                                 cache_index=0)
        got_lg, _, _ = apply_lm(params, toks, cfg8,
                                caches=init_caches(cfg8, b, s, jnp.float32),
                                cache_index=0)
        want_lg = np.asarray(want_lg, np.float32)
        got_lg = np.asarray(got_lg, np.float32)
        assert np.abs(got_lg - want_lg).max() / np.abs(want_lg).max() < 0.05

        want = _greedy(params, cfg, toks, 8)
        got = _greedy(params, cfg8, toks, 8)
        np.testing.assert_array_equal(got[:, :3], want[:, :3])


# -- engine serving with kv_dtype="int8" -------------------------------------

class TestEngineInt8KV:
    def _check(self, cfg8, params, reqs, done):
        assert [c.rid for c in done] == [r.rid for r in reqs]
        for r, c in zip(reqs, done):
            want = np.asarray(greedy_generate(
                params, cfg8, jnp.asarray(r.tokens[None]),
                r.max_new_tokens))[0]
            assert np.array_equal(np.asarray(c.tokens), want), f"rid {r.rid}"

    def test_token_parity(self, smoke_lm):
        cfg, params = smoke_lm
        reqs = synthetic_requests(6, pattern="burst", min_prompt=4,
                                  max_prompt=24, min_new=3, max_new=8,
                                  vocab=cfg.vocab_size, seed=21)
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=8,
                     kv_dtype="int8")
        assert eng.cfg.kv_dtype == "int8"
        done, stats = eng.run(reqs)
        assert stats.prefills == 6
        # reference loop under the SAME quantized-cache config: continuous
        # batching + int8 pool reuse must not change a single token
        self._check(eng.cfg, params, reqs, done)

    def test_token_parity_paged_kernel(self, smoke_lm):
        cfg, params = smoke_lm
        reqs = synthetic_requests(4, pattern="burst", min_prompt=4,
                                  max_prompt=20, min_new=3, max_new=6,
                                  vocab=cfg.vocab_size, seed=23)
        eng = Engine(params, cfg, max_batch=4, max_prompt=32, max_new=8,
                     use_paged_kernel=True, kv_dtype="int8")
        assert eng.cfg.attn_impl == "paged" and eng.cfg.kv_dtype == "int8"
        done, _ = eng.run(reqs)
        self._check(eng.cfg, params, reqs, done)

    def test_unknown_kv_dtype_raises(self, smoke_lm):
        cfg, params = smoke_lm
        with pytest.raises(ValueError, match="unknown kv_dtype 'fp4'"):
            Engine(params, cfg, max_batch=2, max_prompt=16, max_new=4,
                   kv_dtype="fp4")
