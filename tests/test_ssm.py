"""Mamba2 SSD: chunked dual form vs naive sequential recurrence, and
decode-step parity with the chunked prefill's final state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.ssm import (_causal_conv, _dims, apply_ssm, decode_ssm,
                              init_ssm, init_ssm_cache)

KEY = jax.random.PRNGKey(3)


def naive_ssd(p, x, cfg):
    """Token-by-token reference recurrence (the SSM definition)."""
    b, s, h = x.shape
    di, N, P, nh, g = _dims(cfg)
    z = x @ p["in_z"]
    xr = _causal_conv(x @ p["in_x"], p["conv_x"], p["conv_bx"])
    B = _causal_conv(x @ p["in_B"], p["conv_B"], p["conv_bB"])
    C = _causal_conv(x @ p["in_C"], p["conv_C"], p["conv_bC"])
    dt = jax.nn.softplus((x @ p["in_dt"]) + p["dt_bias"])  # (b,s,nh)
    A = -jnp.exp(p["A_log"])
    xin = xr.reshape(b, s, nh, P)
    Bh = jnp.repeat(B.reshape(b, s, g, N), nh // g, axis=2)
    Ch = jnp.repeat(C.reshape(b, s, g, N), nh // g, axis=2)

    state = jnp.zeros((b, nh, N, P))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A)  # (b, nh)
        xdt = xin[:, t] * dt[:, t][..., None]
        state = state * dA[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bh[:, t], xdt)
        y = jnp.einsum("bhnp,bhn->bhp", state, Ch[:, t]) + xin[:, t] * p["D"][None, :, None]
        ys.append(y.reshape(b, di))
    y = jnp.stack(ys, axis=1)
    from repro.models.layers import norm_apply
    y = norm_apply(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], state


@pytest.mark.parametrize("s", [32, 64, 96])
def test_chunked_matches_naive(s):
    cfg = get_smoke_config("mamba2-780m")
    p = init_ssm(KEY, cfg)
    x = jax.random.normal(KEY, (2, s, cfg.d_model)) * 0.5
    got, (state_got, _) = apply_ssm(p, x, cfg)
    want, state_want = naive_ssd(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(state_got), np.asarray(state_want),
                               atol=2e-4, rtol=2e-3)


def test_decode_continues_prefill():
    """decode_ssm from the chunked state must equal running the chunked form
    over the extended sequence."""
    cfg = get_smoke_config("mamba2-780m")
    p = init_ssm(KEY, cfg)
    b, s = 2, 32
    x = jax.random.normal(KEY, (b, s + 1, cfg.d_model)) * 0.5

    full, _ = apply_ssm(p, x, cfg)
    want_last = full[:, -1]

    _, (state, _) = apply_ssm(p, x[:, :s], cfg)
    cache = init_ssm_cache(cfg, b, jnp.float32)
    cache["state"] = state
    # conv caches need the last (width-1) preactivations of each branch
    w = cfg.conv_width - 1
    cache["conv_x"] = (x[:, s - w:s] @ p["in_x"])
    cache["conv_B"] = (x[:, s - w:s] @ p["in_B"])
    cache["conv_C"] = (x[:, s - w:s] @ p["in_C"])
    got, new_cache = decode_ssm(p, x[:, s:s + 1], cfg, cache)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want_last),
                               atol=2e-4, rtol=2e-3)
    assert new_cache["state"].shape == cache["state"].shape


def test_state_carry_across_chunk_boundaries():
    """Feeding two halves with carried state == one full pass."""
    cfg = get_smoke_config("mamba2-780m")
    Q = cfg.ssm_chunk
    p = init_ssm(KEY, cfg)
    x = jax.random.normal(KEY, (1, 2 * Q, cfg.d_model)) * 0.5
    full, (sf, _) = apply_ssm(p, x, cfg)
    # NOTE: splitting mid-sequence also splits the causal conv; feed overlap
    # is not modeled here, so compare states only for conv-free positions by
    # running exact halves through the public API with state carry.
    _, (s1, _) = apply_ssm(p, x[:, :Q], cfg)
    y2, (s2, _) = apply_ssm(p, x[:, Q:], cfg, state=s1)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sf), atol=3e-3,
                               rtol=3e-2)
