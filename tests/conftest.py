import os

# Tests run on the single CPU device (the dry-run sets its own XLA_FLAGS in a
# separate process; setting 512 here would slow every test 500x).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
