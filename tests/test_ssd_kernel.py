"""SSD intra-chunk Pallas kernel: shape/dtype sweep vs the jnp oracle, plus
an end-to-end cross-check against models/ssm.py's chunked math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.ops import ssd_chunk
from repro.kernels.ssd.ref import ssd_chunk_ref

KEY = jax.random.PRNGKey(11)


def _inputs(bh, nc, Q, P, N, dtype=jnp.float32):
    x = (jax.random.normal(KEY, (bh, nc, Q, P)) * 0.5).astype(dtype)
    B = (jax.random.normal(jax.random.fold_in(KEY, 1), (bh, nc, Q, N)) * 0.5).astype(dtype)
    C = (jax.random.normal(jax.random.fold_in(KEY, 2), (bh, nc, Q, N)) * 0.5).astype(dtype)
    seg = -jnp.cumsum(jax.random.uniform(jax.random.fold_in(KEY, 3),
                                         (bh, nc, Q)), axis=-1)
    return x, B, C, seg


@pytest.mark.parametrize("bh,nc,Q,P,N", [
    (4, 3, 32, 16, 16),
    (2, 2, 64, 32, 64),
    (1, 4, 128, 64, 128),   # mamba2-780m native tile
    (2, 1, 256, 64, 128),   # full production chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(bh, nc, Q, P, N, dtype):
    x, B, C, seg = _inputs(bh, nc, Q, P, N, dtype)
    y1, s1 = ssd_chunk(x, B, C, seg, interpret=True)
    y2, s2 = ssd_chunk_ref(x, B, C, seg)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s1, np.float32),
                               np.asarray(s2, np.float32), atol=tol, rtol=tol)


def test_kernel_matches_model_ssd_intra_chunk():
    """The kernel's Y_diag must equal models/ssm.py's intra-chunk term."""
    from repro.configs.registry import get_smoke_config
    from repro.models.ssm import _dims

    cfg = get_smoke_config("mamba2-780m")
    di, N, P, nh, g = _dims(cfg)
    b, s = 2, 64
    Q = cfg.ssm_chunk
    nc = s // Q
    key = jax.random.PRNGKey(0)
    x_dt = jax.random.normal(key, (b, nc, Q, nh, P)) * 0.5
    Bc = jax.random.normal(jax.random.fold_in(key, 1), (b, nc, Q, nh, N)) * 0.5
    Cc = jax.random.normal(jax.random.fold_in(key, 2), (b, nc, Q, nh, N)) * 0.5
    seg = -jnp.cumsum(jax.random.uniform(jax.random.fold_in(key, 3),
                                         (b, nc, Q, nh)), axis=2)

    # model math (models/ssm.py apply_ssm intra-chunk block)
    CB = jnp.einsum("bcqhn,bckhn->bcqkh", Cc, Bc)
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    want = jnp.einsum("bcqkh,bckhp->bcqhp", CB * L, x_dt)

    # kernel layout: fold (b, h) -> bh
    def fold(t):
        return t.transpose(0, 3, 1, 2, 4).reshape(b * nh, nc, Q, t.shape[-1])
    seg_f = seg.transpose(0, 3, 1, 2).reshape(b * nh, nc, Q)
    y, _ = ssd_chunk(fold(x_dt), fold(Bc), fold(Cc), seg_f, interpret=True)
    got = y.reshape(b, nh, nc, Q, P).transpose(0, 2, 3, 1, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)
