"""Explicit-EP (shard_map) MoE dispatch must match the auto-SPMD path, in
loss AND in gradients, on a real multi-device mesh."""
import subprocess
import sys
import textwrap


def _run(code: str, timeout=560):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent(code))
    r = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd="/root/repo", timeout=timeout)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    return r.stdout


def test_shardmap_dispatch_matches_auto_loss_and_grads():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs.registry import get_smoke_config
        from repro.configs.base import MeshConfig
        from repro.models import init_lm, lm_loss
        from repro.parallel import sharding as sh

        # drop-free capacity so both paths route identically
        cfg = dataclasses.replace(get_smoke_config('deepseek-v3-671b'),
                                  moe_capacity_factor=8.0)
        mesh = sh.make_mesh(MeshConfig(data=2, model=4))
        sh.set_activation_context(('data',), mesh=mesh)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        pspecs = sh.param_specs(params, cfg, mesh)
        params_d = jax.device_put(params, sh.to_shardings(pspecs, mesh))
        batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                              0, cfg.vocab_size)}
        batch['labels'] = batch['tokens']
        cfg_sm = dataclasses.replace(cfg, moe_dispatch='shard_map')

        def loss(c):
            return jax.jit(lambda p, b: lm_loss(p, b, c)[0])

        with mesh:
            l_auto = float(loss(cfg)(params_d, batch))
            l_sm = float(loss(cfg_sm)(params_d, batch))
            g_auto = jax.jit(jax.grad(lambda p: lm_loss(p, batch, cfg)[0]))(params_d)
            g_sm = jax.jit(jax.grad(lambda p: lm_loss(p, batch, cfg_sm)[0]))(params_d)
        assert abs(l_auto - l_sm) < 2e-3, (l_auto, l_sm)
        errs = [float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(g_auto), jax.tree.leaves(g_sm))]
        assert max(errs) < 5e-3, max(errs)
        print('SHARDMAP_GRADS_OK', l_auto, max(errs))
    """)
    assert "SHARDMAP_GRADS_OK" in out
