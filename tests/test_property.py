"""Property-based tests (hypothesis) on system invariants.

hypothesis is a dev-only dependency (requirements-dev.txt); on a clean
checkout without it the module skips instead of failing collection.
"""
import dataclasses

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.configs.base import ModelConfig, TRAIN_4K
from repro.core import advisor, quantization as q
from repro.core.gemm_model import GEMM, estimate
from repro.core.hardware import TPU_V5E, A100_40GB
from repro.data.pipeline import synthetic_tokens
from repro.optim.adamw import dequantize_i8, quantize_i8

SET = settings(deadline=None, max_examples=40)

dims = st.integers(min_value=1, max_value=16384)
small_dims = st.integers(min_value=1, max_value=512)


@SET
@given(m=dims, n=dims, k=dims)
def test_tile_utilization_in_unit_interval(m, n, k):
    for hw in (TPU_V5E, A100_40GB):
        u = q.tile_utilization(m, n, k, hw)
        assert 0 < u <= 1.0


@SET
@given(m=dims, n=dims, k=dims, batch=st.integers(1, 64))
def test_estimate_respects_roofline(m, n, k, batch):
    g = GEMM("g", m, k, n, batch=batch)
    e = estimate(g, TPU_V5E)
    # achieved throughput can never exceed peak
    assert e.achieved_tflops <= TPU_V5E.peak_flops / 1e12 + 1e-6
    assert e.time_s >= g.flops / TPU_V5E.peak_flops - 1e-12


@SET
@given(x=dims, mult=st.sampled_from([8, 16, 64, 128, 256]))
def test_round_up_properties(x, mult):
    r = q.round_up(x, mult)
    assert r >= x and r % mult == 0 and r - x < mult


@SET
@given(n=st.integers(1, 2 ** 30))
def test_pow2_factor_divides(n):
    f = q.pow2_factor(n)
    assert n % f == 0
    assert f & (f - 1) == 0  # power of two


@SET
@given(dim=dims, shards=st.sampled_from([1, 2, 4, 8, 16, 32]))
def test_shard_quantization_bounds(dim, shards):
    u = q.shard_quantization(dim, shards)
    assert 0 < u <= 1
    if dim % shards == 0:
        assert u == 1.0


@SET
@given(h_mult=st.integers(2, 40), heads=st.sampled_from([8, 16, 20, 32, 40]))
def test_advisor_proposals_preserve_params_and_help(h_mult, heads):
    h = 128 * h_mult
    if h % heads:
        return
    cfg = ModelConfig(name="p", family="dense", num_layers=8, d_model=h,
                      num_heads=heads, num_kv_heads=heads, d_ff=4 * h,
                      vocab_size=50257, mlp_type="gelu")
    props = advisor.advise(cfg, param_tolerance=0.03)
    for p in props[:4]:
        assert abs(p.param_delta) <= 0.03 + 1e-9
        assert p.predicted_speedup > 0


@SET
@given(shape=st.sampled_from([(7,), (128,), (130,), (4, 33), (2, 3, 5)]),
       seed=st.integers(0, 2 ** 16))
def test_int8_quantization_roundtrip_error(shape, seed):
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), shape)) * 3.0
    qd = quantize_i8(jnp.asarray(x))
    back = np.asarray(dequantize_i8(qd, shape))
    # blockwise absmax int8: error bounded by scale/2 per block
    err = np.abs(back - x)
    bound = np.max(np.abs(x)) / 127.0 + 1e-7
    assert np.max(err) <= bound * 1.01


@SET
@given(seed=st.integers(0, 2 ** 20), step=st.integers(0, 10 ** 6),
       batch=st.integers(1, 8), seq=st.integers(1, 128),
       vocab=st.integers(2, 200000))
def test_synthetic_tokens_deterministic_and_in_range(seed, step, batch, seq, vocab):
    a = synthetic_tokens(seed, step, batch, seq, vocab)
    b = synthetic_tokens(seed, step, batch, seq, vocab)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < vocab


@SET
@given(v=st.integers(1, 300000))
def test_padded_vocab_invariants(v):
    cfg = ModelConfig(name="v", family="dense", num_layers=1, d_model=128,
                      num_heads=2, num_kv_heads=2, d_ff=256, vocab_size=v)
    pv = cfg.padded_vocab_size
    assert pv >= v and pv % 128 == 0 and pv - v < 128
